"""Quickstart: train a tiny SLM through both SATER stages on the
synthetic suite, then route a few queries both ways.

  PYTHONPATH=src python examples/quickstart.py [--scale tiny|small]

Artifacts cache under benchmarks/artifacts so re-runs are instant.
"""

import argparse

import jax
import numpy as np

from repro.core import routing as routing_lib
from repro.core.experiment import SCALES, eval_items, get_models, make_slm
from repro.core.metrics import outcome_latency


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    args = ap.parse_args()
    x = SCALES[args.scale]

    print("== SATER quickstart ==")
    models = get_models(x)
    sater = make_slm(models["stage2"], x)
    llm = routing_lib.OracleLLM(accuracy=1.0, avg_out_tokens=60)

    items = eval_items(x, "modchain")[:8] + eval_items(x, "arith")[:8]

    print("\n-- pre-generation routing (prompt at tau=0.6, route on refusal) --")
    out = routing_lib.pregen_outcomes_sater(sater, items, llm,
                                            jax.random.PRNGKey(0),
                                            thresholds=[0.6])
    for it, o in zip(items, out[0.6]):
        dest = "LLM" if o.routed else "SLM"
        ok = "?" if o.routed else ("OK" if o.slm_correct else "WRONG")
        print(f"  [{dest:>3}] ({ok:>5}) d={it.difficulty} {it.question[:60]}")

    print("\n-- cascade routing (FCV, early stop, tau=0.6) --")
    cas = routing_lib.cascade_outcomes(sater, items, llm,
                                       jax.random.PRNGKey(1), mode="FCV",
                                       k=6, thresholds=[0.6])
    lat = outcome_latency(cas[0.6])
    acc = np.mean([(o.llm_correct if o.routed else o.slm_correct)
                   for o in cas[0.6]])
    print(f"  accepted {lat['frac_accepted']:.0%}  AGL={lat['AGL']:.0f} "
          f"AROL={lat['AROL']:.0f}  accuracy={acc:.0%}")


if __name__ == "__main__":
    main()
