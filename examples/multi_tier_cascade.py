"""Beyond-paper demo: multi-tier cascading (paper Limitation §1 names
this as future work).

Chain: tier0 = SATER SLM at a strict threshold (cheap, answers only
what it is confident about) -> tier1 = the same SATER model at a looser
threshold with more votes (stands in for a mid-size model; in a real
deployment this would be a separately-trained medium SLM) -> terminal
oracle LLM.

  PYTHONPATH=src python examples/multi_tier_cascade.py
"""

import jax

from repro.core import cascade_multi as cm
from repro.core.experiment import SCALES, eval_items, get_models, make_slm
from repro.core.routing import OracleLLM


def main():
    x = SCALES["tiny"]
    models = get_models(x)
    sater = make_slm(models["stage2"], x)

    items = []
    for b in ("arith", "parity", "modchain"):
        items.extend(eval_items(x, b)[:10])

    tiers = [
        cm.Tier(slm=sater, tau=0.45, mode="RCV", k=6, out_price=0.02,
                in_price=0.005),
        cm.Tier(slm=sater, tau=0.2, mode="RCV", k=10, out_price=0.08,
                in_price=0.02),
    ]
    terminal = cm.TerminalTier(llm=OracleLLM(accuracy=1.0,
                                             avg_out_tokens=40))

    out = cm.run_cascade(tiers, terminal, items, jax.random.PRNGKey(0))
    s = cm.summarize(out, len(tiers))
    print("== 3-tier cascade (strict SATER -> loose SATER -> oracle) ==")
    print(f"questions: {len(items)}")
    print(f"tier histogram (answers per tier): {s['tier_histogram']}")
    print(f"accuracy: {s['accuracy']:.2f}")
    print(f"total cost: ${s['cost'] * 1e6:.1f} per 1M-question-scale "
          f"(token prices are per-1M)")
    print(f"AGL (tiers that answered): {s['AGL']:.1f} tokens")
    print(f"AROL (fell to terminal): {s['AROL']:.1f} tokens")

    # two-tier baseline for comparison
    out2 = cm.run_cascade(tiers[1:], terminal, items, jax.random.PRNGKey(0))
    s2 = cm.summarize(out2, 1)
    print("\n== 2-tier baseline (loose SATER -> oracle) ==")
    print(f"tier histogram: {s2['tier_histogram']}   "
          f"accuracy: {s2['accuracy']:.2f}   cost: ${s2['cost'] * 1e6:.1f}")


if __name__ == "__main__":
    main()
