"""Pre-generation routing comparison: SATER (self-aware refusal) vs the
classifier baselines (BERT-style, KNN, HybridLLM) on one benchmark.

  PYTHONPATH=src python examples/pregen_route.py --scale tiny --benchmark modchain
"""

import argparse

import jax

from repro.core import baselines as bl
from repro.core import metrics as metrics_lib
from repro.core import routing as routing_lib
from repro.core.cost import DEFAULT
from repro.core.experiment import SCALES, eval_items, get_models, make_slm, \
    stage_questions
from repro.core.metrics import QuestionRecord
from repro.data.pipeline import format_prompt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--benchmark", default="modchain")
    args = ap.parse_args()
    x = SCALES[args.scale]

    models = get_models(x)
    llm = routing_lib.OracleLLM(accuracy=1.0, avg_out_tokens=60)
    items = eval_items(x, args.benchmark)
    key = jax.random.PRNGKey(0)

    # --- shared: SLM-only answers + golden ToGA ---
    base = make_slm(models["base"], x)
    (c_s, p_s), slm_corr, slm_out, _ = routing_lib.slm_only_endpoint(
        base, items, llm, key, DEFAULT)
    golden = metrics_lib.golden_toga_100(
        slm_corr, [len(format_prompt(it)) for it in items], slm_out,
        DEFAULT, [60] * len(items))

    # --- classifier baselines: trained on Stage-question correctness ---
    train_items = stage_questions(x)
    samples = routing_lib.collect_samples(base, train_items, 4,
                                          jax.random.PRNGKey(7))
    train_prompts = [format_prompt(s.item) for s in samples]
    soft = [s.accuracy for s in samples]
    hard = [1.0 if s.accuracy >= 0.5 else 0.0 for s in samples]
    eval_prompts = [format_prompt(it) for it in items]

    def records(scores):
        return [QuestionRecord(sc, lc, len(p), so, 60, float(s))
                for sc, lc, p, so, s in zip(
                    slm_corr, [llm.answer(it)[0] for it in items],
                    eval_prompts, slm_out, scores)]

    print(f"benchmark={args.benchmark}  SLM-only acc={p_s:.2f} cost={c_s:.3f}")
    print(f"{'method':12s} {'ToA-100':>8} {'ToGR':>7}")
    for name, router in (
            ("KNN", bl.KNNRouter().fit(train_prompts, hard)),
            ("HybridLLM", bl.HybridLLMRouter().fit(train_prompts, soft)),
            ("BERT", bl.BERTRouter(epochs=4).fit(train_prompts, hard))):
        recs = records(router.score(eval_prompts))
        s = metrics_lib.toa_summary(recs, DEFAULT)
        print(f"{name:12s} {s['toa_100']:8.3f} {s['togr']:7.3f}")

    # --- SATER: behavioural refusal ---
    sater = make_slm(models["stage2"], x)
    out = routing_lib.pregen_outcomes_sater(sater, items, llm, key)
    s = metrics_lib.outcome_toa_summary(out, DEFAULT, (c_s, p_s), golden)
    print(f"{'SATER':12s} {s['toa_100']:8.3f} {s['togr']:7.3f}")


if __name__ == "__main__":
    main()
