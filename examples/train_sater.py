"""End-to-end SATER training driver: base SFT -> Stage I (shortest-
response DPO) -> Stage II (confidence-aware refusal SFT), with
checkpoints after every stage and a token-compression report
(the paper's Table 5/6 quantities).

  PYTHONPATH=src python examples/train_sater.py --scale tiny
  PYTHONPATH=src python examples/train_sater.py --scale small --force
"""

import argparse
import os

import jax
import numpy as np

from repro.core import routing as routing_lib
from repro.core.experiment import (SCALES, eval_items, get_models, make_slm)
from repro.data.pipeline import format_prompt
from repro.data.tasks import IN_DOMAIN, is_correct


def evaluate(slm, x, benchmarks, key):
    rows = {}
    for b in benchmarks:
        items = eval_items(x, b)
        texts, lens = routing_lib.batch_generate(
            slm, [format_prompt(it) for it in items], key)
        rows[b] = {
            "acc": float(np.mean([is_correct(it, t)
                                  for it, t in zip(items, texts)])),
            "tokens": float(np.mean(lens)),
        }
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--artifacts", default="benchmarks/artifacts")
    ap.add_argument("--force", action="store_true",
                    help="retrain even if cached checkpoints exist")
    args = ap.parse_args()
    x = SCALES[args.scale]
    if args.force and os.path.isdir(args.artifacts):
        for f in os.listdir(args.artifacts):
            if f.startswith(x.tag + "_"):
                os.remove(os.path.join(args.artifacts, f))

    models = get_models(x, artifacts=args.artifacts)

    print("\n== long-to-short effectiveness (paper Tables 5/6) ==")
    key = jax.random.PRNGKey(42)
    base_rows = evaluate(make_slm(models["base"], x, 0.0), x, IN_DOMAIN, key)
    s1_rows = evaluate(make_slm(models["stage1"], x, 0.0), x, IN_DOMAIN, key)
    print(f"{'benchmark':12s} {'acc0':>6} {'tok0':>6} {'acc1':>6} {'tok1':>6} "
          f"{'dAcc':>7} {'dTok':>7}")
    for b in IN_DOMAIN:
        a0, t0 = base_rows[b]["acc"], base_rows[b]["tokens"]
        a1, t1 = s1_rows[b]["acc"], s1_rows[b]["tokens"]
        print(f"{b:12s} {a0:6.2f} {t0:6.0f} {a1:6.2f} {t1:6.0f} "
              f"{100*(a1-a0):+6.1f}% {100*(t1-t0)/max(t0,1):+6.1f}%")


if __name__ == "__main__":
    main()
