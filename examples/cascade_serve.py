"""End-to-end serving driver (deliverable b): serve a batched request
stream through the SATER cascade — K parallel vote lanes per request on
the trained SLM, weighted majority voting with early stopping, fallback
to the LLM.  Prints per-request decisions and the AGL/AROL/cost summary
against the vanilla-SC baseline.

  PYTHONPATH=src python examples/cascade_serve.py --scale tiny --mode FCV
"""

import argparse
import time

import jax
import numpy as np

from repro.core import routing as routing_lib
from repro.core.cost import DEFAULT
from repro.core.experiment import SCALES, eval_items, get_models, make_slm
from repro.core.metrics import outcome_latency, points_from_outcomes
from repro.data.tasks import IN_DOMAIN


def serve(slm, items, llm, mode, k, tau, key, early_stop=None):
    t0 = time.time()
    out = routing_lib.cascade_outcomes(slm, items, llm, key, mode=mode, k=k,
                                       thresholds=[tau],
                                       early_stop=early_stop)
    rows = out[tau]
    lat = outcome_latency(rows)
    acc = float(np.mean([(o.llm_correct if o.routed else o.slm_correct)
                         for o in rows]))
    cost = points_from_outcomes(out, DEFAULT, assume_llm_perfect=False)[0][0]
    return {"mode": mode, "AGL": lat["AGL"], "AROL": lat["AROL"],
            "accepted": lat["frac_accepted"], "acc": acc, "cost": cost,
            "wall_s": time.time() - t0}, rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--mode", default="FCV", choices=["SC", "RCV", "FCV"])
    ap.add_argument("--tau", type=float, default=0.6)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--streamed", action="store_true",
                    help="also run the scheduler's true compute early stop")
    args = ap.parse_args()
    x = SCALES[args.scale]

    models = get_models(x)
    llm = routing_lib.OracleLLM(accuracy=1.0, avg_out_tokens=60)
    per = max(2, args.requests // len(IN_DOMAIN))
    items = [it for b in IN_DOMAIN for it in eval_items(x, b)[:per]]
    print(f"serving {len(items)} requests, mode={args.mode} "
          f"k={args.k} tau={args.tau}")

    # SATER cascade (stage2 model, early stop)
    sater = make_slm(models["stage2"], x)
    summ, rows = serve(sater, items, llm, args.mode, args.k, args.tau,
                       jax.random.PRNGKey(0))
    for it, o in zip(items, rows):
        dest = "LLM" if o.routed else "SLM"
        print(f"  [{dest:>3}] dec_t={o.decision_tokens:4d} "
              f"spent={o.slm_out_tokens:5d} d={it.difficulty} "
              f"{it.question[:52]}")

    if args.streamed:
        # true compute early stop: VoteEarlyStop kills decided vote
        # groups mid-flight inside the continuous-batching scheduler
        for early in (False, True):
            rows2, st = routing_lib.cascade_outcomes_streamed(
                sater, items, llm, jax.random.PRNGKey(0), mode=args.mode,
                k=args.k, tau=args.tau, early_stop=early)
            print(f"  streamed early_stop={early}: "
                  f"{st.generated_tokens} tokens decoded, "
                  f"{st.cancelled} lanes killed, {st.wall_s:.2f}s wall")

    # vanilla SC baseline (base model, no confidence, no early stop)
    base = make_slm(models["base"], x)
    sc, _ = serve(base, items, llm, "SC", args.k, args.tau,
                  jax.random.PRNGKey(0))

    print(f"\n{'system':12s} {'acc':>6} {'cost':>7} {'AGL':>7} {'AROL':>7} "
          f"{'kept':>6}")
    for name, s in (("SC (base)", sc), (f"SATER/{args.mode}", summ)):
        print(f"{name:12s} {s['acc']:6.2f} {s['cost']:7.3f} {s['AGL']:7.1f} "
              f"{s['AROL']:7.1f} {s['accepted']:6.0%}")
    if sc["AGL"]:
        print(f"\nAGL cut: {100*(1-summ['AGL']/sc['AGL']):.0f}%   "
              f"AROL cut: {100*(1-summ['AROL']/max(sc['AROL'],1e-9)):.0f}%")


if __name__ == "__main__":
    main()
