"""Shared benchmark harness: cached models, cached generation outcomes,
and CSV emission.  Every table benchmark writes
benchmarks/results/<name>.json and returns rows for run.py's CSV."""

from __future__ import annotations

import json
import os
import time

import jax

from repro.core import metrics as metrics_lib
from repro.core import routing as routing_lib
from repro.core.cost import DEFAULT
from repro.core.experiment import eval_items, get_models, make_slm
from repro.data.pipeline import format_prompt
from repro.data.tasks import IN_DOMAIN, OUT_OF_DOMAIN

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
BENCHMARKS = list(IN_DOMAIN) + list(OUT_OF_DOMAIN)

_MODELS = {}
_ENDPOINTS = {}


def models(scale):
    if scale.tag not in _MODELS:
        _MODELS[scale.tag] = get_models(scale)
    return _MODELS[scale.tag]


def oracle_llm():
    return routing_lib.OracleLLM(accuracy=1.0, avg_out_tokens=60)


def real_llm(scale):
    """DeepSeek-V3 stand-in: imperfect oracle (difficulty-decaying acc)."""
    return routing_lib.OracleLLM(accuracy=0.98, per_difficulty_decay=0.02,
                                 avg_out_tokens=60, seed=3)


def slm_endpoint(scale, benchmark: str, which: str = "base"):
    """Cached SLM-only endpoint + correctness/out-tokens per benchmark."""
    key = (scale.tag, benchmark, which)
    if key not in _ENDPOINTS:
        slm = make_slm(models(scale)[which], scale)
        items = eval_items(scale, benchmark)
        llm = oracle_llm()
        _ENDPOINTS[key] = routing_lib.slm_only_endpoint(
            slm, items, llm, jax.random.PRNGKey(99), DEFAULT)
    return _ENDPOINTS[key]


def golden_for(scale, benchmark: str):
    (c_s, p_s), slm_corr, slm_out, _ = slm_endpoint(scale, benchmark)
    items = eval_items(scale, benchmark)
    return metrics_lib.golden_toga_100(
        slm_corr, [len(format_prompt(it)) for it in items], slm_out,
        DEFAULT, [60] * len(items))


def save_result(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_result(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
