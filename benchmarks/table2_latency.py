"""Paper Tables 2 & 4: cascade latency — AGL and AROL for SC (base
model), SC/TE (Stage-I only), SC/RCV and SC/FCV (full SATER) at
tau = 0.6 and tau = 1.0."""

from __future__ import annotations

import jax

from benchmarks import common
from repro.core import metrics as metrics_lib
from repro.core import routing as routing_lib
from repro.core.experiment import eval_items, make_slm


SYSTEMS = (
    ("SC", "base", "SC", False),
    ("SC/TE", "stage1", "SC", False),
    ("SC/RCV", "stage2", "RCV", True),
    ("SC/FCV", "stage2", "FCV", True),
)


def run(scale, taus=(0.6, 1.0), k=None, benchmarks=None):
    benchmarks = benchmarks or common.BENCHMARKS
    k = k or scale.k_samples
    llm = common.oracle_llm()
    mdl = common.models(scale)
    table = {}
    for b in benchmarks:
        items = eval_items(scale, b)
        row = {}
        for name, which, mode, early in SYSTEMS:
            slm = make_slm(mdl[which], scale)
            out = routing_lib.cascade_outcomes(
                slm, items, llm, jax.random.PRNGKey(21), mode=mode, k=k,
                thresholds=list(taus), early_stop=early)
            row[name] = {str(t): metrics_lib.outcome_latency(out[t])
                         for t in taus}
        table[b] = row
    return table


def format_table(table, tau) -> str:
    systems = [s[0] for s in SYSTEMS]
    lines = [f"tau={tau}",
             f"{'benchmark':12s} " + " ".join(f"{s:>8s}{'':>7s}" for s in systems),
             f"{'':12s} " + " ".join(f"{'AGL':>8s}{'AROL':>7s}" for _ in systems)]
    for b, row in table.items():
        cells = []
        for s in systems:
            r = row[s][str(tau)]
            cells.append(f"{r['AGL']:8.1f}{r['AROL']:7.1f}")
        lines.append(f"{b:12s} " + " ".join(cells))
    return "\n".join(lines)
