"""Paper Tables 2 & 4: cascade latency — AGL and AROL for SC (base
model), SC/TE (Stage-I only), SC/RCV and SC/FCV (full SATER) at
tau = 0.6 and tau = 1.0.

Also the *compute* counterpart (run_generated / --smoke): the same
cascade streamed through the continuous-batching scheduler with and
without the VoteEarlyStop policy, reporting wall-clock and tokens the
hardware actually decoded — not just the token accounting the paper's
AGL/AROL proxies use."""

from __future__ import annotations

import os

import jax

from benchmarks import common
from repro.core import metrics as metrics_lib
from repro.core import routing as routing_lib
from repro.core.experiment import eval_items, make_slm


SYSTEMS = (
    ("SC", "base", "SC", False),
    ("SC/TE", "stage1", "SC", False),
    ("SC/RCV", "stage2", "RCV", True),
    ("SC/FCV", "stage2", "FCV", True),
)


def run(scale, taus=(0.6, 1.0), k=None, benchmarks=None):
    benchmarks = benchmarks or common.BENCHMARKS
    k = k or scale.k_samples
    llm = common.oracle_llm()
    mdl = common.models(scale)
    table = {}
    for b in benchmarks:
        items = eval_items(scale, b)
        row = {}
        for name, which, mode, early in SYSTEMS:
            slm = make_slm(mdl[which], scale)
            out = routing_lib.cascade_outcomes(
                slm, items, llm, jax.random.PRNGKey(21), mode=mode, k=k,
                thresholds=list(taus), early_stop=early)
            row[name] = {str(t): metrics_lib.outcome_latency(out[t])
                         for t in taus}
        table[b] = row
    return table


def format_table(table, tau) -> str:
    systems = [s[0] for s in SYSTEMS]
    lines = [f"tau={tau}",
             f"{'benchmark':12s} " + " ".join(f"{s:>8s}{'':>7s}" for s in systems),
             f"{'':12s} " + " ".join(f"{'AGL':>8s}{'AROL':>7s}" for _ in systems)]
    for b, row in table.items():
        cells = []
        for s in systems:
            r = row[s][str(tau)]
            cells.append(f"{r['AGL']:8.1f}{r['AROL']:7.1f}")
        lines.append(f"{b:12s} " + " ".join(cells))
    return "\n".join(lines)


# ----------------------------------------------------------------------
# Compute-level latency: tokens actually generated, with/without the
# scheduler's vote-aware early stop
# ----------------------------------------------------------------------

def _param_count(params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


def _generated_row(slm, items, llm, tau: float, k: int, mode: str) -> dict:
    # no_early_stop first: it pays the jit compiles, so the early-stop
    # wall-clock (the headline) is measured warm
    row = {}
    n_params = _param_count(slm.params)
    for name, early in (("no_early_stop", False), ("early_stop", True)):
        rows, stats = routing_lib.cascade_outcomes_streamed(
            slm, items, llm, jax.random.PRNGKey(23), mode=mode, k=k,
            tau=tau, early_stop=early)
        lat = metrics_lib.outcome_latency(rows)
        row[name] = {
            "AGL": lat["AGL"], "AROL": lat["AROL"],
            "generated_tokens": int(stats.generated_tokens),
            "wall_s": stats.wall_s, "rounds": stats.rounds,
            "cancelled_lanes": stats.cancelled,
            # prefill cost: tokens the prefill path really processed (a
            # shared vote group's prompt counts once, not K times) and
            # the ~2*N*T dense-FLOPs proxy per question — the columns
            # where --share-prefix's K-fold cut is visible
            "prefill_tokens": int(stats.prefill_tokens),
            "prefill_prompts": int(stats.prefill_prompts),
            "prefill_flops_per_q": 2.0 * n_params * stats.prefill_tokens
                                   / max(len(items), 1),
            "shared_lanes": int(stats.shared_lanes),
            "cow_copies": int(stats.cow_copies),
            "prefix_hits": int(stats.prefix_hits),
            "prefix_hit_blocks": int(stats.prefix_hit_blocks),
            # K/V footprint: peak bytes actually held vs the dense cache
            # at the same lane count (equal when running dense)
            "peak_cache_bytes": int(stats.peak_cache_bytes),
            "dense_cache_bytes": int(stats.dense_cache_bytes),
            "pool_blocks": int(stats.pool_blocks),
            "peak_blocks_in_use": int(stats.peak_blocks_in_use),
            "admission_blocked": int(stats.admission_blocked),
            # per-round host/device breakdown: host-side scheduling
            # (admission, draft staging, chunk planning), device round
            # dispatch, and harvest (device sync + host bookkeeping)
            "sched_ms": 1e3 * stats.sched_s,
            "dispatch_ms": 1e3 * stats.dispatch_s,
            "harvest_ms": 1e3 * stats.harvest_s,
        }
    full = max(row["no_early_stop"]["generated_tokens"], 1)
    row["generated_cut"] = 1.0 - row["early_stop"]["generated_tokens"] / full
    dense = max(row["early_stop"]["dense_cache_bytes"], 1)
    row["cache_cut"] = 1.0 - row["early_stop"]["peak_cache_bytes"] / dense
    return row


def run_generated(scale, tau: float = 0.6, k=None, mode: str = "FCV",
                  benchmarks=None, which: str = "stage2"):
    """Streamed cascade over the trained SATER model: per benchmark, the
    generated-token and wall-clock cost with and without early stop."""
    benchmarks = benchmarks or common.BENCHMARKS
    k = k or scale.k_samples
    llm = common.oracle_llm()
    slm = make_slm(common.models(scale)[which], scale)
    return {b: _generated_row(slm, eval_items(scale, b), llm, tau, k, mode)
            for b in benchmarks}


def run_generated_smoke(n_items: int = 8, k: int = 8, tau: float = 1.0,
                        mode: str = "FCV", paged: bool = False,
                        block_size: int = 32, share_prefix: bool = False):
    """No-training smoke: an untrained tiny SLM still shows the
    mechanism.  At tau=1.0 (the paper's strict column) the first
    rejected vote already forces routing, so whole groups are killed
    after their first lane completes and the remaining lanes really
    decode fewer tokens.  With ``paged=True`` the same run uses the
    block-paged KV cache, and the cache columns show the peak block
    footprint against the dense cache at the same lane count.  With
    ``share_prefix=True`` on top, each question's K vote lanes are
    prefilled once and share their prompt blocks — the prefill-token
    and prefill-FLOPs columns drop ~K-fold and peak blocks drop further
    at the same lane count."""
    from repro.core.experiment import TINY, model_config
    from repro.models import model as model_lib

    params = model_lib.init_params(model_config(TINY), jax.random.PRNGKey(0))
    slm = make_slm(params, TINY)
    slm.round_tokens = 8       # finer rounds -> earlier kills in the smoke
    slm.paged = paged
    slm.block_size = block_size
    slm.share_prefix = share_prefix
    items = eval_items(TINY, "arith")[:n_items]
    llm = common.oracle_llm()
    return {"arith": _generated_row(slm, items, llm, tau, k, mode)}


# ----------------------------------------------------------------------
# Chunked prefill under streaming arrivals: ttft tail vs whole-prompt
# ----------------------------------------------------------------------

def run_chunked_smoke(n_requests: int = 40, n_long: int = 1,
                      lanes: int = 4, round_tokens: int = 4,
                      chunk_size: int = 256, prefill_budget: int = 320,
                      long_repeat: int = 17, new_tokens: int = 8,
                      arrivals_per_round: float = 2.0, seed: int = 0):
    """No-training smoke for chunked prefill: the same arrival stream
    served twice — whole-prompt prefill vs chunked prefill at a
    per-round token budget — reporting the per-request wall-clock ttft
    distribution.

    The workload is mostly short arith prompts with ``n_long`` requests
    carrying a fat instruction header (~1,900 tokens).  With
    whole-prompt prefill, an admission wave runs its prompts' entire
    prefill between two decode rounds — the long prompt head-of-line
    blocks every request in flight or admitted alongside, and the
    multi-second stall lands directly in those requests' ttft.
    Chunked, the same prompt streams through ``chunk_size``-token
    chunks under the per-round ``prefill_budget`` with round-robin
    fairness: the budget is priced in *real* prompt tokens, so every
    short prompt's single chunk rides along in the same pass and only
    the long request pays for its own length.

    Arrivals are Poisson in *round index* (exponential gaps at
    ``arrivals_per_round``, submitted just before the round they land
    on): wave composition is then identical run to run and path to
    path, so the comparison is structural — the whole-prefill stall vs
    the chunked budget — rather than a wall-clock feedback loop, while
    ttft is still measured in wall seconds and captures the stall.

    Completions are bit-identical between the two paths (the
    per-request PRNG contract makes generation independent of admission
    timing — tests/test_serving_trace.py), so generated tokens and
    accuracy are equal BY CONSTRUCTION and the comparison isolates pure
    serving latency.  Each path runs twice (first pass pays the jit
    compiles) and reports the min of its two ttft percentiles; the CI
    gate (scripts/check_bench_regression.py) requires equal
    tokens/accuracy and the chunked ttft p95 strictly below the
    whole-prefill one.
    """
    import time

    import numpy as np

    from repro.core.experiment import LLM_SCALE, model_config
    from repro.data.tasks import is_correct, make_benchmark
    from repro.data.tokenizer import default_tokenizer
    from repro.models import model as model_lib
    from repro.serving.batch import GenConfig
    from repro.serving.scheduler import Request, Scheduler

    tok = default_tokenizer()
    # the larger local scale (d256 x 6L): a ~1,900-token whole prefill
    # is a real multi-second stall on the CPU host while decode rounds
    # stay cheap — the regime chunked prefill exists for (the tiny SLM's
    # prefill is so fast the stall drowns in dispatch overhead)
    cfg = model_config(LLM_SCALE)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    items = make_benchmark("arith", n_requests, seed=seed)
    header = ("You are a careful assistant. Think step by step, check "
              "every intermediate result twice, and answer concisely. ")
    rng = np.random.RandomState(seed)
    long_ids = set(rng.choice(n_requests, n_long, replace=False).tolist())
    reqs, max_len = [], 0
    for i, item in enumerate(items):
        prompt = f"Q: {item.question}\nA: "
        if i in long_ids:
            prompt = header * long_repeat + prompt
        toks = tok.encode(prompt, bos=True)
        max_len = max(max_len, len(toks))
        reqs.append(Request(uid=i, tokens=toks))
    arrival_round = np.floor(np.cumsum(
        rng.exponential(1.0 / arrivals_per_round, n_requests))).astype(int)
    gcfg = GenConfig(max_new_tokens=new_tokens, temperature=0.0)

    def serve(chunked: bool):
        # dense lane cache: on the CPU host the paged decode gather
        # materializes a per-layer (lanes, s_max) K/V view each step,
        # which dominates round time at this prompt length and buries
        # the prefill stall the smoke exists to measure
        sched = Scheduler(
            params, cfg, tok, gcfg, n_lanes=lanes,
            round_tokens=round_tokens, max_prompt_len=max_len,
            chunk_size=chunk_size if chunked else None,
            prefill_budget=prefill_budget if chunked else None)
        best = None
        for _ in range(2):           # first pass pays compiles; min-of-2
            loop = sched.loop(jax.random.PRNGKey(5))
            comps = []
            t0 = time.time()
            nxt = 0
            r = 0
            while nxt < n_requests or loop.has_work:
                while nxt < n_requests and arrival_round[nxt] <= r:
                    loop.submit([reqs[nxt]])
                    nxt += 1
                comps.extend(loop.step())
                r += 1
            wall = time.time() - t0
            stats = loop.close()
            ttft = [c.ttft_s for c in comps if c.ttft_s is not None]
            acc = float(np.mean([is_correct(items[c.uid],
                                            tok.decode(c.tokens))
                                 for c in comps]))
            row = {
                "wall_s": wall,
                "rounds": int(stats.rounds),
                "generated_tokens": int(stats.generated_tokens),
                "prefill_tokens": int(stats.prefill_tokens),
                "prefill_chunks": int(stats.prefill_chunks),
                "accuracy": acc,
                "gen_lens": sorted(int(c.gen_len) for c in comps),
                "ttft_mean_s": float(np.mean(ttft)),
                "ttft_p50_s": float(np.percentile(ttft, 50)),
                "ttft_p95_s": float(np.percentile(ttft, 95)),
            }
            if best is None or row["ttft_p95_s"] < best["ttft_p95_s"]:
                best = row
        return best

    whole = serve(chunked=False)
    chunked = serve(chunked=True)
    gen_equal = whole.pop("gen_lens") == chunked.pop("gen_lens")
    return {"serve": {
        "whole": whole,
        "chunked": chunked,
        "n_requests": n_requests,
        "n_long": n_long,
        "arrivals_per_round": arrivals_per_round,
        "ttft_p95_cut": 1.0 - chunked["ttft_p95_s"]
                        / max(whole["ttft_p95_s"], 1e-9),
        "equal_tokens": bool(
            gen_equal
            and whole["generated_tokens"] == chunked["generated_tokens"]),
        "equal_accuracy": bool(whole["accuracy"] == chunked["accuracy"]),
        "ttft_win": bool(chunked["ttft_p95_s"] < whole["ttft_p95_s"]),
    }}


def format_chunked(table) -> str:
    lines = ["chunked prefill vs whole-prompt prefill (Poisson arrivals)",
             f"{'':12s} {'ttft-mean':>10s} {'ttft-p50':>9s} {'ttft-p95':>9s} "
             f"{'wall':>7s} {'rounds':>7s} {'prefill':>8s} {'acc':>5s}"]
    row = table["serve"]
    for name in ("whole", "chunked"):
        r = row[name]
        lines.append(
            f"{name:12s} {r['ttft_mean_s'] * 1e3:8.0f}ms "
            f"{r['ttft_p50_s'] * 1e3:7.0f}ms {r['ttft_p95_s'] * 1e3:7.0f}ms "
            f"{r['wall_s']:6.2f}s {r['rounds']:7d} "
            f"{r['prefill_tokens']:8d} {r['accuracy']:5.2f}")
    lines.append(f"ttft p95 cut: {row['ttft_p95_cut']:.0%}  "
                 f"equal tokens: {row['equal_tokens']}  "
                 f"equal accuracy: {row['equal_accuracy']}")
    return "\n".join(lines)


# ----------------------------------------------------------------------
# Preemption with host KV offload: tiny pool, three serving paths
# ----------------------------------------------------------------------

def run_preempt_smoke(n_requests: int = 12, lanes: int = 4,
                      round_tokens: int = 8, block_size: int = 8,
                      new_tokens: int = 16, arrivals_per_round: float = 1.5,
                      seed: int = 0):
    """No-training smoke for block-granular preemption with host
    offload: one deterministic round-indexed arrival stream served
    three ways —

      * ``ample``      — pool sized for every lane (the reference: no
        memory pressure, completions are the ground truth);
      * ``no_offload`` — a pool holding only two worst-case lanes,
        ``auto_preempt`` off: admission can only wait for lanes to
        finish, so blocked-admission rounds pile up;
      * ``preempt``    — the same tiny pool with ``auto_preempt`` on:
        admission pressure evicts the coldest preemptible lane's KV
        blocks to host RAM and re-admits it when blocks free.

    The per-request PRNG contract (tests/test_serving_trace.py) makes
    all three paths' completions bit-identical BY CONSTRUCTION — the
    tiny pool changes *when* requests run, never what they generate —
    so the gate (scripts/check_bench_regression.py) requires exact
    token equality against the ample reference, at least one full
    offload/resume cycle, and strictly fewer blocked-admission events
    than the no-offload path.  Arrivals are Poisson in round index
    (identical stream per path); each path runs twice (first pass pays
    the jit compiles) and reports min wall-clock with counters from the
    second pass.
    """
    import time

    import numpy as np

    from repro.core.experiment import TINY, model_config
    from repro.data.tasks import make_benchmark
    from repro.data.tokenizer import default_tokenizer
    from repro.models import model as model_lib
    from repro.serving.batch import GenConfig
    from repro.serving.scheduler import Request, Scheduler

    tok = default_tokenizer()
    cfg = model_config(TINY)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    items = make_benchmark("arith", n_requests, seed=seed)
    rng = np.random.RandomState(seed)
    reqs, max_len = [], 0
    for i, item in enumerate(items):
        toks = tok.encode(f"Q: {item.question}\nA: ", bos=True)
        max_len = max(max_len, len(toks))
        reqs.append(Request(uid=i, tokens=toks))
    arrival_round = np.floor(np.cumsum(
        rng.exponential(1.0 / arrivals_per_round, n_requests))).astype(int)
    gcfg = GenConfig(max_new_tokens=new_tokens, temperature=0.7)
    max_blocks = -(-(max_len + new_tokens) // block_size)
    tiny_pool = 2 * max_blocks          # two worst-case lanes of four

    def serve(pool_blocks, auto_preempt):
        sched = Scheduler(
            params, cfg, tok, gcfg, n_lanes=lanes,
            round_tokens=round_tokens, max_prompt_len=max_len,
            paged=True, block_size=block_size, pool_blocks=pool_blocks,
            auto_preempt=auto_preempt)
        best_wall = None
        for _ in range(2):           # first pass pays compiles; min-of-2
            loop = sched.loop(jax.random.PRNGKey(5))
            comps = []
            t0 = time.time()
            nxt = 0
            r = 0
            while nxt < n_requests or loop.has_work:
                while nxt < n_requests and arrival_round[nxt] <= r:
                    loop.submit([reqs[nxt]])
                    nxt += 1
                comps.extend(loop.step())
                r += 1
            wall = time.time() - t0
            stats = loop.close()
            assert sched.pool.leak_report() is None
            best_wall = wall if best_wall is None else min(best_wall, wall)
        # counters are deterministic across passes; only wall varies
        return {
            "wall_s": best_wall,
            "rounds": int(stats.rounds),
            "generated_tokens": int(stats.generated_tokens),
            "admission_blocked": int(stats.admission_blocked),
            "preempts": int(stats.preempts),
            "resumes": int(stats.resumes),
            "offload_bytes": int(stats.offload_bytes),
            "host_blocks_peak": int(stats.host_blocks_peak),
            "pool_blocks": int(sched.pool_blocks),
            "tokens": {str(c.uid): [int(t) for t in c.tokens]
                       for c in comps},
        }

    ample = serve(None, False)
    no_offload = serve(tiny_pool, False)
    preempt = serve(tiny_pool, True)
    ref = ample.pop("tokens")
    bitequal = (no_offload.pop("tokens") == ref
                and preempt.pop("tokens") == ref)
    return {"arith": {
        "ample": ample,
        "no_offload": no_offload,
        "preempt": preempt,
        "n_requests": n_requests,
        "arrivals_per_round": arrivals_per_round,
        "completions_bitequal": bool(bitequal),
        "admission_blocked_cut":
            1.0 - preempt["admission_blocked"]
            / max(no_offload["admission_blocked"], 1e-9),
    }}


def format_preempt(table) -> str:
    row = table["arith"]
    lines = ["preemption + host KV offload under a 2-lane pool "
             "(Poisson arrivals)",
             f"{'':12s} {'wall':>7s} {'rounds':>7s} {'blocked':>8s} "
             f"{'preempts':>9s} {'resumes':>8s} {'host-peak':>10s} "
             f"{'offload':>9s}"]
    for name in ("ample", "no_offload", "preempt"):
        r = row[name]
        lines.append(
            f"{name:12s} {r['wall_s']:6.2f}s {r['rounds']:7d} "
            f"{r['admission_blocked']:8d} {r['preempts']:9d} "
            f"{r['resumes']:8d} {r['host_blocks_peak']:10d} "
            f"{r['offload_bytes'] / 1024:7.0f}KiB")
    lines.append(
        f"completions bit-equal: {row['completions_bitequal']}  "
        f"admission-blocked cut: {row['admission_blocked_cut']:.0%}")
    return "\n".join(lines)


# ----------------------------------------------------------------------
# Quantized serving tier: int8 paged KV (+ int8 weights) vs fp32
# ----------------------------------------------------------------------

def run_quant_smoke(n_requests: int = 12, round_tokens: int = 8,
                    block_size: int = 8, new_tokens: int = 16,
                    max_prompt_len: int = 64, seed: int = 0):
    """No-training smoke for the quantized serving tier: the same
    request stream served twice through the paged scheduler at an
    *equal lane count* —

      * ``fp32`` — the reference tier (fp weights, fp KV pages);
      * ``int8`` — the quantized tier built through the exact SLM knobs
        a cascade would use (``kv_quant=True`` for int8 KV pages with
        per-(slot, head) f32 scales, ``quantize="int8"`` for
        round-tripped int8 weights), via ``routing.make_scheduler``.

    Because the lane count and cache geometry are identical, the HBM
    story reduces to bytes per cached slot: fp32 pays
    ``2 * KV * dh * 4`` bytes while int8 pays ``2 * KV * dh + 2 * KV *
    4`` (values + scales), so ``lanes_per_byte_gain`` — how many more
    lanes one HBM byte budget could hold — is the deterministic ratio
    of the two dense-equivalent footprints, and ``kv_bytes_cut`` is
    the same story as a fraction of the fp32 peak.  Quantized decoding
    is *not* bit-equal to fp32 (that is the point of the gate's
    tolerance mode): the smoke reports mean token-prefix agreement and
    both accuracies, and the gate (scripts/check_bench_regression.py
    ``check_quant_invariants``) requires the int8 accuracy within a
    relative ``--tol`` of fp32, the int8 footprint strictly below, and
    the gain over its floor.  Each path runs twice (first pass pays
    the jit compiles) and reports min wall-clock."""
    import time

    import numpy as np

    from repro.core.experiment import TINY, model_config
    from repro.core.routing import make_scheduler
    from repro.data.tasks import is_correct, make_benchmark
    from repro.models import model as model_lib
    from repro.serving.batch import GenConfig
    from repro.serving.scheduler import Request

    cfg = model_config(TINY)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    items = make_benchmark("arith", n_requests, seed=seed)
    reqs = [Request(uid=i, prompt=f"Q: {item.question}\nA: ")
            for i, item in enumerate(items)]
    gcfg = GenConfig(max_new_tokens=new_tokens, temperature=0.7, top_p=1.0)

    def serve(quant: bool):
        slm = make_slm(params, TINY)
        slm.gcfg = gcfg
        slm.round_tokens = round_tokens
        slm.max_prompt_len = max_prompt_len
        slm.paged = True
        slm.block_size = block_size
        if quant:
            slm.kv_quant = True
            slm.quantize = "int8"
        sched = make_scheduler(slm, n_requests)
        best_wall, comps, stats = None, None, None
        for _ in range(2):           # first pass pays compiles; min-of-2
            loop = sched.loop(jax.random.PRNGKey(5))
            loop.submit([Request(**vars(r)) for r in reqs])
            t0 = time.time()
            comps = loop.drain()
            wall = time.time() - t0
            stats = loop.close()
            assert sched.pool.leak_report() is None
            best_wall = wall if best_wall is None else min(best_wall, wall)
        tok = slm.tokenizer
        acc = float(np.mean([is_correct(items[c.uid], tok.decode(c.tokens))
                             for c in comps]))
        return {
            "wall_s": best_wall,
            "rounds": int(stats.rounds),
            "generated_tokens": int(stats.generated_tokens),
            "n_lanes": int(sched.n_lanes),
            "pool_blocks": int(stats.pool_blocks),
            "peak_blocks_in_use": int(stats.peak_blocks_in_use),
            "peak_cache_bytes": int(stats.peak_cache_bytes),
            "dense_cache_bytes": int(stats.dense_cache_bytes),
            "accuracy": acc,
            "tokens": {str(c.uid): [int(t) for t in c.tokens]
                       for c in comps},
        }

    fp32 = serve(False)
    int8 = serve(True)
    fp_toks, q_toks = fp32.pop("tokens"), int8.pop("tokens")

    def prefix_agreement(got, want):
        if not want:
            return 1.0
        n = 0
        for a, b in zip(got, want):
            if a != b:
                break
            n += 1
        return n / len(want)

    agreement = float(np.mean([prefix_agreement(q_toks[u], fp_toks[u])
                               for u in fp_toks]))
    return {"arith": {
        "fp32": fp32,
        "int8": int8,
        "n_requests": n_requests,
        "equal_lanes": bool(fp32["n_lanes"] == int8["n_lanes"]),
        # deterministic geometry ratio: bytes per cached slot at equal
        # lane count (fp32 values vs int8 values + f32 scales)
        "lanes_per_byte_gain": fp32["dense_cache_bytes"]
                               / max(int8["dense_cache_bytes"], 1),
        "kv_bytes_cut": 1.0 - int8["peak_cache_bytes"]
                        / max(fp32["peak_cache_bytes"], 1e-9),
        "token_agreement": agreement,
    }}


def format_quant(table) -> str:
    row = table["arith"]
    lines = ["quantized serving tier: int8 paged KV + int8 weights vs fp32 "
             "(equal lanes)",
             f"{'':8s} {'wall':>7s} {'rounds':>7s} {'gen':>6s} {'acc':>5s} "
             f"{'peak-KV':>9s} {'dense-eq':>9s} {'blocks':>7s}"]
    for name in ("fp32", "int8"):
        r = row[name]
        lines.append(
            f"{name:8s} {r['wall_s']:6.2f}s {r['rounds']:7d} "
            f"{r['generated_tokens']:6d} {r['accuracy']:5.2f} "
            f"{r['peak_cache_bytes'] / 1024:7.1f}Ki "
            f"{r['dense_cache_bytes'] / 1024:7.1f}Ki "
            f"{r['peak_blocks_in_use']:7d}")
    lines.append(
        f"lanes/HBM-byte gain: {row['lanes_per_byte_gain']:.2f}x  "
        f"peak-KV cut: {row['kv_bytes_cut']:.0%}  "
        f"token agreement: {row['token_agreement']:.0%}")
    return "\n".join(lines)


# ----------------------------------------------------------------------
# Pipelined multi-tier cascade: barrier tiers vs mid-flight escalation
# ----------------------------------------------------------------------

UNREACHABLE_TAU = 1.01   # vote share is <= 1.0: acceptance impossible


def run_pipeline_smoke(n_items: int = 12, k: int = 4,
                       tau: float = UNREACHABLE_TAU,
                       lane_budget: int = 16, round_tokens: int = 8):
    """No-training smoke for cascade pipelining: two SATER-shaped tiers
    (one untrained tiny SLM shared by both — the repo's multi-tier
    example reuses one model with different policies) in front of an
    oracle terminal, once as sequential barriers
    (``run_cascade(stream_early_stop=True)``) and once pipelined
    (``run_cascade_pipelined``: a rejected question's next-tier vote
    group is submitted the moment VoteEarlyStop decides, filling lanes
    the barrier path would leave idle in its per-tier ramp/drain).

    The default tau is ``UNREACHABLE_TAU`` (> 1): the confidence-vote
    share can never exceed 1.0, so acceptance is impossible *by
    construction* — not just improbable for an untrained model — and
    every question routes to the terminal in both paths regardless of
    which tokens get sampled.  That makes the CI gate's
    ``equal_accuracy`` invariant deterministic while keeping sampled
    decoding (temperature 0.7), whose ragged EOS times are exactly
    what gives the pipelined path lanes to backfill (greedy decoding
    on the untrained model never samples EOS, every lane runs to the
    same budget, and both paths pack perfectly).  Each group still
    exercises ``VoteEarlyStop`` fully: its first finished lane proves
    the vote unreachable and kills the rest mid-flight.  The
    comparison therefore isolates serving efficiency: the pipelined
    path must win on decode
    *rounds* (a deterministic packing win, not a timing artifact) and
    therefore on wall-clock.  Each path runs twice and the best (min)
    wall of its two passes is reported — the first pass also pays the
    jit compiles, and min-of-2 keeps the CI gate's strict
    wall(pipe) < wall(seq) check out of reach of runner noise.
    """
    import time

    import numpy as np

    from repro.core import cascade_multi as cm
    from repro.core.experiment import TINY, model_config
    from repro.models import model as model_lib

    params = model_lib.init_params(model_config(TINY), jax.random.PRNGKey(0))
    slm = make_slm(params, TINY)
    slm.round_tokens = round_tokens
    slm.lane_budget = lane_budget
    items = eval_items(TINY, "arith")[:n_items]
    tiers = [cm.Tier(slm=slm, tau=tau, mode="FCV", k=k),
             cm.Tier(slm=slm, tau=tau, mode="FCV", k=k)]
    terminal = cm.TerminalTier(llm=common.oracle_llm())
    key = jax.random.PRNGKey(5)

    walls_seq, walls_pipe = [], []
    for _ in range(2):             # first pass pays compiles; min-of-2
        t0 = time.time()
        out_seq, tier_stats = cm.run_cascade(tiers, terminal, items, key,
                                             stream_early_stop=True,
                                             return_stats=True)
        walls_seq.append(time.time() - t0)
    for _ in range(2):
        out_pipe, ps = cm.run_cascade_pipelined(tiers, terminal, items, key)
        walls_pipe.append(ps.wall_s)
    wall_seq, wall_pipe = min(walls_seq), min(walls_pipe)
    s_seq = cm.summarize(out_seq, len(tiers))
    s_pipe = cm.summarize(out_pipe, len(tiers))
    seq_rounds = sum(s.rounds for s in tier_stats if s is not None)
    seq_gen = sum(s.generated_tokens for s in tier_stats if s is not None)
    return {"arith": {
        "sequential": {
            "wall_s": wall_seq,
            "rounds": int(seq_rounds),
            "generated_tokens": int(seq_gen),
            "accuracy": s_seq["accuracy"],
            "tier_histogram": s_seq["tier_histogram"],
        },
        "pipelined": {
            "wall_s": wall_pipe,
            "rounds": int(ps.rounds),
            "generated_tokens": int(ps.generated_tokens),
            "accuracy": s_pipe["accuracy"],
            "tier_histogram": s_pipe["tier_histogram"],
            "overlap_fraction": ps.overlap_fraction,
            "host_iters": int(ps.host_iters),
            "fused_loops": int(ps.fused_loops),
            "escalated": ps.escalated,
            "ttd_mean_s": float(np.mean(ps.ttd_s)) if ps.ttd_s else 0.0,
            "ttd_p95_s": float(np.percentile(ps.ttd_s, 95))
                         if ps.ttd_s else 0.0,
        },
        "speedup": wall_seq / max(wall_pipe, 1e-9),
        "rounds_cut": 1.0 - ps.rounds / max(seq_rounds, 1),
        "equal_accuracy": bool(
            s_seq["accuracy"] == s_pipe["accuracy"]
            and s_seq["tier_histogram"] == s_pipe["tier_histogram"]),
    }}


# ----------------------------------------------------------------------
# Sharded serving: lane scaling over a simulated mesh + tier placement
# ----------------------------------------------------------------------

def run_sharded_smoke(devices: int = 4, lanes_per_device: int = 4,
                      n_requests: int = 24, round_tokens: int = 8,
                      block_size: int = 8, new_tokens: int = 16,
                      n_items_placement: int = 8, k: int = 4,
                      seed: int = 0):
    """No-training smoke for multi-device sharded serving, two phases
    on a simulated ``devices``-wide host mesh
    (``--xla_force_host_platform_device_count`` — no accelerator
    involved, so this runs CI-gated on CPU).

    **Lane scaling**: the same request stream served paged at a fixed
    ``lanes_per_device``, once single-device (no mesh) and once with
    the lane dim and per-shard KV pools sharded over the mesh's data
    axis (``Scheduler(mesh=...)``: decode rounds under shard_map, one
    block-pool slab per shard, no cross-shard gathers on the decode hot
    path).  The per-request PRNG contract makes completions bit-equal
    BY CONSTRUCTION — shard placement is pure layout — so the gate
    (scripts/check_bench_regression.py) requires exact token equality
    plus an aggregate lane count >= 3x the single-device run.
    Per-device tokens/sec and scaling efficiency are *reported*, not
    gated: simulated CPU devices share one host's cores, so efficiency
    on this rig measures sharding overhead, not real scaling.

    **Tier placement**: the two-tier cascade of ``run_pipeline_smoke``
    (one SLM, tau unreachable, sampled decoding) with ``placement``
    pinning tier 0 to the first half of the mesh's devices and the
    escalation tier to the second half — run once as per-tier barriers
    (``run_cascade``: the slices run back-to-back, the *serialized*
    placement baseline) and once pipelined
    (``run_cascade_pipelined``: escalated groups decode on their slice
    while tier 0 keeps decoding on its own).  The gate always requires
    equal accuracy/tier histogram, ``n_loops == 2`` (disjoint slices
    deliberately un-fuse the host loop) and ``overlap_fraction > 0``
    (host iterations where BOTH slices had rounds in flight — the
    escalation tier decoding concurrently with tier 0, not merely
    interleaved).  The *wall* gate — pipelined strictly below the
    serialized placement — additionally arms only when the host has
    >= 2 CPU cores (``wall_gate_armed``): simulated devices timeshare
    the host's cores, so on a single-core rig both placements do
    identical total compute and wall parity is the physical ceiling;
    with two or more cores the two slices' XLA executions genuinely
    run in parallel and the concurrent placement must win.  Each serve
    runs twice (first pass pays the jit compiles) and reports min wall.
    """
    import time

    import numpy as np

    from repro.core import cascade_multi as cm
    from repro.core.experiment import TINY, model_config
    from repro.data.tasks import make_benchmark
    from repro.data.tokenizer import default_tokenizer
    from repro.launch.mesh import make_sim_mesh
    from repro.models import model as model_lib
    from repro.serving.batch import GenConfig
    from repro.serving.scheduler import Request, Scheduler

    tok = default_tokenizer()
    cfg = model_config(TINY)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    items = make_benchmark("arith", n_requests, seed=seed)
    reqs, max_len = [], 0
    for i, item in enumerate(items):
        toks = tok.encode(f"Q: {item.question}\nA: ", bos=True)
        max_len = max(max_len, len(toks))
        reqs.append(Request(uid=i, tokens=toks))
    gcfg = GenConfig(max_new_tokens=new_tokens, temperature=0.7)

    def serve(mesh, n_lanes, n_devices):
        sched = Scheduler(params, cfg, tok, gcfg, n_lanes=n_lanes,
                          round_tokens=round_tokens, max_prompt_len=max_len,
                          paged=True, block_size=block_size, mesh=mesh)
        best_wall, comps, stats = None, None, None
        for _ in range(2):           # first pass pays compiles; min-of-2
            loop = sched.loop(jax.random.PRNGKey(5))
            loop.submit(reqs)
            t0 = time.time()
            comps = loop.drain()
            wall = time.time() - t0
            stats = loop.close()
            assert stats.leak_report is None
            best_wall = wall if best_wall is None else min(best_wall, wall)
        gen = int(stats.generated_tokens)
        return {
            "wall_s": best_wall,
            "rounds": int(stats.rounds),
            "generated_tokens": gen,
            "n_lanes": n_lanes,
            "n_devices": n_devices,
            "aggregate_tok_s": gen / max(best_wall, 1e-9),
            "tok_s_per_device": gen / max(best_wall, 1e-9) / n_devices,
        }, {str(c.uid): [int(t) for t in c.tokens] for c in comps}

    single, toks_1 = serve(None, lanes_per_device, 1)
    sharded, toks_n = serve(make_sim_mesh(devices),
                            lanes_per_device * devices, devices)
    scaling = {
        "single": single,
        "sharded": sharded,
        "lane_scale": sharded["n_lanes"] / single["n_lanes"],
        "scaling_efficiency": sharded["aggregate_tok_s"]
                              / max(single["aggregate_tok_s"], 1e-9)
                              / devices,
        "completions_bitequal": bool(toks_n == toks_1),
    }

    # --- tier placement: serialized slices vs concurrent slices ------
    slm = make_slm(params, TINY)
    slm.round_tokens = round_tokens
    slm.lane_budget = 4 * lanes_per_device
    p_items = eval_items(TINY, "arith")[:n_items_placement]
    tiers = [cm.Tier(slm=slm, tau=UNREACHABLE_TAU, mode="FCV", k=k),
             cm.Tier(slm=slm, tau=UNREACHABLE_TAU, mode="FCV", k=k)]
    terminal = cm.TerminalTier(llm=common.oracle_llm())
    key = jax.random.PRNGKey(5)
    half = devices // 2
    devs = jax.devices()
    placement = {0: devs[:half], 1: devs[half:devices]}

    walls_seq, walls_pipe = [], []
    for _ in range(2):             # first pass pays compiles; min-of-2
        t0 = time.time()
        out_seq, tier_stats = cm.run_cascade(tiers, terminal, p_items, key,
                                             stream_early_stop=True,
                                             return_stats=True,
                                             placement=placement)
        walls_seq.append(time.time() - t0)
    for _ in range(2):
        out_pipe, ps = cm.run_cascade_pipelined(tiers, terminal, p_items,
                                                key, placement=placement)
        walls_pipe.append(ps.wall_s)
    wall_seq, wall_pipe = min(walls_seq), min(walls_pipe)
    s_seq = cm.summarize(out_seq, len(tiers))
    s_pipe = cm.summarize(out_pipe, len(tiers))
    seq_rounds = sum(s.rounds for s in tier_stats if s is not None)
    placement_row = {
        "sequential": {
            "wall_s": wall_seq,
            "rounds": int(seq_rounds),
            "accuracy": s_seq["accuracy"],
            "tier_histogram": s_seq["tier_histogram"],
        },
        "pipelined": {
            "wall_s": wall_pipe,
            "rounds": int(ps.rounds),
            "accuracy": s_pipe["accuracy"],
            "tier_histogram": s_pipe["tier_histogram"],
            "overlap_fraction": ps.overlap_fraction,
            "n_loops": int(ps.n_loops),
        },
        "speedup": wall_seq / max(wall_pipe, 1e-9),
        "rounds_cut": 1.0 - ps.rounds / max(seq_rounds, 1),
        "tier_devices": [half, devices - half],
        "equal_accuracy": bool(
            s_seq["accuracy"] == s_pipe["accuracy"]
            and s_seq["tier_histogram"] == s_pipe["tier_histogram"]),
        "host_cores": int(os.cpu_count() or 1),
        "wall_gate_armed": bool((os.cpu_count() or 1) >= 2),
    }
    return {"arith": {"scaling": scaling, "placement": placement_row}}


def format_sharded(table, devices: int) -> str:
    row = table["arith"]
    sc, pl = row["scaling"], row["placement"]
    lines = [f"sharded serving on {devices} simulated devices",
             f"{'':12s} {'devices':>8s} {'lanes':>6s} {'wall':>7s} "
             f"{'rounds':>7s} {'gen':>6s} {'tok/s/dev':>10s} "
             f"{'agg tok/s':>10s}"]
    for name in ("single", "sharded"):
        r = sc[name]
        lines.append(
            f"{name:12s} {r['n_devices']:8d} {r['n_lanes']:6d} "
            f"{r['wall_s']:6.2f}s {r['rounds']:7d} "
            f"{r['generated_tokens']:6d} {r['tok_s_per_device']:10.1f} "
            f"{r['aggregate_tok_s']:10.1f}")
    lines.append(
        f"lane scale: {sc['lane_scale']:.1f}x  scaling efficiency: "
        f"{sc['scaling_efficiency']:.0%}  completions bit-equal: "
        f"{sc['completions_bitequal']}")
    seq, pipe = pl["sequential"], pl["pipelined"]
    lines.append(
        f"tier placement ({pl['tier_devices'][0]}+{pl['tier_devices'][1]} "
        f"devices): serialized {seq['wall_s']:.2f}s / {seq['rounds']} "
        f"rounds vs concurrent {pipe['wall_s']:.2f}s / {pipe['rounds']} "
        f"rounds  speedup {pl['speedup']:.2f}x"
        f"{'' if pl['wall_gate_armed'] else ' (wall gate unarmed: 1 core)'}"
        f"  overlap {pipe['overlap_fraction']:.0%}  acc= "
        f"{'yes' if pl['equal_accuracy'] else 'NO'}")
    return "\n".join(lines)


# ----------------------------------------------------------------------
# Heterogeneous cascade: a recurrent (mamba2-style) tier escalating to
# a transformer tier, each loop on its own cache protocol
# ----------------------------------------------------------------------

def run_hetero_smoke(n_items: int = 8, k: int = 4,
                     tau: float = UNREACHABLE_TAU, lane_budget: int = 8,
                     round_tokens: int = 8, new_tokens: int = 24,
                     block_size: int = 8):
    """No-training smoke for mixed-architecture cascading: tier 0 is a
    tiny mamba2-style *pure-SSM* model served paged under the
    state-slot protocol (a constant-size conv + SSD state slot per
    lane — no KV blocks at all), tier 1 the TINY dense transformer on
    block-paged KV.  The tiers are distinct SLMs, so the pipelined
    driver opens one serving loop per architecture — two lane pools,
    two cache protocols, interleaved in one split-phase host loop.

    Both tiers use ``UNREACHABLE_TAU``: acceptance is impossible by
    construction, so every question runs the SSM tier's vote lanes,
    escalates to the transformer tier, and lands on the oracle
    terminal — making the accuracy/tier-histogram equality gate
    against the per-tier barrier path
    (``run_cascade(stream_early_stop=True)``) deterministic under
    sampled decoding, exactly as in ``run_pipeline_smoke``.

    The gated invariants (scripts/check_bench_regression.py) are the
    protocol split itself: ``n_loops == 2`` (distinct cache protocols
    cannot fuse onto one lane pool), the SSM tier's state-slot pool
    saturating at its cap with ``peak_state_bytes`` equal to
    ``peak_state_slots * state_slot_bytes`` (recurrent state is O(1)
    per lane — the pool never grows the way a KV block table does),
    the transformer tier holding zero state slots, and every loop
    draining leak-clean.
    """
    import time

    from repro.configs.base import ModelConfig
    from repro.core import cascade_multi as cm
    from repro.core.experiment import TINY, model_config
    from repro.data.tokenizer import default_tokenizer
    from repro.models import model as model_lib
    from repro.serving.batch import GenConfig

    tok = default_tokenizer()
    ssm_cfg = ModelConfig(
        name="smoke-mamba2", arch_type="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, head_dim=0, d_ff=192, ssm_state=16,
        ssm_head_dim=32, ssm_chunk=16, vocab_size=tok.vocab_size,
        remat=False, source="hetero smoke: recurrent tier-0")
    ssm_params = model_lib.init_params(ssm_cfg, jax.random.PRNGKey(1))
    gcfg = GenConfig(max_new_tokens=new_tokens, temperature=0.7, top_p=1.0)
    ssm_slm = routing_lib.SLM(ssm_params, ssm_cfg, tok, gcfg,
                              max_prompt_len=TINY.max_len,
                              lane_budget=lane_budget, paged=True,
                              round_tokens=round_tokens)
    attn_params = model_lib.init_params(model_config(TINY),
                                        jax.random.PRNGKey(0))
    attn_slm = make_slm(attn_params, TINY)
    attn_slm.round_tokens = round_tokens
    attn_slm.lane_budget = lane_budget
    attn_slm.paged = True
    attn_slm.block_size = block_size
    attn_slm.gcfg = gcfg

    items = eval_items(TINY, "arith")[:n_items]
    tiers = [cm.Tier(slm=ssm_slm, tau=tau, mode="FCV", k=k),
             cm.Tier(slm=attn_slm, tau=tau, mode="FCV", k=k)]
    terminal = cm.TerminalTier(llm=common.oracle_llm())
    key = jax.random.PRNGKey(5)

    walls_seq, walls_pipe = [], []
    for _ in range(2):             # first pass pays compiles; min-of-2
        t0 = time.time()
        out_seq, tier_stats = cm.run_cascade(tiers, terminal, items, key,
                                             stream_early_stop=True,
                                             return_stats=True)
        walls_seq.append(time.time() - t0)
    for _ in range(2):
        out_pipe, ps = cm.run_cascade_pipelined(tiers, terminal, items, key)
        walls_pipe.append(ps.wall_s)
    wall_seq, wall_pipe = min(walls_seq), min(walls_pipe)
    s_seq = cm.summarize(out_seq, len(tiers))
    s_pipe = cm.summarize(out_pipe, len(tiers))
    seq_rounds = sum(s.rounds for s in tier_stats if s is not None)

    # loops follow tier order: loop 0 serves the SSM tier, loop 1 the
    # transformer tier (distinct SLMs never fuse)
    ssm_st, attn_st = ps.loop_stats

    def tier_row(st):
        return {
            "rounds": int(st.rounds),
            "generated_tokens": int(st.generated_tokens),
            "state_slots": int(st.state_slots),
            "peak_state_slots": int(st.peak_state_slots),
            "state_slot_bytes": int(st.state_slot_bytes),
            "peak_state_bytes": int(st.peak_state_bytes),
            "peak_blocks_in_use": int(st.peak_blocks_in_use),
        }

    return {"arith": {
        "sequential": {
            "wall_s": wall_seq,
            "rounds": int(seq_rounds),
            "accuracy": s_seq["accuracy"],
            "tier_histogram": s_seq["tier_histogram"],
        },
        "pipelined": {
            "wall_s": wall_pipe,
            "rounds": int(ps.rounds),
            "accuracy": s_pipe["accuracy"],
            "tier_histogram": s_pipe["tier_histogram"],
            "overlap_fraction": ps.overlap_fraction,
            "n_loops": int(ps.n_loops),
        },
        "ssm_tier": tier_row(ssm_st),
        "attn_tier": tier_row(attn_st),
        "equal_accuracy": bool(
            s_seq["accuracy"] == s_pipe["accuracy"]
            and s_seq["tier_histogram"] == s_pipe["tier_histogram"]),
        "leak_clean": bool(all(s.leak_report is None
                               for s in ps.loop_stats)
                           and all(s is None or s.leak_report is None
                                   for s in tier_stats)),
    }}


def format_hetero(table) -> str:
    row = table["arith"]
    seq, pipe = row["sequential"], row["pipelined"]
    ssm, attn = row["ssm_tier"], row["attn_tier"]
    lines = ["heterogeneous cascade: SSM tier-0 -> transformer tier-1",
             f"{'tier':18s} {'rounds':>7s} {'gen':>6s} {'slots':>6s} "
             f"{'peak':>5s} {'state B':>9s} {'KV blk':>7s}"]
    for name, r in (("ssm (mamba2)", ssm), ("attn (paged KV)", attn)):
        lines.append(
            f"{name:18s} {r['rounds']:7d} {r['generated_tokens']:6d} "
            f"{r['state_slots']:6d} {r['peak_state_slots']:5d} "
            f"{r['peak_state_bytes']:9d} {r['peak_blocks_in_use']:7d}")
    lines.append(
        f"serialized {seq['wall_s']:.2f}s / {seq['rounds']} rounds vs "
        f"pipelined {pipe['wall_s']:.2f}s / {pipe['rounds']} rounds "
        f"({pipe['n_loops']} loops, overlap "
        f"{pipe['overlap_fraction']:.0%})  acc= "
        f"{'yes' if row['equal_accuracy'] else 'NO'}  leak-clean: "
        f"{'yes' if row['leak_clean'] else 'NO'}")
    return "\n".join(lines)


# ----------------------------------------------------------------------
# Speculative cascade: rejected-tier drafts verified by the next tier
# ----------------------------------------------------------------------

def run_spec_smoke(n_items: int = 8, k: int = 4, tau: float = UNREACHABLE_TAU,
                   lane_budget: int = 16, round_tokens: int = 4,
                   new_tokens: int = 16, spec_k: int = 12):
    """No-training smoke for the speculative cascade: the pipelined
    two-tier cascade run twice — plain (``draft_rejected=False``) and
    with each rejected group's representative completion fed to the
    next tier as a draft (``draft_rejected=True``, verified ``spec_k``
    tokens per round by ``serving/batch.decode_round_spec``).

    Greedy decoding (temperature 0) with ``tau=UNREACHABLE_TAU`` makes
    the comparison deterministic: every question is rejected by both
    tiers and lands on the terminal in both paths, so accuracy and the
    tier histogram are equal *by construction*, and the two tiers share
    one set of weights, so a tier-2 lane whose prompt matches the
    tier-1 representative reproduces its stream exactly — its whole
    draft verifies in one round instead of ``budget/round_tokens``
    rounds, and ``VoteEarlyStop`` then kills the rest of its group
    rounds early.  The win the CI gate checks is therefore on the
    *escalated* tier's decode rounds (tier 1 is identical in both
    paths, so its loop's round count cancels out).

    The smoke also re-decodes one escalated group directly through the
    serving layer with and without its draft (no stop policy, every
    lane to budget): the completions must be **bit-equal**, which is
    the speculation contract — drafts change round counts, never
    output.  Each cascade path runs twice (first pass pays the jit
    compiles, including the verify-round executable) and reports the
    min wall of its two passes."""
    from repro.core import cascade_multi as cm
    from repro.core.experiment import TINY, model_config
    from repro.core.routing import make_scheduler
    from repro.data.pipeline import format_prompt
    from repro.models import model as model_lib
    from repro.serving.batch import GenConfig
    from repro.serving.scheduler import Request

    params = model_lib.init_params(model_config(TINY), jax.random.PRNGKey(0))
    gcfg = GenConfig(max_new_tokens=new_tokens, temperature=0.0, top_p=1.0)

    def tier_slm(spec):
        slm = make_slm(params, TINY, temperature=0.0)
        slm.gcfg = gcfg
        slm.round_tokens = round_tokens
        slm.lane_budget = lane_budget
        slm.spec_k = spec
        return slm

    # two *distinct* SLM objects (same weights) so the pipelined cascade
    # opens one loop per tier instead of fusing them — per-tier round
    # counts stay separable; only tier 2 verifies drafts
    tiers = [cm.Tier(slm=tier_slm(None), tau=tau, mode="FCV", k=k),
             cm.Tier(slm=tier_slm(spec_k), tau=tau, mode="FCV", k=k)]
    slm2 = tiers[1].slm
    items = eval_items(TINY, "arith")[:n_items]
    terminal = cm.TerminalTier(llm=common.oracle_llm())
    key = jax.random.PRNGKey(5)

    def run_path(drafted: bool):
        best = None
        for _ in range(2):         # first pass pays compiles; min-of-2
            out, ps = cm.run_cascade_pipelined(tiers, terminal, items, key,
                                               draft_rejected=drafted)
            if best is None or ps.wall_s < best[1].wall_s:
                best = (out, ps)
        return best

    out_plain, ps_plain = run_path(False)
    out_spec, ps_spec = run_path(True)

    # serving-layer bit-equality: one escalated group, drafted vs not
    # (no stop policy — all lanes run to budget and must match exactly)
    reqs = [Request(uid=j, prompt=format_prompt(items[0], conf_level=lvl))
            for j, lvl in enumerate(tiers[1].levels())]
    loop = make_scheduler(slm2, k).loop(jax.random.PRNGKey(9))
    loop.submit([Request(**vars(r)) for r in reqs])
    ref = {c.uid: list(c.tokens) for c in loop.drain()}
    loop.close()
    loop = make_scheduler(slm2, k).loop(jax.random.PRNGKey(9))
    loop.submit([Request(**vars(r)) for r in reqs],
                draft_tokens={r.uid: ref[0] for r in reqs})
    got = {c.uid: list(c.tokens) for c in loop.drain()}
    gstats = loop.close()

    def row(out, ps):
        t2 = ps.loop_stats[1]
        s = cm.summarize(out, len(tiers))
        return {
            "wall_s": ps.wall_s,
            "rounds": int(ps.rounds),
            "escalated_rounds": int(t2.rounds),
            "generated_tokens": int(ps.generated_tokens),
            "spec_rounds": int(ps.spec_rounds),
            "drafted_tokens": int(ps.drafted_tokens),
            "accepted_draft_tokens": int(ps.accepted_draft_tokens),
            "accuracy": s["accuracy"],
            "tier_histogram": s["tier_histogram"],
            # per-round host/device breakdown across both tier loops
            "sched_ms": 1e3 * sum(x.sched_s for x in ps.loop_stats),
            "dispatch_ms": 1e3 * sum(x.dispatch_s for x in ps.loop_stats),
            "harvest_ms": 1e3 * sum(x.harvest_s for x in ps.loop_stats),
        }

    plain, spec = row(out_plain, ps_plain), row(out_spec, ps_spec)
    return {"arith": {
        "no_draft": plain,
        "draft_rejected": spec,
        "speedup": plain["wall_s"] / max(spec["wall_s"], 1e-9),
        "escalated_rounds_cut": 1.0 - spec["escalated_rounds"]
                                / max(plain["escalated_rounds"], 1),
        "accept_rate": spec["accepted_draft_tokens"]
                       / max(spec["drafted_tokens"], 1),
        "equal_accuracy": bool(
            plain["accuracy"] == spec["accuracy"]
            and plain["tier_histogram"] == spec["tier_histogram"]
            and [(o.accepted_tier, o.correct) for o in out_plain]
                == [(o.accepted_tier, o.correct) for o in out_spec]),
        "completions_bitequal": bool(got == ref),
        "group_accepted_tokens": int(gstats.accepted_draft_tokens),
    }}


def format_spec(table, tau: float) -> str:
    lines = [f"speculative cascade: rejected-tier drafts @ tau={tau}",
             f"{'benchmark':12s} {'wall(plain)':>12s} {'wall(spec)':>11s} "
             f"{'speedup':>8s} {'rnd-esc(p)':>11s} {'rnd-esc(s)':>11s} "
             f"{'cut':>6s} {'accept':>7s} {'bit=':>5s} {'acc=':>5s}"]
    for b, row in table.items():
        p, s = row["no_draft"], row["draft_rejected"]
        lines.append(
            f"{b:12s} {p['wall_s']:11.2f}s {s['wall_s']:10.2f}s "
            f"{row['speedup']:7.2f}x {p['escalated_rounds']:11d} "
            f"{s['escalated_rounds']:11d} {row['escalated_rounds_cut']:6.0%} "
            f"{row['accept_rate']:7.0%} "
            f"{'yes' if row['completions_bitequal'] else 'NO':>5s} "
            f"{'yes' if row['equal_accuracy'] else 'NO':>5s}")
    return "\n".join(lines)


def format_pipeline(table, tau: float) -> str:
    """One line per benchmark comparing the barrier and pipelined
    cascade paths (both warm): wall-clock, decode rounds (the
    deterministic packing win), tier-overlap fraction, and the
    pipelined path's mean/p95 time-to-decision."""
    lines = [f"pipelined cascade vs sequential barriers @ tau={tau}",
             f"{'benchmark':12s} {'wall(seq)':>10s} {'wall(pipe)':>11s} "
             f"{'speedup':>8s} {'rnd(seq)':>9s} {'rnd(pipe)':>10s} "
             f"{'overlap':>8s} {'ttd-mean':>9s} {'ttd-p95':>8s} {'acc=':>5s}"]
    for b, row in table.items():
        seq, pipe = row["sequential"], row["pipelined"]
        lines.append(
            f"{b:12s} {seq['wall_s']:9.2f}s {pipe['wall_s']:10.2f}s "
            f"{row['speedup']:7.2f}x {seq['rounds']:9d} "
            f"{pipe['rounds']:10d} {pipe['overlap_fraction']:8.0%} "
            f"{pipe['ttd_mean_s']:8.2f}s {pipe['ttd_p95_s']:7.2f}s "
            f"{'yes' if row['equal_accuracy'] else 'NO':>5s}")
    return "\n".join(lines)


def format_generated(table, tau: float) -> str:
    """One line per benchmark; ``cache(es)`` is the peak K/V footprint
    of the early-stop run, ``dense-eq`` the dense cache at the same
    lane count (identical unless the run was paged), and ``prefill``
    the prompt tokens the prefill path really processed (drops ~K-fold
    with --share-prefix)."""
    lines = [f"compute early stop @ tau={tau}",
             f"{'benchmark':12s} {'gen(es)':>9s} {'gen(full)':>10s} "
             f"{'cut':>6s} {'wall(es)':>9s} {'wall(full)':>11s} {'killed':>7s}"
             f" {'prefill':>8s} {'cache(es)':>10s} {'dense-eq':>10s} "
             f"{'hbm-cut':>8s}"]
    for b, row in table.items():
        es, full = row["early_stop"], row["no_early_stop"]
        lines.append(
            f"{b:12s} {es['generated_tokens']:9d} "
            f"{full['generated_tokens']:10d} {row['generated_cut']:6.0%} "
            f"{es['wall_s']:8.2f}s {full['wall_s']:10.2f}s "
            f"{es['cancelled_lanes']:7d} "
            f"{es['prefill_tokens']:8d} "
            f"{es['peak_cache_bytes'] / 2**20:9.2f}M "
            f"{es['dense_cache_bytes'] / 2**20:9.2f}M "
            f"{row['cache_cut']:8.0%}")
    return "\n".join(lines)


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="untrained tiny model, arith only")
    ap.add_argument("--scale", default=None,
                    help="experiment scale for trained runs "
                         "(default: tiny)")
    ap.add_argument("--tau", type=float, default=None)
    ap.add_argument("--k", type=int, default=None,
                    help="default: 8 (smoke) / scale.k_samples")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the block-paged KV cache "
                         "(smoke only; reports peak blocks vs dense)")
    ap.add_argument("--block-size", type=int, default=32,
                    help="cache slots per block with --paged")
    ap.add_argument("--share-prefix", action="store_true",
                    help="with --paged: prefill each K-vote group once "
                         "and share its prompt blocks (refcount + CoW)")
    ap.add_argument("--pipeline-cascade", action="store_true",
                    help="smoke the pipelined multi-tier cascade against "
                         "the sequential-barrier path (wall-clock, decode "
                         "rounds, overlap, time-to-decision)")
    ap.add_argument("--spec-cascade", action="store_true",
                    help="smoke the speculative cascade: rejected-tier "
                         "completions fed to the next tier as drafts and "
                         "verified spec_k tokens per round, against the "
                         "same pipelined cascade without drafts")
    ap.add_argument("--chunked-serve", action="store_true",
                    help="smoke chunked prefill against whole-prompt "
                         "prefill under a Poisson arrival stream "
                         "(per-request ttft distribution)")
    ap.add_argument("--preempt", action="store_true",
                    help="smoke block-granular preemption with host KV "
                         "offload: a 2-lane pool served with and without "
                         "auto_preempt against an ample-pool reference")
    ap.add_argument("--quant", action="store_true",
                    help="smoke the quantized serving tier: int8 paged KV "
                         "(+ int8 weights) vs fp32 at equal lane count "
                         "(HBM footprint, accuracy at tolerance)")
    ap.add_argument("--sharded", action="store_true",
                    help="smoke multi-device sharded serving on simulated "
                         "host devices: lane scaling at bit-equal "
                         "completions + cascade tier placement (serialized "
                         "vs concurrent slices)")
    ap.add_argument("--devices", type=int, default=4,
                    help="simulated device count for --sharded (default 4)")
    ap.add_argument("--hetero", action="store_true",
                    help="smoke the mixed-architecture cascade: a "
                         "mamba2-style pure-SSM tier-0 (paged state-slot "
                         "pool) escalating to a paged-KV transformer "
                         "tier-1, pipelined vs per-tier barriers")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the result table as JSON (CI artifact)")
    args = ap.parse_args()
    if args.share_prefix and not args.paged:
        ap.error("--share-prefix requires --paged")
    if args.hetero:
        if not args.smoke or args.paged or args.pipeline_cascade \
                or args.chunked_serve or args.spec_cascade or args.preempt \
                or args.quant or args.sharded:
            ap.error("--hetero is a standalone --smoke benchmark")
        t = run_hetero_smoke(k=args.k or 4)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"hetero_smoke": True, "smoke": True,
                           "table": t}, f, indent=2)
        print(format_hetero(t))
    elif args.sharded:
        if not args.smoke or args.paged or args.pipeline_cascade \
                or args.chunked_serve or args.spec_cascade or args.preempt \
                or args.quant:
            ap.error("--sharded is a standalone --smoke benchmark")
        if args.devices < 2 or args.devices % 2:
            ap.error("--devices must be an even count >= 2")
        # must run before the first jax device query locks the backend
        from repro.launch.mesh import ensure_sim_devices
        ensure_sim_devices(args.devices)
        t = run_sharded_smoke(devices=args.devices)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"sharded_smoke": True, "smoke": True,
                           "devices": args.devices, "table": t}, f, indent=2)
        print(format_sharded(t, args.devices))
    elif args.quant:
        if not args.smoke or args.paged or args.pipeline_cascade \
                or args.chunked_serve or args.spec_cascade or args.preempt:
            ap.error("--quant is a standalone --smoke benchmark")
        t = run_quant_smoke()
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"quant_smoke": True, "smoke": True,
                           "table": t}, f, indent=2)
        print(format_quant(t))
    elif args.preempt:
        if not args.smoke or args.paged or args.pipeline_cascade \
                or args.chunked_serve or args.spec_cascade or args.quant:
            ap.error("--preempt is a standalone --smoke benchmark")
        t = run_preempt_smoke()
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"preempt_smoke": True, "smoke": True,
                           "table": t}, f, indent=2)
        print(format_preempt(t))
    elif args.spec_cascade:
        if not args.smoke or args.paged or args.pipeline_cascade \
                or args.chunked_serve:
            ap.error("--spec-cascade is a standalone --smoke benchmark")
        args.tau = UNREACHABLE_TAU if args.tau is None else args.tau
        t = run_spec_smoke(tau=args.tau, k=args.k or 4)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"tau": args.tau, "spec_cascade": True,
                           "smoke": True, "table": t}, f, indent=2)
        print(format_spec(t, args.tau))
    elif args.chunked_serve:
        if not args.smoke or args.paged or args.pipeline_cascade:
            ap.error("--chunked-serve is a standalone --smoke benchmark")
        t = run_chunked_smoke()
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"chunked_serve": True, "smoke": True,
                           "table": t}, f, indent=2)
        print(format_chunked(t))
    elif args.pipeline_cascade:
        if args.paged or args.share_prefix:
            ap.error("--pipeline-cascade runs the dense smoke cascade")
        if not args.smoke or args.scale is not None:
            ap.error("--pipeline-cascade is only wired for --smoke runs")
        args.tau = UNREACHABLE_TAU if args.tau is None else args.tau
        t = run_pipeline_smoke(tau=args.tau, k=args.k or 4)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"tau": args.tau, "pipeline_cascade": True,
                           "smoke": True, "table": t}, f, indent=2)
        print(format_pipeline(t, args.tau))
    else:
        if args.smoke:
            args.tau = 1.0 if args.tau is None else args.tau
            t = run_generated_smoke(tau=args.tau, k=args.k or 8,
                                    paged=args.paged,
                                    block_size=args.block_size,
                                    share_prefix=args.share_prefix)
        else:
            from repro.core.experiment import SCALES
            if args.paged:
                ap.error("--paged is only wired for --smoke runs")
            args.tau = 0.6 if args.tau is None else args.tau
            t = run_generated(SCALES[args.scale or "tiny"], tau=args.tau,
                              k=args.k)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"tau": args.tau, "paged": args.paged,
                           "share_prefix": args.share_prefix,
                           "smoke": args.smoke, "table": t}, f, indent=2)
        print(format_generated(t, args.tau))
