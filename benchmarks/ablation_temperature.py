"""Paper Figure 13 analogue: sampling-temperature ablation.

The paper compares cascade/pre-gen curves at temperature 0.7 vs 0.3 and
finds lower temperature reduces output diversity, hurting accuracy in
high-threshold intervals while RCV/FCV retain their advantage.  This
ablation reruns the routing evaluation at both temperatures on a subset.

  PYTHONPATH=src python -m benchmarks.ablation_temperature
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import routing as routing_lib
from repro.core.experiment import SCALES, eval_items, get_models, make_slm


BENCHES = ("modchain", "parity")
N_ITEMS = 20
TAUS = (0.3, 0.6, 0.9)


def run(scale_tag: str = "tiny"):
    x = SCALES[scale_tag]
    models = get_models(x)
    llm = routing_lib.OracleLLM(accuracy=1.0, avg_out_tokens=40)
    items = []
    for b in BENCHES:
        items.extend(eval_items(x, b)[:N_ITEMS])

    out = {}
    for temp in (0.7, 0.3):
        sater = make_slm(models["stage2"], x, temperature=temp)
        key = jax.random.PRNGKey(11)
        pre = routing_lib.pregen_outcomes_sater(sater, items, llm, key,
                                                thresholds=list(TAUS))
        casc = routing_lib.cascade_outcomes(sater, items, llm, key,
                                            mode="FCV", k=6,
                                            thresholds=list(TAUS))
        row = {}
        for tau in TAUS:
            p = pre[tau]
            c = casc[tau]
            row[str(tau)] = {
                "pregen_acc": float(np.mean(
                    [o.llm_correct if o.routed else o.slm_correct
                     for o in p])),
                "pregen_routed": float(np.mean([o.routed for o in p])),
                "cascade_acc": float(np.mean(
                    [o.llm_correct if o.routed else o.slm_correct
                     for o in c])),
                "cascade_routed": float(np.mean([o.routed for o in c])),
            }
        out[str(temp)] = row
    return out


def format_table(res) -> str:
    lines = [f"{'temp':>5} {'tau':>4} {'pregen acc':>11} {'routed':>7} "
             f"{'cascade acc':>12} {'routed':>7}"]
    for temp, rows in res.items():
        for tau, r in rows.items():
            lines.append(
                f"{temp:>5} {tau:>4} {r['pregen_acc']:11.2f} "
                f"{r['pregen_routed']:7.2f} {r['cascade_acc']:12.2f} "
                f"{r['cascade_routed']:7.2f}")
    return "\n".join(lines)


if __name__ == "__main__":
    res = run()
    common.save_result("ablation_temperature_tiny", res)
    print(format_table(res))
