"""Paper Tables 3/5/6: Stage-I (long-to-short) effectiveness — accuracy
and mean output tokens, original vs SATER-TE, per benchmark, with
percentage deltas."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import routing as routing_lib
from repro.core.experiment import eval_items, make_slm
from repro.data.pipeline import format_prompt
from repro.data.tasks import is_correct


def run(scale, benchmarks=None):
    benchmarks = benchmarks or common.BENCHMARKS
    mdl = common.models(scale)
    table = {}
    for b in benchmarks:
        items = eval_items(scale, b)
        row = {}
        for name, which in (("original", "base"), ("SATER", "stage1")):
            slm = make_slm(mdl[which], scale, temperature=0.0)
            texts, lens = routing_lib.batch_generate(
                slm, [format_prompt(it) for it in items],
                jax.random.PRNGKey(31))
            row[name] = {
                "acc": float(np.mean([is_correct(it, t)
                                      for it, t in zip(items, texts)])),
                "tokens": float(np.mean(lens)),
            }
        row["delta_acc_pct"] = 100 * (row["SATER"]["acc"] - row["original"]["acc"])
        row["delta_tok_pct"] = 100 * (row["SATER"]["tokens"] -
                                      row["original"]["tokens"]) / \
            max(row["original"]["tokens"], 1)
        table[b] = row
    return table


def format_table(table) -> str:
    lines = [f"{'benchmark':12s} {'acc0':>6} {'tok0':>7} {'acc1':>6} "
             f"{'tok1':>7} {'dAcc%':>7} {'dTok%':>7}"]
    for b, r in table.items():
        lines.append(
            f"{b:12s} {r['original']['acc']:6.2f} {r['original']['tokens']:7.1f} "
            f"{r['SATER']['acc']:6.2f} {r['SATER']['tokens']:7.1f} "
            f"{r['delta_acc_pct']:+7.1f} {r['delta_tok_pct']:+7.1f}")
    accs = [r["delta_acc_pct"] for r in table.values()]
    toks = [r["delta_tok_pct"] for r in table.values()]
    lines.append(f"{'average':12s} {'':6} {'':7} {'':6} {'':7} "
                 f"{np.mean(accs):+7.1f} {np.mean(toks):+7.1f}")
    return "\n".join(lines)
