"""Benchmark harness — one entry per paper table/figure + the roofline
table.  Prints ``name,us_per_call,derived`` CSV rows and writes full
JSON payloads under benchmarks/results/.

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --only roofline
  PYTHONPATH=src python -m benchmarks.run --scale small --force
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny")
    ap.add_argument("--only", default=None,
                    help="comma list: roofline,table1,table2,table3,fig3")
    ap.add_argument("--force", action="store_true",
                    help="recompute even if a cached result JSON exists")
    args = ap.parse_args()

    from benchmarks import common
    from repro.core.experiment import SCALES
    scale = SCALES[args.scale]
    only = set(args.only.split(",")) if args.only else None

    csv_rows = [("name", "us_per_call", "derived")]

    def emit(name, wall_s, n_calls, derived):
        us = 1e6 * wall_s / max(n_calls, 1)
        csv_rows.append((name, f"{us:.1f}", derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    def want(name):
        return only is None or name in only

    # ------------------------------------------------------- roofline
    if want("roofline"):
        from benchmarks import roofline
        with common.timer() as t:
            rows = roofline.build_table("pod")
        common.save_result("roofline_pod", rows)
        print(roofline.format_table(rows), file=sys.stderr)
        dom = {}
        for r in rows:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        emit("roofline_pod", t.s, max(len(rows), 1),
             "dominant:" + "/".join(f"{k}={v}" for k, v in sorted(dom.items())))

    # ------------------------------------------------- paper tables
    n_q = scale.n_eval * len(common.BENCHMARKS)

    if want("table3"):
        from benchmarks import table3_long2short
        cached = None if args.force else common.load_result(
            f"table3_{scale.tag}")
        with common.timer() as t:
            table = cached or table3_long2short.run(scale)
        common.save_result(f"table3_{scale.tag}", table)
        print(table3_long2short.format_table(table), file=sys.stderr)
        import numpy as np
        dtok = np.mean([r["delta_tok_pct"] for r in table.values()])
        dacc = np.mean([r["delta_acc_pct"] for r in table.values()])
        emit("table3_long2short", t.s, n_q,
             f"dTok={dtok:+.1f}%;dAcc={dacc:+.1f}%")

    if want("table2"):
        from benchmarks import table2_latency
        cached = None if args.force else common.load_result(
            f"table2_{scale.tag}")
        with common.timer() as t:
            table = cached or table2_latency.run(scale)
        common.save_result(f"table2_{scale.tag}", table)
        for tau in (0.6, 1.0):
            print(table2_latency.format_table(table, tau), file=sys.stderr)
        import numpy as np
        sc_agl = np.mean([r["SC"]["0.6"]["AGL"] for r in table.values()])
        fcv_agl = np.mean([r["SC/FCV"]["0.6"]["AGL"] for r in table.values()])
        sc_arol = np.mean([r["SC"]["0.6"]["AROL"] for r in table.values()])
        fcv_arol = np.mean([r["SC/FCV"]["0.6"]["AROL"] for r in table.values()])
        emit("table2_latency", t.s, n_q * 4,
             f"AGL_cut={100*(1-fcv_agl/max(sc_agl,1e-9)):.0f}%;"
             f"AROL_cut={100*(1-fcv_arol/max(sc_arol,1e-9)):.0f}%")

    if want("table1"):
        from benchmarks import table1_pregen
        cached = None if args.force else common.load_result(
            f"table1_{scale.tag}")
        with common.timer() as t:
            table = cached or table1_pregen.run(scale)
        common.save_result(f"table1_{scale.tag}", table)
        print(table1_pregen.format_table(table), file=sys.stderr)
        import numpy as np
        wins = sum(1 for row in table.values()
                   if row["SATER"]["togr"] >= max(
                       row[m]["togr"] for m in row if m != "SATER"))
        mean_togr = np.mean([row["SATER"]["togr"] for row in table.values()])
        emit("table1_pregen", t.s, n_q * 10,
             f"SATER_wins={wins}/{len(table)};mean_ToGR={mean_togr:.3f}")

    if want("fig3"):
        from benchmarks import fig3_cost_curves
        cached = None if args.force else common.load_result(
            f"fig3_{scale.tag}")
        with common.timer() as t:
            curves = cached or fig3_cost_curves.run(scale)
        common.save_result(f"fig3_{scale.tag}", curves)
        print(fig3_cost_curves.format_table(curves), file=sys.stderr)
        emit("fig3_cost_curves", t.s, n_q * 3, "ratios=13.75/25/50/100")

    common.save_result("bench_csv", [list(r) for r in csv_rows])


if __name__ == "__main__":
    main()
