"""Emit the EXPERIMENTS.md §Results tables from the dry-run/roofline
artifacts.

  PYTHONPATH=src python -m benchmarks.report > /tmp/results.md
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")
HBM_GB = 17.18          # 16 GiB


def _load(tag):
    with open(os.path.join(RESULTS, f"dryrun_{tag}.json")) as f:
        return json.load(f)


def dryrun_table() -> str:
    rows = ["### §Dry-run/Results — lower+compile, bytes/device, fit",
            "",
            "| arch | shape | mesh | params | GB/dev (arg+temp) | fits 16 GiB | "
            "HLO GFLOPs/chip | coll B/chip | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    pairs = sorted({tuple(os.path.basename(p)[7:-5].split("__"))
                    for p in glob.glob(os.path.join(RESULTS, "dryrun_*.json"))
                    if len(os.path.basename(p)[7:-5].split("__")) == 3})
    for arch, shape, mesh in pairs:
        d = _load(f"{arch}__{shape}__{mesh}")
        if not d.get("ok"):
            rows.append(f"| {arch} | {shape} | {mesh} | | FAILED | | | | |")
            continue
        tot = (d["argument_size_in_bytes"] + d["temp_size_in_bytes"]) / 1e9
        fit = "yes" if tot <= HBM_GB else f"**no** ({tot:.1f} GB)"
        rows.append(
            f"| {arch} | {shape} | {mesh} | {d['params']/1e9:.1f}B "
            f"| {tot:.2f} | {fit} | {d.get('hlo_flops', 0)/1e9:.0f} "
            f"| {d['collectives']['total']:.2e} | {d.get('compile_s', 0)} |")
    return "\n".join(rows)


def roofline_table() -> str:
    with open(os.path.join(RESULTS, "roofline_pod.json")) as f:
        rl = json.load(f)
    rows = ["### §Roofline/Results — single-pod (256 chips), per step",
            "",
            "| arch | shape | compute s | memory s | collective s | dominant | "
            "useful (model/HLO flops) | MFU bound |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rl, key=lambda x: (x["arch"], x["shape"])):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['model_flops_ratio']:.2f} "
            f"| {r['mfu_bound']:.2f} |")
    return "\n".join(rows)


def main():
    print(dryrun_table())
    print()
    print(roofline_table())


if __name__ == "__main__":
    main()
