"""§Roofline: derive the three roofline terms per (arch x shape) from the
dry-run artifacts (benchmarks/results/dryrun_*.json, single-pod mesh).

  compute    = FLOPs / (chips * 197 TFLOP/s bf16)
  memory     = HBM bytes / (chips * 819 GB/s)
  collective = per-chip collective bytes / (50 GB/s ICI)

FLOPs/bytes come from the analytic step model (launch/analytics.py)
because XLA's cost analysis counts scan bodies once; the per-chip raw
HLO numbers are kept alongside for cross-checking.  Collective bytes are
parsed from the compiled HLO with while-loop trip multipliers (i.e. they
ARE from the compiled artifact)."""

from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_dryruns(mesh: str = "pod"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"dryrun_*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("ok"):
            rows.append(r)
    return rows


def roofline_terms(r: dict) -> dict:
    chips = r["devices"]
    t_c = r["analytic_flops"] / (chips * PEAK_FLOPS_BF16)
    t_m = r["analytic_bytes"] / (chips * HBM_BW)
    coll = r["collectives"].get("total",
                                sum(v for k, v in r["collectives"].items()
                                    if not k.startswith("n_")))
    t_x = coll / ICI_BW          # collective bytes are per-chip already
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    ratio = r["model_flops"] / r["analytic_flops"]
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops_ratio": ratio,
        "step_lower_bound_s": bound,
        "mfu_bound": r["model_flops"] / (r["devices"] * PEAK_FLOPS_BF16) / bound
        if bound > 0 else 0.0,
    }


_SUGGEST = {
    "compute": "raise arithmetic intensity per chip (bigger per-chip tiles, "
               "defer remat, fuse elementwise into matmuls)",
    "memory": "cut HBM traffic (smaller logits dtype/chunked loss, fewer "
              "remat reads, quantized cache)",
    "collective": "reshard to cut cross-chip bytes (fewer all-reduces in the "
                  "layer scan, reduce-scatter grads, avoid FSDP regather)",
}


def build_table(mesh: str = "pod"):
    rows = []
    for r in load_dryruns(mesh):
        t = roofline_terms(r)
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "devices": r["devices"],
            **{k: t[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "dominant", "model_flops_ratio",
                                 "mfu_bound")},
            "suggest": _SUGGEST[t["dominant"]],
            "hlo_flops_per_chip": r.get("hlo_flops"),
            "temp_bytes_per_chip": r.get("temp_size_in_bytes"),
            "compile_s": r.get("compile_s"),
        })
    return rows


def format_table(rows) -> str:
    lines = [f"{'arch':24s} {'shape':12s} {'compute_s':>10} {'memory_s':>10} "
             f"{'collect_s':>10} {'dominant':>10} {'useful':>7} {'mfu<=':>6}"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.2e} "
            f"{r['memory_s']:10.2e} {r['collective_s']:10.2e} "
            f"{r['dominant']:>10s} {r['model_flops_ratio']:7.2f} "
            f"{r['mfu_bound']:6.2f}")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = build_table()
    print(format_table(rows))
    with open(os.path.join(RESULTS, "roofline_pod.json"), "w") as f:
        json.dump(rows, f, indent=1)
