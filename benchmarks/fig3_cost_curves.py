"""Paper Figures 3/4: average cost-accuracy(100) curves — pre-generation
vs cascade routing at cost ratios 1:13.75, 1:25, 1:50, 1:100."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import metrics as metrics_lib
from repro.core import routing as routing_lib
from repro.core.cost import with_ratio
from repro.core.experiment import eval_items, make_slm

RATIOS = (13.75, 25, 50, 100)


def run(scale, benchmarks=None, k=None):
    benchmarks = benchmarks or common.BENCHMARKS[:4]
    k = k or scale.k_samples
    llm = common.oracle_llm()
    mdl = common.models(scale)
    sater = make_slm(mdl["stage2"], scale)
    base = make_slm(mdl["base"], scale)

    # collect outcome sets once; price them at each ratio afterwards
    per_bench = {}
    for b in benchmarks:
        items = eval_items(scale, b)
        per_bench[b] = {
            "pregen": routing_lib.pregen_outcomes_sater(
                sater, items, llm, jax.random.PRNGKey(41)),
            "cascade_fcv": routing_lib.cascade_outcomes(
                sater, items, llm, jax.random.PRNGKey(42), mode="FCV", k=k),
            "cascade_sc": routing_lib.cascade_outcomes(
                base, items, llm, jax.random.PRNGKey(43), mode="SC", k=k,
                early_stop=False),
        }

    curves = {}
    for ratio in RATIOS:
        cm = with_ratio(ratio)
        agg = {}
        for method in ("pregen", "cascade_fcv", "cascade_sc"):
            pts_all = []
            for b in benchmarks:
                pts = metrics_lib.points_from_outcomes(
                    per_bench[b][method], cm, assume_llm_perfect=True)
                pts_all.append(pts)
            # average across benchmarks pointwise (same threshold grid)
            n = min(len(p) for p in pts_all)
            agg[method] = [
                (float(np.mean([p[i][0] for p in pts_all])),
                 float(np.mean([p[i][1] for p in pts_all])))
                for i in range(n)]
        curves[str(ratio)] = agg
    return curves


def format_table(curves) -> str:
    lines = []
    for ratio, agg in curves.items():
        lines.append(f"-- cost ratio 1:{ratio} (cost_at_tau, acc100_at_tau) --")
        for method, pts in agg.items():
            head = " ".join(f"({c:.2f},{a:.2f})" for c, a in pts[::2])
            lines.append(f"  {method:12s} {head}")
    return "\n".join(lines)
