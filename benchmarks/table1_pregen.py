"""Paper Table 1 (+ Table 7): pre-generation routing — ToA-100 and ToGR
for SATER vs BERT / KNN / HybridLLM (+ margin-sampling, FrugalGPT) across
the in-domain and OOD benchmarks."""

from __future__ import annotations

import jax

from benchmarks import common
from repro.core import baselines as bl
from repro.core import metrics as metrics_lib
from repro.core import routing as routing_lib
from repro.core.cost import DEFAULT
from repro.core.experiment import eval_items, make_slm, stage_questions
from repro.core.metrics import QuestionRecord
from repro.data.pipeline import format_prompt


def _train_routers(scale):
    """Fit all classifier baselines on stage-question correctness."""
    base = make_slm(common.models(scale)["base"], scale)
    train_items = stage_questions(scale)
    samples = routing_lib.collect_samples(base, train_items, 4,
                                          jax.random.PRNGKey(7))
    prompts = [format_prompt(s.item) for s in samples]
    soft = [s.accuracy for s in samples]
    hard = [1.0 if s.accuracy >= 0.5 else 0.0 for s in samples]
    routers = {
        "KNN": bl.KNNRouter().fit(prompts, hard),
        "HybridLLM": bl.HybridLLMRouter().fit(prompts, soft),
        "BERT": bl.BERTRouter(epochs=4).fit(prompts, hard),
    }
    # FrugalGPT: correctness classifier on (prompt, answer) pairs
    frugal = bl.FrugalGPTScorer()
    ans = [s.texts[0] for s in samples]
    corr = [float(s.correct_flags[0]) for s in samples]
    frugal.fit_pairs(prompts, ans, corr)
    routers["FrugalGPT"] = frugal
    return routers, samples


def run(scale, benchmarks=None):
    benchmarks = benchmarks or common.BENCHMARKS
    routers, _ = _train_routers(scale)
    llm = common.oracle_llm()
    sater = make_slm(common.models(scale)["stage2"], scale)

    table = {}
    for b in benchmarks:
        items = eval_items(scale, b)
        (c_s, p_s), slm_corr, slm_out, slm_texts = common.slm_endpoint(scale, b)
        golden = common.golden_for(scale, b)
        prompts = [format_prompt(it) for it in items]
        llm_ans = [llm.answer(it) for it in items]

        def records(scores):
            return [QuestionRecord(sc, la[0], len(p), so, la[1], float(s))
                    for sc, la, p, so, s in zip(slm_corr, llm_ans, prompts,
                                                slm_out, scores)]

        row = {}
        for name, router in routers.items():
            if name == "FrugalGPT":
                scores = router.score_pairs(prompts, slm_texts)
            else:
                scores = router.score(prompts)
            s = metrics_lib.toa_summary(records(scores), DEFAULT)
            row[name] = {"toa_100": s["toa_100"], "togr": s["togr"]}

        out = routing_lib.pregen_outcomes_sater(sater, items, llm,
                                                jax.random.PRNGKey(11))
        s = metrics_lib.outcome_toa_summary(out, DEFAULT, (c_s, p_s), golden)
        row["SATER"] = {"toa_100": s["toa_100"], "togr": s["togr"]}
        table[b] = row
    return table


def format_table(table) -> str:
    methods = ["HybridLLM", "KNN", "BERT", "FrugalGPT", "SATER"]
    lines = [f"{'benchmark':12s} " + " ".join(f"{m:>10s}{'':>7s}" for m in methods),
             f"{'':12s} " + " ".join(f"{'ToA-100':>10s}{'ToGR':>7s}" for _ in methods)]
    for b, row in table.items():
        cells = []
        for m in methods:
            r = row.get(m, {})
            cells.append(f"{r.get('toa_100', float('nan')):10.3f}"
                         f"{r.get('togr', float('nan')):7.3f}")
        lines.append(f"{b:12s} " + " ".join(cells))
    return "\n".join(lines)
