#!/usr/bin/env python
"""CI docs check: fail on broken relative links in README.md and
docs/*.md.

Checks every markdown inline link `[text](target)` whose target is
neither absolute (http/https/mailto) nor a pure in-page anchor:
the referenced file must exist relative to the linking file (anchors
are stripped; directory targets must exist as directories).

  python scripts/check_docs_links.py            # repo root inferred
  python scripts/check_docs_links.py --root .   # explicit
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import sys

# inline links, tolerating one level of nested brackets in the text;
# reference-style definitions are rare here and intentionally ignored
LINK_RE = re.compile(r"\[(?:[^\[\]]|\[[^\]]*\])*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(path: str) -> list:
    broken = []
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:        # code samples are not links
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(SKIP_PREFIXES):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), rel))
                if not os.path.exists(resolved):
                    broken.append((path, lineno, target))
    return broken


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="repo root (default: parent of this script)")
    args = ap.parse_args()
    root = args.root or os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))
    files = [os.path.join(root, "README.md")] + \
        sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    broken = []
    checked = 0
    for path in files:
        if not os.path.exists(path):
            print(f"missing expected doc: {path}", file=sys.stderr)
            broken.append((path, 0, "<file itself>"))
            continue
        checked += 1
        broken.extend(check_file(path))
    for path, lineno, target in broken:
        print(f"{os.path.relpath(path, root)}:{lineno}: broken link -> "
              f"{target}", file=sys.stderr)
    print(f"checked {checked} files, {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
