"""Benchmark-regression gate for the CI smoke JSONs.

Compares a freshly produced smoke-benchmark JSON
(``benchmarks/table2_latency.py --json``) against the committed
baseline under ``benchmarks/baselines/`` and fails (exit 1) when a
cost metric regressed beyond its tolerance:

  * *counters* (tokens decoded, prefill tokens, peak pool blocks,
    decode rounds) are deterministic given the pinned seeds, but may
    drift a few percent across jax/numpy versions (different matmul
    reduction orders flip the occasional sampled token) — they get a
    relative tolerance plus a small absolute slack;
  * *wall-clock* varies with the runner, so it only gates at a generous
    ``--wall-slack`` factor — it catches "the smoke got 3x slower",
    not machine noise;
  * *ratios that should stay high* (``generated_cut``, ``cache_cut``,
    ``overlap_fraction``) gate downward with an absolute tolerance;
  * the pipelined-cascade JSON additionally carries *invariants* that
    hold regardless of baseline: the pipelined path must beat the
    sequential barrier path on wall-clock AND decode rounds at equal
    accuracy (``equal_accuracy``) — the acceptance bar for cascade
    pipelining, checked on every CI run;
  * the chunked-serve JSON (``--chunked-serve``) carries its own
    baseline-free invariants: chunked prefill must generate exactly the
    tokens (and accuracy) whole-prompt prefill generates — bit-identity
    is the contract, not a tolerance — and its ttft p95 under the
    Poisson arrival stream must sit strictly below the whole-prefill
    path's;
  * the speculative-cascade JSON (``--spec-cascade``) likewise:
    draft acceptance must be nonzero, drafted completions must be
    bit-equal to the undrafted path at equal accuracy, and the drafted
    run must sit strictly below the undrafted one on wall-clock and
    total rounds, with the escalated tier's rounds cut >= 30%;
  * the preemption JSON (``--preempt``) carries its own baseline-free
    invariants: the tiny pool must force at least one offload/resume
    cycle, preempted completions must be bit-equal to the ample-pool
    reference, and the preempting path must block admission strictly
    less often than the same pool without offload;
  * the sharded JSON (``--sharded``) carries its own baseline-free
    invariants: the mesh run must carry >= 3x the single-device lane
    count at bit-equal completions, and the tier-placement phase must
    keep accuracy/tier histogram equal with both slices' rounds
    genuinely in flight together (``overlap_fraction > 0`` across the
    two un-fused loops); the strict wall win of the concurrent
    placement over the serialized one additionally gates only when the
    producing rig could physically parallelize (``wall_gate_armed`` —
    simulated devices timeshare the host's cores, so a single-core
    host tops out at wall parity);
  * the heterogeneous-cascade JSON (``--hetero``) carries its own
    baseline-free invariants: the mixed SSM -> transformer cascade must
    keep accuracy/tier histogram equal to the per-tier barrier path,
    open one serving loop per cache protocol (``n_loops == 2``), and
    account recurrent state exactly (SSM tier peak state bytes ==
    peak slots x slot size at a saturated pool; zero state slots on
    the transformer tier; all loops leak-clean);
  * the quantized-tier JSON (``--quant``) carries its own baseline-free
    invariants: the int8 tier must sit *strictly below* the fp32 tier
    on both KV-footprint metrics at an equal lane count, clear the
    efficiency bar (lanes-per-HBM-byte gain >= 1.7x, or peak-KV cut
    >= 40%), and hold fp32 accuracy within the relative ``--tol``.
    Quantized serving is the one path that is NOT bit-equal to its
    reference — ``--tol`` is the stated accuracy tolerance that
    replaces the bit-identity checks every other smoke gates on.

``--tol`` (default 0.10) is the generic accuracy tolerance: any
``accuracy`` / ``token_agreement`` metric present in both trees gates
downward against the baseline at that relative tolerance (plus a small
absolute slack), and the quant invariants reuse it for the int8-vs-fp32
accuracy comparison.

Usage:
    python scripts/check_bench_regression.py CURRENT.json BASELINE.json
    python scripts/check_bench_regression.py CURRENT.json BASELINE.json --update

``--update`` rewrites the baseline from the current run (after a
deliberate improvement or an accepted drift; commit the result).
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys

# metric name -> (direction, relative tolerance, absolute slack)
#   "low"  : lower is better; fail when current > base * (1+rel) + abs
#   "high" : higher is better; fail when current < base * (1-rel) - abs
COUNTERS = {
    "generated_tokens": ("low", 0.20, 16),
    "prefill_tokens": ("low", 0.15, 16),
    "prefill_prompts": ("low", 0.15, 4),
    "peak_blocks_in_use": ("low", 0.30, 4),
    "rounds": ("low", 0.25, 4),
    "cancelled_lanes": ("high", 0.30, 4),
    "generated_cut": ("high", 0.0, 0.15),
    "cache_cut": ("high", 0.0, 0.15),
    # relative floor: catches tier overlap collapsing toward zero
    # without pinning the exact (raggedness-dependent) fraction
    "overlap_fraction": ("high", 0.5, 0.01),
    # speculative cascade: escalated-tier rounds must stay cut and
    # drafts must keep verifying (greedy same-weights tiers: ~1.0)
    "escalated_rounds": ("low", 0.25, 2),
    "escalated_rounds_cut": ("high", 0.0, 0.15),
    "accept_rate": ("high", 0.0, 0.15),
    # preemption smoke: offload/resume churn must neither vanish (the
    # tiny pool stopped pressuring) nor blow up (thrash), and blocked
    # admissions must stay low on the preempting path
    "preempts": ("low", 0.5, 4),
    "resumes": ("low", 0.5, 4),
    "admission_blocked": ("low", 0.5, 4),
    "host_blocks_peak": ("low", 0.5, 4),
    # quantized tier: the footprint win must not erode vs baseline
    "lanes_per_byte_gain": ("high", 0.05, 0.0),
    "kv_bytes_cut": ("high", 0.0, 0.05),
}
WALL_METRICS = ("wall_s", "ttft_mean_s", "ttft_p50_s", "ttft_p95_s")
# accuracy-type metrics gate downward at the generic --tol (relative)
# plus a small absolute slack for all-but-empty smokes
ACCURACY_METRICS = ("accuracy", "token_agreement")
ACCURACY_ABS_SLACK = 0.02


def walk(cur, base, path=""):
    """Yield (path, key, current, baseline) for every gated numeric
    metric present in both trees, recursing through dicts."""
    if not isinstance(cur, dict) or not isinstance(base, dict):
        return
    for k, v in cur.items():
        p = f"{path}.{k}" if path else k
        if isinstance(v, dict):
            yield from walk(v, base.get(k), p)
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            if k in COUNTERS or k in WALL_METRICS or k in ACCURACY_METRICS:
                b = base.get(k) if isinstance(base, dict) else None
                if isinstance(b, (int, float)) and not isinstance(b, bool):
                    yield p, k, float(v), float(b)


def check_metrics(cur, base, wall_slack, tol=0.1):
    failures, rows = [], []
    for path, key, v, b in walk(cur, base):
        if key in WALL_METRICS:
            ok = v <= b * wall_slack
            bound = f"<= {b * wall_slack:.2f} ({wall_slack:.1f}x slack)"
        elif key in ACCURACY_METRICS:
            limit = b * (1 - tol) - ACCURACY_ABS_SLACK
            ok = v >= limit
            bound = f">= {limit:.2f} (--tol {tol:.2f})"
        else:
            direction, rel, slack = COUNTERS[key]
            if direction == "low":
                limit = b * (1 + rel) + slack
                ok = v <= limit
                bound = f"<= {limit:.2f}"
            else:
                limit = b * (1 - rel) - slack
                ok = v >= limit
                bound = f">= {limit:.2f}"
        rows.append((path, v, b, bound, ok))
        if not ok:
            failures.append(f"{path}: {v:.2f} vs baseline {b:.2f} "
                            f"(bound {bound})")
    return failures, rows


def check_pipeline_invariants(cur):
    """Baseline-free acceptance checks for --pipeline-cascade JSONs."""
    failures = []
    for bench, row in cur.get("table", {}).items():
        seq, pipe = row.get("sequential"), row.get("pipelined")
        if not (isinstance(seq, dict) and isinstance(pipe, dict)):
            continue
        if not row.get("equal_accuracy", False):
            failures.append(f"{bench}: pipelined accuracy/tier histogram "
                            "diverged from the sequential path")
        if not pipe["wall_s"] < seq["wall_s"]:
            failures.append(
                f"{bench}: pipelined wall {pipe['wall_s']:.2f}s not "
                f"strictly below sequential {seq['wall_s']:.2f}s")
        if not pipe["rounds"] < seq["rounds"]:
            failures.append(
                f"{bench}: pipelined rounds {pipe['rounds']} not strictly "
                f"below sequential {seq['rounds']}")
    return failures


def check_chunked_invariants(cur):
    """Baseline-free acceptance checks for --chunked-serve JSONs."""
    failures = []
    for bench, row in cur.get("table", {}).items():
        whole, chunked = row.get("whole"), row.get("chunked")
        if not (isinstance(whole, dict) and isinstance(chunked, dict)):
            continue
        if not row.get("equal_tokens", False):
            failures.append(f"{bench}: chunked prefill generated different "
                            "tokens than whole-prompt prefill (bit-identity "
                            "violated)")
        if not row.get("equal_accuracy", False):
            failures.append(f"{bench}: chunked accuracy diverged from the "
                            "whole-prompt path")
        if not chunked["ttft_p95_s"] < whole["ttft_p95_s"]:
            failures.append(
                f"{bench}: chunked ttft p95 {chunked['ttft_p95_s']:.3f}s not "
                f"strictly below whole-prefill {whole['ttft_p95_s']:.3f}s")
    return failures


def check_spec_invariants(cur):
    """Baseline-free acceptance checks for --spec-cascade JSONs: the
    drafted cascade must keep accepting drafts, keep completions
    bit-equal to the undrafted path, and beat it strictly on the
    escalated tier's rounds (>= 30% cut) and on wall-clock."""
    failures = []
    for bench, row in cur.get("table", {}).items():
        plain, spec = row.get("no_draft"), row.get("draft_rejected")
        if not (isinstance(plain, dict) and isinstance(spec, dict)):
            continue
        if not row.get("accept_rate", 0) > 0:
            failures.append(f"{bench}: draft accept rate is zero — "
                            "verification committed nothing")
        if not row.get("completions_bitequal", False):
            failures.append(f"{bench}: drafted completions diverged from "
                            "the undrafted path (bit-identity violated)")
        if not row.get("equal_accuracy", False):
            failures.append(f"{bench}: drafted accuracy/tier histogram "
                            "diverged from the undrafted path")
        if not spec["wall_s"] < plain["wall_s"]:
            failures.append(
                f"{bench}: drafted wall {spec['wall_s']:.2f}s not strictly "
                f"below undrafted {plain['wall_s']:.2f}s")
        if not spec["rounds"] < plain["rounds"]:
            failures.append(
                f"{bench}: drafted rounds {spec['rounds']} not strictly "
                f"below undrafted {plain['rounds']}")
        limit = 0.7 * plain["escalated_rounds"]
        if not spec["escalated_rounds"] <= limit:
            failures.append(
                f"{bench}: escalated-tier rounds {spec['escalated_rounds']} "
                f"above the 30%-cut bar (<= {limit:.1f}, undrafted "
                f"{plain['escalated_rounds']})")
    return failures


def check_preempt_invariants(cur):
    """Baseline-free acceptance checks for --preempt JSONs: the tiny
    pool must force at least one full offload/resume cycle, preempted
    completions must be bit-equal to the ample-pool reference, and the
    preempting path must block admission strictly less often than the
    same pool without offload."""
    failures = []
    for bench, row in cur.get("table", {}).items():
        no_off, pre = row.get("no_offload"), row.get("preempt")
        if not (isinstance(no_off, dict) and isinstance(pre, dict)):
            continue
        if not pre.get("resumes", 0) > 0:
            failures.append(f"{bench}: zero resumes — the tiny pool never "
                            "forced an offload/resume cycle")
        if not row.get("completions_bitequal", False):
            failures.append(f"{bench}: preempted completions diverged from "
                            "the ample-pool reference (bit-identity "
                            "violated)")
        if not pre["admission_blocked"] < no_off["admission_blocked"]:
            failures.append(
                f"{bench}: preempting path blocked admission "
                f"{pre['admission_blocked']} time(s), not strictly below "
                f"the no-offload path's {no_off['admission_blocked']}")
    return failures


def check_shard_invariants(cur):
    """Baseline-free acceptance checks for --sharded JSONs: the mesh
    run must scale lane count >= 3x at bit-equal completions, and the
    tier-placement phase must show the escalation tier's slice decoding
    concurrently with tier 0's (overlap > 0 across the two un-fused
    loops) at equal accuracy.  The strict wall win over the serialized
    placement gates only when the producing rig had >= 2 host cores
    (``wall_gate_armed``) — on a single core both placements do the
    same total compute, so wall parity is the ceiling there."""
    failures = []
    for bench, row in cur.get("table", {}).items():
        sc, pl = row.get("scaling"), row.get("placement")
        if not (isinstance(sc, dict) and isinstance(pl, dict)):
            continue
        if not sc.get("completions_bitequal", False):
            failures.append(f"{bench}: sharded completions diverged from "
                            "the single-device oracle (bit-identity "
                            "violated)")
        if not sc.get("lane_scale", 0) >= 3:
            failures.append(
                f"{bench}: sharded lane scale {sc.get('lane_scale', 0):.1f}x "
                "below the 3x aggregate-lane bar")
        if not pl.get("equal_accuracy", False):
            failures.append(f"{bench}: placed-pipelined accuracy/tier "
                            "histogram diverged from the serialized "
                            "placement")
        pipe = pl.get("pipelined", {})
        if not pipe.get("n_loops", 0) == 2:
            failures.append(
                f"{bench}: disjoint tier slices ran {pipe.get('n_loops', 0)} "
                "host loop(s), expected 2 (placement did not un-fuse)")
        if not pipe.get("overlap_fraction", 0) > 0:
            failures.append(
                f"{bench}: zero overlap — the escalation tier's slice never "
                "decoded while tier 0's slice had rounds in flight")
        seq = pl.get("sequential", {})
        if pl.get("wall_gate_armed", False) and \
                not pipe.get("wall_s", 0) < seq.get("wall_s", 0):
            failures.append(
                f"{bench}: concurrent placement wall {pipe.get('wall_s', 0):.2f}s "
                f"not strictly below serialized {seq.get('wall_s', 0):.2f}s "
                f"on a {pl.get('host_cores')}-core rig")
    return failures


def check_hetero_invariants(cur):
    """Baseline-free acceptance checks for --hetero JSONs: the
    mixed-architecture cascade must keep accuracy/tier histogram equal
    to the per-tier barrier path, run one serving loop per architecture
    (n_loops == 2 — distinct cache protocols cannot fuse onto one lane
    pool), and account recurrent state exactly: the SSM tier's
    state-slot pool saturates at its cap with peak state bytes equal to
    peak slots x slot size (state is O(1) per lane — the pool never
    grows the way a KV block table does) while the transformer tier
    holds zero state slots, with every loop draining leak-clean."""
    failures = []
    for bench, row in cur.get("table", {}).items():
        ssm, attn = row.get("ssm_tier"), row.get("attn_tier")
        if not (isinstance(ssm, dict) and isinstance(attn, dict)):
            continue
        if not row.get("equal_accuracy", False):
            failures.append(f"{bench}: pipelined hetero accuracy/tier "
                            "histogram diverged from the per-tier barrier "
                            "path")
        pipe = row.get("pipelined", {})
        if not pipe.get("n_loops", 0) == 2:
            failures.append(
                f"{bench}: mixed architectures ran {pipe.get('n_loops', 0)} "
                "host loop(s), expected 2 (one per cache protocol)")
        if not ssm.get("state_slots", 0) > 0:
            failures.append(f"{bench}: the SSM tier reported no state-slot "
                            "pool — it did not serve under the state-slot "
                            "protocol")
        if not ssm.get("peak_state_slots", -1) == ssm.get("state_slots", 0):
            failures.append(
                f"{bench}: SSM tier peak slot occupancy "
                f"{ssm.get('peak_state_slots')} below its cap "
                f"{ssm.get('state_slots')} — demand never saturated the "
                "pool, so slot backpressure went unexercised")
        want = ssm.get("peak_state_slots", 0) * ssm.get("state_slot_bytes", 0)
        if not (ssm.get("state_slot_bytes", 0) > 0
                and ssm.get("peak_state_bytes", -1) == want):
            failures.append(
                f"{bench}: SSM tier peak state bytes "
                f"{ssm.get('peak_state_bytes')} != slots x slot size "
                f"{want} — recurrent state stopped being O(1) per lane")
        if not attn.get("state_slots", 1) == 0:
            failures.append(
                f"{bench}: the transformer tier holds "
                f"{attn.get('state_slots')} state slot(s) — the attention "
                "protocol must not carry a state-slot pool")
        if not row.get("leak_clean", False):
            failures.append(f"{bench}: a serving loop closed with a leak "
                            "report (blocks or state slots not drained)")
    return failures


def check_quant_invariants(cur, tol=0.1):
    """Baseline-free acceptance checks for --quant JSONs: the int8 tier
    must strictly undercut the fp32 tier on both KV-footprint metrics
    at an equal lane count, clear the efficiency bar (>= 1.7x
    lanes-per-HBM-byte, or >= 40% peak-KV cut), and hold fp32 accuracy
    within the relative ``tol``.  Token agreement with the fp32 stream
    is additionally floored at 0.25: quantized serving may legitimately
    diverge token by token, but near-zero agreement means the int8 path
    is not serving the same model anymore."""
    failures = []
    for bench, row in cur.get("table", {}).items():
        fp32, int8 = row.get("fp32"), row.get("int8")
        if not (isinstance(fp32, dict) and isinstance(int8, dict)):
            continue
        if not row.get("equal_lanes", False):
            failures.append(
                f"{bench}: lane counts differ (fp32 {fp32.get('n_lanes')} "
                f"vs int8 {int8.get('n_lanes')}) — the footprint "
                "comparison is only meaningful at equal lanes")
        for metric in ("peak_cache_bytes", "dense_cache_bytes"):
            if not int8[metric] < fp32[metric]:
                failures.append(
                    f"{bench}: int8 {metric} {int8[metric]} not strictly "
                    f"below fp32 {fp32[metric]}")
        gain = row.get("lanes_per_byte_gain", 0)
        cut = row.get("kv_bytes_cut", 0)
        if not (gain >= 1.7 or cut >= 0.4):
            failures.append(
                f"{bench}: efficiency bar missed — lanes/HBM-byte gain "
                f"{gain:.2f}x < 1.7x and peak-KV cut {cut:.0%} < 40%")
        limit = fp32["accuracy"] * (1 - tol)
        if not int8["accuracy"] >= limit:
            failures.append(
                f"{bench}: int8 accuracy {int8['accuracy']:.3f} below the "
                f"tolerance bound {limit:.3f} (fp32 {fp32['accuracy']:.3f} "
                f"at --tol {tol:.2f})")
        if not row.get("token_agreement", 0) >= 0.25:
            failures.append(
                f"{bench}: token agreement "
                f"{row.get('token_agreement', 0):.0%} below the 25% floor "
                "— the int8 tier no longer tracks the fp32 model")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh smoke JSON from this CI run")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--wall-slack", type=float, default=3.0,
                    help="allowed wall-clock factor over baseline "
                         "(runners differ; default 3.0)")
    ap.add_argument("--tol", type=float, default=0.1,
                    help="relative accuracy tolerance: accuracy / "
                         "token_agreement metrics may trail the baseline "
                         "by this fraction (default 0.10)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current run")
    args = ap.parse_args()

    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline} <- {args.current}")
        return 0

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    failures, rows = check_metrics(cur, base, args.wall_slack, args.tol)
    if cur.get("pipeline_cascade"):
        failures += check_pipeline_invariants(cur)
    if cur.get("chunked_serve"):
        failures += check_chunked_invariants(cur)
    if cur.get("spec_cascade"):
        failures += check_spec_invariants(cur)
    if cur.get("preempt_smoke"):
        failures += check_preempt_invariants(cur)
    if cur.get("sharded_smoke"):
        failures += check_shard_invariants(cur)
    if cur.get("quant_smoke"):
        failures += check_quant_invariants(cur, args.tol)
    if cur.get("hetero_smoke"):
        failures += check_hetero_invariants(cur)

    width = max((len(r[0]) for r in rows), default=20)
    print(f"{args.current} vs {args.baseline}:")
    for path, v, b, bound, ok in rows:
        print(f"  {'ok ' if ok else 'FAIL'} {path:{width}s} "
              f"{v:12.2f}  base {b:12.2f}  bound {bound}")
    if failures:
        print(f"\n{len(failures)} benchmark regression(s):")
        for msg in failures:
            print(f"  - {msg}")
        print("(after a deliberate change, refresh with: "
              f"python {sys.argv[0]} {args.current} {args.baseline} --update)")
        return 1
    print(f"no regressions ({len(rows)} metrics gated)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
