"""Explicit per-architecture decode-cache protocol.

The serving stack historically dispatched on the cache pytree's shape
("``block_tables`` present => paged attention"), which conflated two
independent axes: how *attention* KV is stored (dense per-lane rows vs
a block pool) and whether the model carries *recurrent* (conv + SSD)
state at all.  That implicit test mis-served anything that was not an
attention-only transformer: a pure-SSM model has no KV to page, a
hybrid has both kinds of state, and the scheduler's admission /
preemption / accounting paths each need to know which pieces exist.

:class:`CacheProtocol` names the three storage families explicitly:

``dense_attention``
    KV in per-lane dense ``(L, B, sc, KV, dh)`` rows
    (:func:`model.init_decode_state`).  Cost grows with ``sc``.
``paged_attention``
    KV in a shared block pool indexed through per-lane block tables
    (:func:`model.init_paged_decode_state`), host-managed by
    ``serving/block_pool.BlockPool``.  Cost grows with tokens written.
``state_slots``
    Per-lane recurrent state: conv tail ``(L, B, W, Cc)`` + SSD state
    ``(L, B, H, P, N)``.  O(1) per lane regardless of sequence length,
    so "paging" it means *slot accounting* (admission backpressure,
    preempt/offload byte tracking, leak audit —
    ``serving/block_pool.StateSlotPool``), not block indirection.

A config maps to a protocol via :func:`cache_protocol` (attention-only
=> one of the first two; mamba2 => state_slots only; hymba => KV family
+ state_slots).  :func:`protocol_of` recovers the protocol from a live
cache pytree — the jit-static replacement for the old ``"block_tables"
in cache`` test.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class CacheProtocol:
    """Which state families a decode cache carries, and how."""
    dense_attention: bool = False
    paged_attention: bool = False
    state_slots: bool = False

    @property
    def has_attention(self) -> bool:
        return self.dense_attention or self.paged_attention

    @property
    def hybrid(self) -> bool:
        return self.has_attention and self.state_slots


def cache_protocol(cfg: ModelConfig, paged: bool) -> CacheProtocol:
    """The protocol a scheduler with ``paged=<paged>`` serves ``cfg``
    under.  ``paged=True`` on a pure-SSM model means state-slot
    accounting only (there is no KV to page); on a hybrid it means
    paged KV *plus* state slots."""
    if not (cfg.has_attention or cfg.has_ssm):
        raise ValueError(f"{cfg.name}: no token mixer (neither attention "
                         "nor SSM) — nothing to cache")
    return CacheProtocol(
        dense_attention=cfg.has_attention and not paged,
        paged_attention=cfg.has_attention and paged,
        state_slots=cfg.has_ssm,
    )


def protocol_of(cache, cfg: ModelConfig) -> CacheProtocol:
    """Recover the protocol from a live cache pytree (static under jit:
    key presence is part of the pytree structure)."""
    return CacheProtocol(
        dense_attention=cfg.has_attention and "block_tables" not in cache,
        paged_attention=cfg.has_attention and "block_tables" in cache,
        state_slots=cfg.has_ssm,
    )
