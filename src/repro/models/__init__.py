from repro.models.model import (  # noqa: F401
    decode_step,
    forward,
    init_params,
    init_decode_state,
    lm_loss,
    prefill,
)
