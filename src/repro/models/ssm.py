"""Mamba2 SSD (state-space duality) block — chunked scan for training /
prefill, single-step recurrence for decode.

Block: in_proj -> [z | x | B | C | dt] -> causal depthwise conv1d on
(x|B|C) -> SiLU -> SSD -> gated RMSNorm(z) -> out_proj.

SSD semantics (per head h, state width N, head dim P):
    h_t = exp(dt_t * A) * h_{t-1} + B_t (x_t * dt_t)^T
    y_t = C_t . h_t + D * x_t
The chunked algorithm computes intra-chunk contributions with a masked
(C B^T) "attention" matrix and carries inter-chunk states with lax.scan —
this is the structure the Pallas kernel (kernels/ssd) tiles for VMEM.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

G = 1  # n_groups for B/C projections


def ssm_dims(cfg: ModelConfig):
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.n_ssm_heads
    conv_ch = di + 2 * G * n
    proj_out = 2 * di + 2 * G * n + h
    return di, n, h, conv_ch, proj_out


def ssm_init(cfg: ModelConfig, key, dtype):
    di, n, h, conv_ch, proj_out = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    # dt bias: softplus^-1 of dt in [1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (h,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt = jnp.exp(u)
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    a_init = jax.random.uniform(ks[3], (h,), minval=1.0, maxval=16.0)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, 1, conv_ch)) /
                   math.sqrt(cfg.ssm_conv_width)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(a_init).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(jax.random.split(ks[0])[1], di, cfg.d_model, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, n, h, _, _ = ssm_dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * G * n]
    dt = zxbcdt[..., di + di + 2 * G * n:]
    return z, xbc, dt


def _conv_full(p, u):
    """Causal depthwise conv over (B, S, C)."""
    w = p["conv_w"]                                       # (W, 1, C)
    width = w.shape[0]
    out = jax.lax.conv_general_dilated(
        u, w.astype(u.dtype),
        window_strides=(1,),
        padding=[(width - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=u.shape[-1],
    )
    return out + p["conv_b"].astype(u.dtype)


def _conv_valid(p, full):
    """Depthwise conv over pre-concatenated (B, W-1+S, C) inputs.

    The caller prepends the W-1 context rows (zeros for a fresh
    sequence, the carried conv state's tail for a chunk continuation),
    so a VALID conv yields exactly S causal outputs.  One code path
    serves training, whole-prompt prefill, and chunked prefill — each
    output position is the same width-W dot product regardless of where
    its window's inputs came from."""
    w = p["conv_w"]                                       # (W, 1, C)
    out = jax.lax.conv_general_dilated(
        full, w.astype(full.dtype),
        window_strides=(1,),
        padding=[(0, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=full.shape[-1],
    )
    return out + p["conv_b"].astype(full.dtype)


def _conv_step(p, conv_state, u_t):
    """conv_state: (B, W, C) last W inputs INCLUDING current after update."""
    conv_state = jnp.concatenate([conv_state[:, 1:], u_t[:, None]], axis=1)
    w = p["conv_w"][:, 0, :].astype(u_t.dtype)            # (W, C)
    y = jnp.einsum("bwc,wc->bc", conv_state, w) + p["conv_b"].astype(u_t.dtype)
    return conv_state, y


# ----------------------------------------------------------------------
# SSD core
# ----------------------------------------------------------------------

def ssd_chunked(xbar, a, b, c, chunk: int, init_state=None):
    """Chunked SSD scan (pure-jnp oracle shared with kernels/ssd/ref.py).

    xbar: (B,S,H,P)  -- x * dt
    a:    (B,S,H)    -- dt * A  (log-decay, <= 0)
    b,c:  (B,S,G,N)  -- broadcast over heads
    Returns y (B,S,H,P), final_state (B,H,P,N).
    """
    bsz, s, h, p_dim = xbar.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    t = xbar.shape[1] // chunk
    q = chunk
    xb = xbar.reshape(bsz, t, q, h, p_dim).astype(jnp.float32)
    ab = a.reshape(bsz, t, q, h).astype(jnp.float32)
    bb = b.reshape(bsz, t, q, G, n).astype(jnp.float32)
    cb = c.reshape(bsz, t, q, G, n).astype(jnp.float32)

    cum_a = jnp.cumsum(ab, axis=2)                                    # (B,T,Q,H)

    # intra-chunk: scores[i,j] = (C_i . B_j) exp(cum_a[i]-cum_a[j]), j <= i
    dec = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]           # (B,T,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    cb_h = cb[:, :, :, 0, :]                                          # (B,T,Q,N) (G=1)
    bb_h = bb[:, :, :, 0, :]
    scores = jnp.einsum("btin,btjn->btij", cb_h, bb_h)                # (B,T,Qi,Qj)
    # w is the one O(Q^2 * H) tensor; when the model computes in bf16,
    # keep it bf16 with f32 accumulation (hymba prefill_32k: ~8 GB/dev
    # saved).  f32 inputs (CPU-scale models, kernel oracle) stay f32.
    wdt = jnp.bfloat16 if xbar.dtype == jnp.bfloat16 else jnp.float32
    w = (scores[..., None] * jnp.exp(dec)).astype(wdt)                # (B,T,Qi,Qj,H)
    y_intra = jnp.einsum("btijh,btjhp->btihp", w, xb.astype(wdt),
                         preferred_element_type=jnp.float32)

    # chunk states: S_t = sum_j exp(cum_a[last]-cum_a[j]) B_j (xbar_j)^T
    dec_end = jnp.exp(cum_a[:, :, -1:, :] - cum_a)                    # (B,T,Q,H)
    state_t = jnp.einsum("btjn,btjh,btjhp->bthpn", bb_h, dec_end, xb)  # (B,T,H,P,N)

    # inter-chunk recurrence
    a_tot = jnp.exp(cum_a[:, :, -1, :])                               # (B,T,H)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p_dim, n), jnp.float32)
    else:
        init_state = init_state.astype(jnp.float32)

    def step(hprev, xs):
        at, st = xs                                                   # (B,H), (B,H,P,N)
        hnew = hprev * at[:, :, None, None] + st
        return hnew, hprev

    hlast, h_before = jax.lax.scan(
        step, init_state,
        (jnp.swapaxes(a_tot, 0, 1), jnp.swapaxes(state_t, 0, 1)))
    h_before = jnp.swapaxes(h_before, 0, 1)                           # (B,T,H,P,N)

    # inter-chunk output: y_i += C_i . (exp(cum_a[i]) * h_before)
    y_inter = jnp.einsum("btin,bthpn,btih->btihp",
                         cb_h, h_before, jnp.exp(cum_a))
    y = (y_intra + y_inter).reshape(bsz, t * q, h, p_dim)[:, :s]
    return y, hlast


def ssd_decode_step(xbar_t, a_t, b_t, c_t, state):
    """One-step recurrence.

    xbar_t: (B,H,P); a_t: (B,H); b_t/c_t: (B,G,N); state: (B,H,P,N).
    """
    b_h = b_t[:, 0, :]                                                # (B,N)
    c_h = c_t[:, 0, :]
    state = (state * jnp.exp(a_t.astype(jnp.float32))[:, :, None, None]
             + jnp.einsum("bhp,bn->bhpn", xbar_t.astype(jnp.float32),
                          b_h.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", state, c_h.astype(jnp.float32))
    return y, state


# ----------------------------------------------------------------------
# Layer-level entry points
# ----------------------------------------------------------------------

def _ssd_inputs(cfg: ModelConfig, p, xbc_conv, dt_raw, valid=None):
    """Split post-conv channels and build SSD inputs.

    ``valid`` (broadcastable to dt's shape) zeroes dt at padding
    positions: with dt=0 both xbar (= x*dt) and a (= dt*A) vanish, so a
    pad step contributes nothing to the state and decays nothing
    (exp(0)=1) — the final state is exactly the state at the last valid
    position.  Valid positions multiply dt by 1.0, which is exact, so
    masking never perturbs real outputs."""
    di, n, h, _, _ = ssm_dims(cfg)
    p_dim = cfg.ssm_head_dim
    xs = xbc_conv[..., :di]
    b = xbc_conv[..., di:di + G * n]
    c = xbc_conv[..., di + G * n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    if valid is not None:
        dt = dt * valid.astype(dt.dtype)
    a_neg = -jnp.exp(p["A_log"])                                      # (H,) < 0
    shp = xs.shape[:-1]
    xh = xs.reshape(*shp, h, p_dim)
    xbar = xh * dt[..., None]
    a = dt * a_neg
    return xh, xbar, a, b.reshape(*shp, G, n), c.reshape(*shp, G, n), dt


def ssm_forward(cfg: ModelConfig, p, x, init_state=None, init_conv=None,
                positions=None, lengths=None):
    """Full-sequence SSM mixer.  x: (B,S,D).

    Returns y (B,S,D), (conv_state (B,W,Cc), ssm_state (B,H,P,N)).

    ``init_state`` / ``init_conv`` carry SSD and conv state from a
    previous call (chunked prefill): ``init_conv`` is the (B, W, Cc)
    raw pre-conv inputs exactly as a previous call returned them —
    row m is the input at chunk-local position m - W, so the conv
    window of this call's first outputs reads the previous chunk's
    tail instead of zeros.  ``positions`` (B,S) are the tokens'
    absolute positions (default arange) and ``lengths`` (B,) the
    per-row total valid length: positions >= lengths are padding and
    are masked out of the state recurrence (dt -> 0), so the returned
    states are exactly the states at each row's last valid position
    and the returned conv state gathers the last W *valid* inputs.
    """
    from repro.models.layers import rmsnorm_gated
    cdt = jnp.dtype(cfg.compute_dtype)
    di, n, h, conv_ch, _ = ssm_dims(cfg)
    width = cfg.ssm_conv_width
    x = x.astype(cdt)
    zxbcdt = x @ p["in_proj"].astype(cdt)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    bsz, s, _ = xbc.shape
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
    # causal conv with explicit left context: the W-1 inputs before
    # this call's window (zeros for a fresh sequence)
    if init_conv is None:
        prev = jnp.zeros((bsz, width, conv_ch), xbc.dtype)
    else:
        prev = init_conv.astype(xbc.dtype)
    xbc_c = jax.nn.silu(_conv_valid(
        p, jnp.concatenate([prev[:, 1:], xbc], axis=1)))
    # final conv state: the last W raw inputs at each row's valid end.
    # state_src[i] is the input at chunk-local position i - W, so the
    # window [end, end + W) is the inputs at [end - W, end) — for a
    # short row it mixes carried context and fresh inputs.
    state_src = jnp.concatenate([prev, xbc], axis=1)       # (B, W+S, Cc)
    if lengths is None:
        end = jnp.full((bsz,), s, jnp.int32)
        valid = None
    else:
        end = jnp.clip(lengths - positions[:, 0], 0, s).astype(jnp.int32)
        valid = (positions < lengths[:, None])[..., None]   # (B,S,1) vs dt (B,S,H)
    idx = end[:, None] + jnp.arange(width, dtype=jnp.int32)[None]
    conv_state = jnp.take_along_axis(
        state_src, idx[:, :, None], axis=1)                # (B, W, Cc)
    xh, xbar, a, b, c, dt = _ssd_inputs(cfg, p, xbc_c, dt_raw, valid)
    y, ssm_state = ssd_chunked(xbar, a, b, c, cfg.ssm_chunk, init_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(cdt)
    y = rmsnorm_gated(p["norm_scale"], y, z)
    return y @ p["out_proj"].astype(cdt), (conv_state, ssm_state)


def ssm_decode(cfg: ModelConfig, p, x_t, conv_state, ssm_state):
    """One-token step.  x_t: (B,1,D) -> (y (B,1,D), new states)."""
    from repro.models.layers import rmsnorm_gated
    cdt = jnp.dtype(cfg.compute_dtype)
    di, n, h, conv_ch, _ = ssm_dims(cfg)
    x_t = x_t[:, 0].astype(cdt)                                       # (B,D)
    zxbcdt = x_t @ p["in_proj"].astype(cdt)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_state, xbc_c = _conv_step(p, conv_state, xbc)
    xbc_c = jax.nn.silu(xbc_c)
    xh, xbar, a, b, c, dt = _ssd_inputs(cfg, p, xbc_c, dt_raw)
    y, ssm_state = ssd_decode_step(xbar, a, b, c, ssm_state)
    y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(-1, di).astype(cdt)
    y = rmsnorm_gated(p["norm_scale"], y, z)
    y = y @ p["out_proj"].astype(cdt)
    return y[:, None, :], (conv_state, ssm_state)
