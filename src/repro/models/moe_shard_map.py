"""Expert-parallel MoE dispatch with an explicit shard_map all-to-all
schedule (§Perf track B's identified next move).

GSPMD lowers the capacity scatter/gather as full-buffer gathers and
reshards (~2x the necessary bytes; EXPERIMENTS.md §Perf B1–B5).  This
module makes the communication explicit and minimal:

  1. each device routes + capacity-dispatches ITS OWN token slice
     (tokens are additionally split across the 'model' axis so the 16
     model-replicas don't duplicate router work),
  2. one all-to-all over 'model' moves token buffers to the devices
     owning their experts,
  3. local (E_loc, C, D) x (E_loc, D, F) einsums — weights never move,
  4. the reverse all-to-all + a local combine + one all-gather restore
     the token-major layout.

Per-device collective bytes ~= 2 x |dispatch slice| + |token slice|,
independent of the expert count.  Differentiable (collective transposes
exist), so the same path serves train steps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

try:                                    # newer JAX exports it at top level
    from jax import shard_map
except ImportError:                     # older releases: experimental module
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _act

# set by the launcher (dryrun/train/serve) before lowering; model code
# cannot otherwise see the mesh from inside jit.
_MESH = None


def set_mesh(mesh):
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def _batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def apply_moe_shard_map(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (out, aux).  Requires set_mesh() with a mesh whose
    'model' axis divides n_experts."""
    mesh = get_mesh()
    msz = int(mesh.shape["model"])
    bax = _batch_axes(mesh)
    all_axes = tuple(mesh.shape.keys())
    cdt = jnp.dtype(cfg.compute_dtype)
    e, k = cfg.n_experts, cfg.moe_top_k
    b, s, d = x.shape

    def body(xb, router, wi_gate, wi_up, wo):
        bl, sl, _ = xb.shape
        t_loc = bl * sl
        xf = xb.reshape(t_loc, d).astype(cdt)
        # split this device's tokens across the model axis (the input is
        # replicated over 'model'); pad so the chunk divides evenly
        chunk = -(-t_loc // msz)
        pad = chunk * msz - t_loc
        if pad:
            xf = jnp.pad(xf, ((0, pad), (0, 0)))
        r = jax.lax.axis_index("model")
        xt = jax.lax.dynamic_slice_in_dim(xf, r * chunk, chunk, axis=0)

        logits = xt.astype(jnp.float32) @ router                  # (Tc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        gate = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

        # aux losses over the LOCAL token slice, averaged across devices
        me = jnp.mean(probs, axis=0)
        assign = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
            1.0) / (chunk * k)
        lb_loss = e * jnp.sum(me * assign)
        z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

        # local capacity dispatch (capacity per token-chunk)
        import math
        cap = max(k, int(math.ceil(
            cfg.moe_capacity_factor * chunk * k / e)))
        cap = cap + (-cap) % msz            # a2a needs cap % msz == 0
        flat_e = top_i.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]
        keep = pos_in_e < cap
        pos_safe = jnp.where(keep, pos_in_e, cap)
        tok_idx = jnp.repeat(jnp.arange(chunk), k)
        xd = jnp.zeros((e, cap, d), cdt).at[flat_e, pos_safe].set(
            xt[tok_idx], mode="drop")                              # (E,C,D)

        # ---- all-to-all: expert-major -> expert-local ----
        xd = jax.lax.all_to_all(xd, "model", split_axis=0,
                                concat_axis=1, tiled=True)        # (E/m, C*m, D)

        h = _act(cfg.activation,
                 jnp.einsum("ecd,edf->ecf", xd, wi_gate.astype(cdt)))
        h = h * jnp.einsum("ecd,edf->ecf", xd, wi_up.astype(cdt))
        ye = jnp.einsum("ecf,efd->ecd", h, wo.astype(cdt))        # (E/m,C*m,D)

        # ---- reverse all-to-all ----
        ye = jax.lax.all_to_all(ye, "model", split_axis=1,
                                concat_axis=0, tiled=True)        # (E,C,D)

        y_tok = ye.at[flat_e, pos_safe].get(mode="fill", fill_value=0)
        y_tok = y_tok * (keep[:, None] * gate.reshape(-1)[:, None]).astype(cdt)
        out_t = jnp.sum(y_tok.reshape(chunk, k, d), axis=1)       # (Tc, D)

        # reassemble all token chunks on every model replica
        out = jax.lax.all_gather(out_t, "model", axis=0, tiled=True)
        if pad:
            out = out[:t_loc]
        out = out.reshape(bl, sl, d)

        frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
        aux = {
            "moe_lb": cfg.moe_aux_loss_coef * lb_loss,
            "moe_z": cfg.moe_router_z_coef * z_loss,
            "moe_dropped": frac_dropped,
        }
        aux = {kk: jax.lax.pmean(v, all_axes) for kk, v in aux.items()}
        return out, aux

    # replication checking was renamed check_rep -> check_vma across JAX
    # releases; disable it under whichever name this JAX understands.
    import inspect
    check_kw = ("check_vma" if "check_vma" in
                inspect.signature(shard_map).parameters else "check_rep")
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bax, None, None),            # x
                  P(None, None),                 # router (replicated)
                  P("model", None, None),        # wi_gate (E sharded)
                  P("model", None, None),        # wi_up
                  P("model", None, None)),       # wo
        out_specs=(P(bax, None, None),
                   {"moe_lb": P(), "moe_z": P(), "moe_dropped": P()}),
        **{check_kw: False})
    return fn(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
