"""Mixture-of-Experts: top-k router with capacity-based dispatch.

Dispatch is the GShard/Switch capacity scheme done with gather/scatter
(no (T, E, C) one-hot dispatch tensor):

  1. router softmax over E experts, top-k per token,
  2. position-in-expert via cumsum of assignment one-hots,
  3. tokens beyond capacity C = ceil(cf * T * k / E) are dropped,
  4. scatter into an (E, C, D) buffer, expert-sharded einsum FFN,
  5. gather back and combine with router weights.

The (E, C, D) buffer is what pjit shards over the ``model`` axis (expert
dim) — the all-to-all emerges from the scatter/gather resharding.
Aux losses: Switch load-balance loss + router z-loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _act, dense_init, mlp_init, apply_mlp


def moe_init(cfg: ModelConfig, key, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)

    def ew(k, din, dout, scale):
        return (jax.random.normal(k, (e, din, dout)) * scale).astype(dtype)

    p = {"router": dense_init(ks[0], d, e, jnp.float32)}
    if cfg.mlp_gated:
        p["wi_gate"] = ew(ks[1], d, f, scale_in)
        p["wi_up"] = ew(ks[2], d, f, scale_in)
        p["wo"] = ew(ks[3], f, d, scale_out)
    else:
        p["wi"] = ew(ks[1], d, f, scale_in)
        p["wo"] = ew(ks[2], f, d, scale_out)
    if cfg.moe_shared_expert:
        p["shared"] = mlp_init(cfg, ks[4], d, cfg.d_ff, dtype)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.moe_capacity_factor * n_tokens * cfg.moe_top_k / cfg.n_experts))
    return max(cfg.moe_top_k, min(c, n_tokens))


def apply_moe(cfg: ModelConfig, p, x, dropless: bool = False):
    """x: (B, S, D) -> (out (B,S,D), aux dict of scalars).

    With cfg.moe_dispatch_chunks > 1 the token stream is processed in
    chunks via lax.scan, bounding the (E, C, D) dispatch buffers (and the
    position-in-expert cumsum) to one chunk at a time — at 1M-token
    prefill the unchunked buffers alone are tens of GB/device (olmoe:
    145 GB/dev -> fits after chunking; EXPERIMENTS.md §Perf).

    ``dropless=True`` (inference/serving) sizes capacity so no token is
    ever dropped, making each token's output independent of the batch
    composition — the serving determinism contract.  Training keeps the
    capacity scheme (and its load-balance pressure)."""
    b, s, d = x.shape
    if cfg.moe_shard_map and cfg.mlp_gated and not dropless:
        from repro.models import moe_shard_map as msm
        mesh = msm.get_mesh()
        if mesh is not None and cfg.n_experts % int(mesh.shape["model"]) == 0:
            out, aux = msm.apply_moe_shard_map(cfg, p, x)
            if cfg.moe_shared_expert:
                # the shared expert is dense — GSPMD tensor parallelism
                # handles it fine outside the shard_map region
                out = out + apply_mlp(cfg, p["shared"],
                                      x.astype(jnp.dtype(cfg.compute_dtype)))
            return out, aux
    nc = cfg.moe_dispatch_chunks
    t = b * s
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    if nc > 1 and s % nc != 0:
        # train steps see S-1 tokens (next-token shift): pick the
        # smallest divisor of s giving at least the requested chunk
        # count, else fall back to unchunked.  (4095 % 4 != 0 silently
        # disabling the chunking cost 25 GB/dev on olmoe train — §Perf.)
        nc = next((c for c in range(nc, min(4 * nc, s) + 1) if s % c == 0), 1)
    if nc > 1 and s % nc == 0 and (t // nc) >= cfg.n_experts:
        # Chunk along the SEQUENCE dim: each (B, S/nc, D) slice keeps the
        # batch dim (and hence the data sharding) intact.  Chunking the
        # flat token stream instead makes every chunk live on a few
        # devices and GSPMD all-gathers the whole stream (17 GB/dev on
        # olmoe prefill — EXPERIMENTS.md §Perf).
        xs = jnp.swapaxes(x.reshape(b, nc, s // nc, d), 0, 1)  # (nc,B,S/nc,D)
        if cfg.shard_moe_dispatch:
            from jax.sharding import PartitionSpec as P
            U = P.UNCONSTRAINED
            xs = jax.lax.with_sharding_constraint(xs, P(None, "data", U, U))

        def one(carry, xc):
            bc, sc, _ = xc.shape
            out_c, aux_c = _moe_tokens(cfg, p, xc.reshape(bc * sc, d),
                                       dropless)
            return carry, (out_c.reshape(bc, sc, d), aux_c)

        _, (outs, auxs) = jax.lax.scan(one, 0, xs)
        out = jnp.swapaxes(outs, 0, 1).reshape(b, s, d)
        aux = jax.tree.map(jnp.mean, auxs)
        return out, aux
    out, aux = _moe_tokens(cfg, p, x.reshape(t, d), dropless)
    return out.reshape(b, s, d), aux


def _moe_tokens(cfg: ModelConfig, p, xf, dropless: bool = False):
    """Core top-k capacity dispatch on a flat token batch (T, D).

    ``dropless=True`` sets capacity to T itself: top_k assigns a token
    to an expert at most once, so position-in-expert is at most T-1 and
    ``keep`` is all-true — nothing drops, and because the (E, C, D)
    expert einsum treats each (e, c) row independently, every token's
    output is bitwise independent of which other tokens share the
    batch (the decode-lane-count invariance serving relies on)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    xf = xf.astype(cdt)

    logits = (xf.astype(jnp.float32) @ p["router"])                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                           # (T, k)
    gate = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # --- aux losses ---
    me = jnp.mean(probs, axis=0)                                     # (E,)
    assign = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
    lb_loss = e * jnp.sum(me * assign)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # --- capacity dispatch ---
    cap = t if dropless else moe_capacity(cfg, t)
    flat_e = top_i.reshape(-1)                                       # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)              # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                        # pos BEFORE this row
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    pos_safe = jnp.where(keep, pos_in_e, cap)                        # cap => dropped

    tok_idx = jnp.repeat(jnp.arange(t), k)
    xd = jnp.zeros((e, cap, d), cdt)
    xd = xd.at[flat_e, pos_safe].set(xf[tok_idx], mode="drop")       # (E, C, D)
    if cfg.shard_moe_dispatch:
        from jax.sharding import PartitionSpec as P
        U = P.UNCONSTRAINED
        if cfg.param_count() > 3e10 and d % 16 == 0:
            # FSDP-scale MoE (llama4-scout, ~109B): the expert weights'
            # d_model dim is data-sharded; shard the dispatch buffer's D
            # dim the same way so the expert einsum contracts local
            # slices with a partial-sum all-reduce instead of
            # all-gathering 4 GB of expert weights per layer.
            xd = jax.lax.with_sharding_constraint(xd, P("model", U, "data"))
        else:
            # NOTE: additionally data-sharding the capacity dim was tried
            # (hoping the scatter lowers as all-to-all) and REFUTED:
            # -3% temp on prefill but +112% collective bytes (GSPMD
            # lowers it as gather+reshard).  EXPERIMENTS.md §Perf B5.
            xd = jax.lax.with_sharding_constraint(xd, P("model", U, U))

    if cfg.mlp_gated:
        h = _act(cfg.activation, jnp.einsum("ecd,edf->ecf", xd, p["wi_gate"].astype(cdt)))
        h = h * jnp.einsum("ecd,edf->ecf", xd, p["wi_up"].astype(cdt))
    else:
        h = _act(cfg.activation, jnp.einsum("ecd,edf->ecf", xd, p["wi"].astype(cdt)))
    if cfg.shard_moe_dispatch and cfg.param_count() > 3e10:
        from jax.sharding import PartitionSpec as P
        U = P.UNCONSTRAINED
        # keep the hidden dim data-sharded to match wo's FSDP'd F dim
        f_ax = "data" if h.shape[-1] % 16 == 0 else U
        h = jax.lax.with_sharding_constraint(h, P("model", U, f_ax))
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cdt))          # (E, C, D)
    if cfg.shard_moe_dispatch:
        from jax.sharding import PartitionSpec as P
        U = P.UNCONSTRAINED
        ye = jax.lax.with_sharding_constraint(ye, P("model", U, U))

    y_tok = ye.at[flat_e, pos_safe].get(mode="fill", fill_value=0)   # (T*k, D)
    y_tok = y_tok * (keep[:, None] * gate.reshape(-1)[:, None]).astype(cdt)
    out = jnp.sum(y_tok.reshape(t, k, d), axis=1)

    if cfg.moe_shared_expert:
        out = out + apply_mlp(cfg, p["shared"], xf)

    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {
        "moe_lb": cfg.moe_aux_loss_coef * lb_loss,
        "moe_z": cfg.moe_router_z_coef * z_loss,
        "moe_dropped": frac_dropped,
    }
    return out, aux
