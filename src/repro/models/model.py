"""Unified decoder LM covering all assigned architecture families.

Layers are stacked on a leading axis and executed with ``jax.lax.scan``
so compile time is depth-independent (crucial for the 48-layer dry-run
configs on the CPU host).  Per-layer heterogeneity (gemma3 5:1
local:global windows) flows through the scan as a per-layer window array.

Entry points:
  * init_params(cfg, key)
  * forward(params, cfg, tokens|embeds, positions)        -> logits, aux
  * prefill(params, cfg, tokens|embeds, positions)        -> logits, cache
  * decode_step(params, cfg, tokens, cache)               -> logits, cache
  * init_decode_state(cfg, batch, cache_len)              -> empty cache
  * init_paged_decode_state(cfg, batch, s_max, bs, n_blk) -> paged cache
  * lm_loss(cfg, logits, labels, mask, aux)               -> scalar, metrics
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_init,
    embed_tokens,
    logits_from_hidden,
    mlp_init,
    norm_init,
)


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 5)
    p = {"norm1": norm_init(cfg, cfg.d_model, dtype)}
    if cfg.has_attention:
        p["attn"] = attn_mod.attn_init(cfg, ks[0], dtype)
    if cfg.has_ssm:
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[1], dtype)
    if cfg.is_moe:
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["moe"] = moe_mod.moe_init(cfg, ks[2], dtype)
    elif cfg.d_ff:
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["mlp"] = mlp_init(cfg, ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers, k_final = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys)
    return {
        "embed": embed_init(cfg, k_embed, dtype),
        "layers": layers,
        "final_norm": norm_init(cfg, cfg.d_model, dtype),
    }


# ----------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------

def _zero_aux(cfg: ModelConfig):
    return {"moe_lb": jnp.float32(0.0), "moe_z": jnp.float32(0.0),
            "moe_dropped": jnp.float32(0.0)}


def _maybe_seq_shard(cfg: ModelConfig, x):
    """§Perf: constrain the residual stream to be sequence-sharded over
    the 'model' axis (GSPMD then uses reduce-scatter/all-gather around
    the tensor-parallel matmuls instead of full all-reduces)."""
    if not cfg.seq_shard_activations or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    U = P.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(x, P(U, "model", U))


def _mixer_forward(cfg: ModelConfig, lp, x, positions, window, lengths=None):
    """Token mixer (attention / ssm / both), full sequence.

    Returns (mix_out, cache_parts) where cache_parts has the per-layer
    state needed for decode (k/v and/or conv/ssm states).  ``lengths``
    (B,), when given, marks positions >= lengths as right-padding the
    SSM state recurrence must skip — without it a padded prompt's
    conv/SSD states absorb pad tokens (attention masks padding by
    position; SSM state is cumulative, so it needs the explicit mask).
    """
    parts = {}
    h = apply_norm(cfg, lp["norm1"], x)
    outs = []
    if cfg.has_attention:
        a_out, (k, v) = attn_mod.attention_forward(cfg, lp["attn"], h, positions, window)
        outs.append(a_out)
        parts["k"], parts["v"] = k, v
    if cfg.has_ssm:
        s_out, (conv_state, ssm_state) = ssm_mod.ssm_forward(
            cfg, lp["ssm"], h, positions=positions, lengths=lengths)
        outs.append(s_out)
        parts["conv"], parts["ssm"] = conv_state, ssm_state
    if len(outs) == 2:       # hymba: parallel heads, mean-fused
        mix = (outs[0] + outs[1]) * 0.5
    else:
        mix = outs[0]
    return mix, parts


def _channel_forward(cfg: ModelConfig, lp, x, dropless: bool = False):
    """FFN / MoE sublayer.  Returns (out, aux).

    ``dropless=True`` — every inference entry point (prefill, chunked
    prefill, decode, verify) — makes MoE capacity cover all tokens, so
    a token's output never depends on the batch it shares a forward
    pass with (the serving determinism contract).  Training keeps the
    capacity scheme."""
    if cfg.is_moe:
        h = apply_norm(cfg, lp["norm2"], x)
        return moe_mod.apply_moe(cfg, lp["moe"], h, dropless=dropless)
    if cfg.d_ff:
        h = apply_norm(cfg, lp["norm2"], x)
        return apply_mlp(cfg, lp["mlp"], h), None
    return None, None


def _block_forward(cfg: ModelConfig, lp, x, positions, window, lengths=None,
                   dropless: bool = False):
    mix, parts = _mixer_forward(cfg, lp, x, positions, window, lengths)
    x = _maybe_seq_shard(cfg, x + mix)
    ch, aux = _channel_forward(cfg, lp, x, dropless)
    if ch is not None:
        x = _maybe_seq_shard(cfg, x + ch)
    return x, parts, aux


# ----------------------------------------------------------------------
# Full-sequence forward (training) — no cache retained
# ----------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None,
            return_hidden: bool = False):
    """Returns (logits (B,S,V), aux dict) or (logits, aux, hidden)."""
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = embed_tokens(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def block(carry, layer):
        x, aux = carry
        lp, window = layer
        x, _, la = _block_forward(cfg, lp, x, positions, window)
        if la is not None:
            aux = {k: aux[k] + la[k] for k in aux}
        return (x, aux), None

    if cfg.remat:
        block = jax.checkpoint(block, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(block, (x, _zero_aux(cfg)),
                               (params["layers"], windows))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params["embed"], x)
    if cfg.is_moe:
        aux = dict(aux)
        aux["moe_dropped"] = aux["moe_dropped"] / cfg.n_layers
    if return_hidden:
        return logits, aux, x
    return logits, aux


def _maybe_vocab_shard(cfg: ModelConfig, logits):
    """Constrain the logits' vocab dim to the 'model' mesh axis.

    With a 128k-262k vocab, unsharded (B,S,V) logits alone exceed HBM at
    train_4k scale (e.g. gemma3: 65k tok/dev x 262144 x 2B = 34 GB/dev).
    The embedding is already vocab-sharded, so constraining the logits
    keeps the whole loss pipeline sharded; the softmax reductions below
    then lower to tiny (B,S) all-reduces over 'model'."""
    if not cfg.shard_logits_vocab or logits.ndim != 3:
        return logits
    from jax.sharding import PartitionSpec as P
    U = P.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(logits, P(U, U, "model"))


def lm_loss(cfg: ModelConfig, logits, labels, mask, aux=None):
    """Mean cross-entropy over masked positions + MoE aux losses.

    Written as explicit max / exp-sum / one-hot-dot reductions (instead
    of log_softmax + take_along_axis) so that (a) no f32 (B,S,V) array
    has to be materialized — XLA fuses the exp into the reduce — and (b)
    every reduction is over the (possibly 'model'-sharded) vocab axis,
    keeping cross-shard traffic at O(B*S) stats instead of all-gathering
    logits."""
    logits = _maybe_vocab_shard(cfg, logits)
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)                      # (B,S,1)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]  # (B,S)
    onehot = labels[..., None] == jnp.arange(v, dtype=labels.dtype)
    label_logit = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)   # (B,S)
    ll = label_logit - lse
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = -jnp.sum(ll * mask) / denom
    loss = ce
    metrics = {"ce": ce, "n_tokens": jnp.sum(mask)}
    if aux is not None and cfg.is_moe:
        loss = loss + aux["moe_lb"] + aux["moe_z"]
        metrics.update({k: aux[k] for k in ("moe_lb", "moe_z", "moe_dropped")})
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    metrics["token_acc"] = acc
    return loss, metrics


# ----------------------------------------------------------------------
# Cache
# ----------------------------------------------------------------------

def cache_length(cfg: ModelConfig, seq_len: int) -> int:
    """Uniform per-layer cache length.

    If every attention layer is windowed (sliding variant), the cache is
    a ring buffer of the max window; any global layer forces full length.
    """
    if not cfg.has_attention:
        return 0
    windows = cfg.layer_windows()
    if all(w > 0 for w in windows):
        return min(seq_len, max(windows))
    return seq_len


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                      cache_dtype=None):
    """Empty cache sized for sequences up to seq_len.

    cfg.kv_quant stores k/v in int8 with a per-(slot, kv-head) f32
    absmax scale — halves the decode memory term (the dominant roofline
    term for decode_32k after the §Perf cache fixes)."""
    cdt = cache_dtype or jnp.dtype(cfg.compute_dtype)
    L = cfg.n_layers
    cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.has_attention:
        sc = cache_length(cfg, seq_len)
        dh = cfg.resolved_head_dim
        kv_dt = jnp.int8 if cfg.kv_quant else cdt
        cache["k"] = jnp.zeros((L, batch, sc, cfg.n_kv_heads, dh), kv_dt)
        cache["v"] = jnp.zeros((L, batch, sc, cfg.n_kv_heads, dh), kv_dt)
        if cfg.kv_quant:
            cache["k_scale"] = jnp.zeros((L, batch, sc, cfg.n_kv_heads),
                                         jnp.float32)
            cache["v_scale"] = jnp.zeros((L, batch, sc, cfg.n_kv_heads),
                                         jnp.float32)
        cache["cache_pos"] = jnp.full((batch, sc), -1, jnp.int32)
    if cfg.has_ssm:
        di, n, h, conv_ch, _ = ssm_mod.ssm_dims(cfg)
        cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv_width, conv_ch), cdt)
        cache["ssm"] = jnp.zeros((L, batch, h, cfg.ssm_head_dim, n), jnp.float32)
    return cache


def init_paged_decode_state(cfg: ModelConfig, batch: int, s_max: int,
                            block_size: int, n_blocks: int,
                            cache_dtype=None):
    """Empty block-paged decode cache (the paged variant of
    :func:`init_decode_state`).

    K/V live in a shared pool of ``n_blocks`` allocatable blocks of
    ``block_size`` slots (plus block 0, the trash block that absorbs
    writes from evicted lanes), indexed per lane through a
    ``(batch, max_blocks)`` block table managed by the host-side
    allocator (serving/block_pool.py).  ``kpos`` is the static
    ``arange(s_max)`` of logical positions — its shape carries the
    lane's logical cache width through jit, and validity masks derive
    from it (``kpos <= pos``), so no per-slot ``cache_pos`` is needed.

    Per-architecture cache protocol (models/cache_protocol.py): only
    attention KV is block-paged.  SSM conv/SSD state is O(1) per lane
    and stays lane-indexed dense — a pure-SSM config gets a cache of
    just ``pos`` + ``conv`` + ``ssm`` (the *state-slot* protocol; the
    scheduler accounts for it with ``block_pool.StateSlotPool`` instead
    of a block table), and a hybrid carries both families.  No
    pure-ring sliding-window configs (paged lanes are append-only;
    windows are enforced by masking instead, any mix with a global
    layer is fine).

    With ``cfg.kv_quant`` the page pools are int8 and each (block-slot,
    kv-head) carries an f32 absmax scale in ``k_scale``/``v_scale``
    pools of shape ``(L, n_blocks + 1, block_size, KV)`` — the scale
    pools are indexed by exactly the same flat slot ids as the value
    pools, so block sharing/CoW/offload move scales verbatim alongside
    their int8 blocks.
    """
    if not (cfg.has_attention or cfg.has_ssm):
        raise ValueError(f"{cfg.name}: no token mixer to cache state for")
    cdt = cache_dtype or jnp.dtype(cfg.compute_dtype)
    L = cfg.n_layers
    cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.has_attention:
        if cache_length(cfg, s_max) != s_max:
            raise ValueError(
                "paged decode cache requires full-length caching "
                "(pure sliding-window ring configs decode dense)")
        dh = cfg.resolved_head_dim
        kv_dt = jnp.int8 if cfg.kv_quant else cdt
        max_blocks = -(-s_max // block_size)
        cache["kpos"] = jnp.arange(s_max, dtype=jnp.int32)
        cache["block_tables"] = jnp.zeros((batch, max_blocks), jnp.int32)
        cache["k"] = jnp.zeros(
            (L, n_blocks + 1, block_size, cfg.n_kv_heads, dh), kv_dt)
        cache["v"] = jnp.zeros(
            (L, n_blocks + 1, block_size, cfg.n_kv_heads, dh), kv_dt)
        if cfg.kv_quant:
            cache["k_scale"] = jnp.zeros(
                (L, n_blocks + 1, block_size, cfg.n_kv_heads), jnp.float32)
            cache["v_scale"] = jnp.zeros(
                (L, n_blocks + 1, block_size, cfg.n_kv_heads), jnp.float32)
    if cfg.has_ssm:
        di, n, h, conv_ch, _ = ssm_mod.ssm_dims(cfg)
        cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv_width, conv_ch), cdt)
        cache["ssm"] = jnp.zeros((L, batch, h, cfg.ssm_head_dim, n),
                                 jnp.float32)
    return cache


# ----------------------------------------------------------------------
# Prefill
# ----------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens=None, embeds=None,
            positions=None, lengths=None, max_len=None,
            last_only: bool = False):
    """Process the prompt, return (logits, cache).

    ``lengths`` (B,) marks per-lane prompt length (tokens beyond are
    right-padding); cache ``pos`` is set to lengths.  ``max_len`` sizes
    the cache for subsequent decoding (default: prompt length only).
    ``last_only`` applies the LM head only at each lane's last prompt
    position (returns (B,V)) — avoids materializing (B,S,V) logits,
    which dominates prefill memory at 32k x 128k-vocab scale.
    """
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = embed_tokens(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    sc = cache_length(cfg, max(max_len or s, s))
    kept = min(s, sc)

    def block(carry, layer):
        x, aux = carry
        lp, window = layer
        x, parts, la = _block_forward(cfg, lp, x, positions, window,
                                      lengths=lengths, dropless=True)
        if la is not None:
            aux = {k: aux[k] + la[k] for k in aux}
        out_parts = {}
        if cfg.has_attention:
            k, v = parts["k"], parts["v"]
            if kept < s:
                k, v = k[:, s - kept:], v[:, s - kept:]
            out_parts["k"], out_parts["v"] = k, v
        if cfg.has_ssm:
            out_parts["conv"], out_parts["ssm"] = parts["conv"], parts["ssm"]
        return (x, aux), out_parts

    if cfg.remat:
        block = jax.checkpoint(block, prevent_cse=False)
    (x, aux), layer_caches = jax.lax.scan(block, (x, _zero_aux(cfg)),
                                          (params["layers"], windows))
    x = apply_norm(cfg, params["final_norm"], x)
    if last_only:
        idx = (lengths - 1)[:, None, None].astype(jnp.int32)
        x_last = jnp.take_along_axis(x, jnp.broadcast_to(
            idx, (b, 1, x.shape[-1])), axis=1)[:, 0]
        logits = logits_from_hidden(cfg, params["embed"], x_last)      # (B,V)
    else:
        logits = logits_from_hidden(cfg, params["embed"], x)

    cache = {"pos": lengths.astype(jnp.int32)}
    if cfg.has_attention:
        L = cfg.n_layers
        dh = cfg.resolved_head_dim
        kept_pos = positions[:, s - kept:]                             # (B,kept)
        if kept == s and sc == s:
            # Full cache, whole prompt kept: position p lives at slot p,
            # i.e. the scatter below would be the identity permutation.
            # Writing the scan output through directly avoids the
            # zeros+scatter round-trip (which at 32k x 48L materializes
            # several full-cache temp copies — see EXPERIMENTS.md §Perf).
            k_cache, v_cache = layer_caches["k"], layer_caches["v"]
            cache_pos = jnp.where(kept_pos < lengths[:, None], kept_pos, -1)
        else:
            # slots: position p lives at slot p % sc; the kept positions
            # are contiguous so the slot map is injective -> ring scatter.
            slots = (kept_pos % sc).astype(jnp.int32)
            bidx = jnp.arange(b)[:, None]
            cdt = layer_caches["k"].dtype
            k_cache = jnp.zeros((L, b, sc, cfg.n_kv_heads, dh), cdt
                                ).at[:, bidx, slots].set(layer_caches["k"])
            v_cache = jnp.zeros((L, b, sc, cfg.n_kv_heads, dh), cdt
                                ).at[:, bidx, slots].set(layer_caches["v"])
            cache_pos = jnp.full((b, sc), -1, jnp.int32
                                 ).at[bidx, slots].set(kept_pos)
            # mark right-padding invalid
            cache_pos = jnp.where(cache_pos < lengths[:, None], cache_pos, -1)
        cache["k"], cache["v"], cache["cache_pos"] = k_cache, v_cache, cache_pos
    if cfg.has_ssm:
        cache["conv"], cache["ssm"] = layer_caches["conv"], layer_caches["ssm"]
    return logits, cache


def prefill_chunk(params, cfg: ModelConfig, tokens, cache, start, lengths,
                  lanes=None, read_rows=None, write_rows=None, sb=None):
    """Process one C-token chunk of each row's prompt against an
    existing decode cache, appending the chunk's K/V — the incremental
    sibling of :func:`prefill` that lets the serving loop interleave
    prompt processing with decode rounds (serving/scheduler.py).

    tokens: (Nb, C) the chunk's token ids (right-padded past the
    prompt); start: (Nb,) each row's chunk offset into its prompt;
    lengths: (Nb,) full prompt lengths; sb: static prompt-bucket width
    — every attention reduction runs at exactly this width, which is
    what makes a chunked prompt bit-identical to whole-prompt prefill
    at the same bucket (reductions over different lengths are not
    bitwise comparable; tests/test_serving_trace.py holds the line).

    Dense cache (:func:`init_decode_state` layout): ``lanes`` (Nb,)
    maps chunk rows to lane rows (>= n_lanes = dummy row, dropped).
    Paged cache (:func:`init_paged_decode_state`): ``read_rows`` /
    ``write_rows`` (Nb, max_blocks) carry each row's gather/scatter
    block ids — they differ when a shared-prefix row reads
    prefix-cache blocks whose writes are routed to the trash block.

    Returns ``(last_logits (Nb, V), cache)`` — the logits at each row's
    last position covered so far (``min(start + C, lengths) - 1``; on a
    row's final chunk, exactly the prompt-last-token logits whole
    prefill would return).  Host-side per-lane state (``pos``,
    ``cache_pos`` validity, the scheduler's logits buffer) is the
    caller's job — see serving/batch.py ``prefill_chunk_jit``.

    SSM / hybrid caches: each chunk reads the lane's carried conv +
    SSD state (``ssm_forward(init_state=..., init_conv=...)``), rows
    whose ``start == 0`` reading zeros instead (a first chunk must not
    see a previous occupant's state), and writes the updated states
    back to the lane rows.  Bit-identity with whole-prompt prefill
    needs chunk starts aligned to ``cfg.ssm_chunk`` (the SSD
    intra-chunk einsums must see the same chunk boundaries) — the
    scheduler enforces ``chunk_size % ssm_chunk == 0``.

    Quantized caches (``k_scale`` present): the chunk's K/V are
    quantized per (slot, kv-head) before the scatter, and the cache
    view each chunk attends over is the dequantized int8 cache.  A
    chunked quantized prompt therefore matches whole-prompt-then-
    quantize only to tolerance (later chunks read earlier chunks
    through the int8 round-trip), but it IS bit-stable across chunk
    schedules that cover the same slots — per-slot quantization is
    elementwise deterministic.
    """
    from repro.models.cache_protocol import protocol_of
    x = embed_tokens(cfg, params["embed"], tokens)
    b, c, _ = x.shape
    q_pos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # (Nb,C)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    proto = protocol_of(cache, cfg)
    has_attn = proto.has_attention
    has_ssm = proto.state_slots
    paged = proto.paged_attention
    quant = "k_scale" in cache
    cdt = jnp.dtype(cfg.compute_dtype)
    dh = cfg.resolved_head_dim
    if has_ssm:
        # a row's first chunk must read zero state, not whatever a
        # previous lane occupant left in the rows (chunked admission
        # never resets the state arrays)
        fresh = start == 0

    if not has_attn:
        pass
    elif paged:
        pb, bs = cache["k"].shape[1], cache["k"].shape[2]
        kpos_sb = jnp.arange(sb, dtype=jnp.int32)
        # per-row flat pool slots: reads follow read_rows (shared prompt
        # blocks included), writes follow write_rows (trash for
        # cache-satisfied positions and rows padded past their blocks)
        gather_idx = read_rows[:, kpos_sb // bs] * bs + (kpos_sb % bs)[None, :]
        write_blk = jnp.take_along_axis(
            write_rows, jnp.minimum(q_pos // bs, write_rows.shape[1] - 1),
            axis=1)
        write_tgt = write_blk * bs + q_pos % bs                       # (Nb,C)
        k_pos_view = jnp.broadcast_to(kpos_sb[None, :], (b, sb))
    else:
        k_pos_view = jnp.broadcast_to(jnp.arange(sb, dtype=jnp.int32)[None, :],
                                      (b, sb))

    def block(carry, layer):
        x, k_stack, v_stack, ks_stack, vs_stack, conv_stack, ssm_stack = carry
        lp = layer["lp"]
        window = layer["window"]
        idx = layer["idx"]
        h = apply_norm(cfg, lp["norm1"], x)
        outs = []
        if has_attn:
            q, k, v = attn_mod.chunk_qkv(cfg, lp["attn"], h, q_pos)
            k_l = jax.lax.dynamic_index_in_dim(k_stack, idx, 0,
                                               keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(v_stack, idx, 0,
                                               keepdims=False)
            if quant:
                ks_l = jax.lax.dynamic_index_in_dim(ks_stack, idx, 0,
                                                    keepdims=False)
                vs_l = jax.lax.dynamic_index_in_dim(vs_stack, idx, 0,
                                                    keepdims=False)
                k, ksc = attn_mod.quantize_kv(k)               # (Nb,C,KV)
                v, vsc = attn_mod.quantize_kv(v)
            if paged:
                k_flat = k_l.reshape(pb * bs, cfg.n_kv_heads, dh)
                v_flat = v_l.reshape(pb * bs, cfg.n_kv_heads, dh)
                k_flat = k_flat.at[write_tgt].set(k.astype(k_flat.dtype))
                v_flat = v_flat.at[write_tgt].set(v.astype(v_flat.dtype))
                if quant:
                    ks_flat = ks_l.reshape(pb * bs, cfg.n_kv_heads)
                    vs_flat = vs_l.reshape(pb * bs, cfg.n_kv_heads)
                    ks_flat = ks_flat.at[write_tgt].set(ksc)
                    vs_flat = vs_flat.at[write_tgt].set(vsc)
                    k_att = attn_mod.dequantize_kv(k_flat[gather_idx],
                                                   ks_flat[gather_idx], cdt)
                    v_att = attn_mod.dequantize_kv(v_flat[gather_idx],
                                                   vs_flat[gather_idx], cdt)
                    ks_l = ks_flat.reshape(pb, bs, cfg.n_kv_heads)
                    vs_l = vs_flat.reshape(pb, bs, cfg.n_kv_heads)
                else:
                    k_att, v_att = k_flat[gather_idx], v_flat[gather_idx]
                k_l = k_flat.reshape(pb, bs, cfg.n_kv_heads, dh)
                v_l = v_flat.reshape(pb, bs, cfg.n_kv_heads, dh)
            else:
                k_l = k_l.at[lanes[:, None], q_pos].set(k.astype(k_l.dtype),
                                                        mode="drop")
                v_l = v_l.at[lanes[:, None], q_pos].set(v.astype(v_l.dtype),
                                                        mode="drop")
                if quant:
                    ks_l = ks_l.at[lanes[:, None], q_pos].set(ksc,
                                                              mode="drop")
                    vs_l = vs_l.at[lanes[:, None], q_pos].set(vsc,
                                                              mode="drop")
                    k_att = attn_mod.dequantize_kv(k_l[lanes, :sb],
                                                   ks_l[lanes, :sb], cdt)
                    v_att = attn_mod.dequantize_kv(v_l[lanes, :sb],
                                                   vs_l[lanes, :sb], cdt)
                else:
                    k_att, v_att = k_l[lanes, :sb], v_l[lanes, :sb]
            a_out = attn_mod.chunk_attend(cfg, lp["attn"], q, k_att, v_att,
                                          q_pos, k_pos_view, window)
            outs.append(a_out)
            k_stack = jax.lax.dynamic_update_index_in_dim(k_stack, k_l,
                                                          idx, 0)
            v_stack = jax.lax.dynamic_update_index_in_dim(v_stack, v_l,
                                                          idx, 0)
            if quant:
                ks_stack = jax.lax.dynamic_update_index_in_dim(
                    ks_stack, ks_l, idx, 0)
                vs_stack = jax.lax.dynamic_update_index_in_dim(
                    vs_stack, vs_l, idx, 0)
        if has_ssm:
            conv_l = jax.lax.dynamic_index_in_dim(conv_stack, idx, 0,
                                                  keepdims=False)
            ssm_l = jax.lax.dynamic_index_in_dim(ssm_stack, idx, 0,
                                                 keepdims=False)
            # lane-row gather (out-of-range dummy rows clamp — their
            # writes drop below); first chunks read zero state
            conv_rows = jnp.where(fresh[:, None, None], 0.0, conv_l[lanes])
            ssm_rows = jnp.where(fresh[:, None, None, None], 0.0,
                                 ssm_l[lanes])
            s_out, (conv_new, ssm_new) = ssm_mod.ssm_forward(
                cfg, lp["ssm"], h, init_state=ssm_rows, init_conv=conv_rows,
                positions=q_pos, lengths=lengths)
            outs.append(s_out)
            conv_l = conv_l.at[lanes].set(conv_new.astype(conv_l.dtype),
                                          mode="drop")
            ssm_l = ssm_l.at[lanes].set(ssm_new, mode="drop")
            conv_stack = jax.lax.dynamic_update_index_in_dim(
                conv_stack, conv_l, idx, 0)
            ssm_stack = jax.lax.dynamic_update_index_in_dim(
                ssm_stack, ssm_l, idx, 0)
        mix = (outs[0] + outs[1]) * 0.5 if len(outs) == 2 else outs[0]
        x = x + mix
        ch, _ = _channel_forward(cfg, lp, x, dropless=True)
        if ch is not None:
            x = x + ch
        return (x, k_stack, v_stack, ks_stack, vs_stack, conv_stack,
                ssm_stack), None

    L = cfg.n_layers
    xs = {"lp": params["layers"], "window": windows,
          "idx": jnp.arange(L, dtype=jnp.int32)}
    zero = jnp.zeros((), x.dtype)
    k0 = cache["k"] if has_attn else zero
    v0 = cache["v"] if has_attn else zero
    ks0 = cache["k_scale"] if quant else zero
    vs0 = cache["v_scale"] if quant else zero
    conv0 = cache["conv"] if has_ssm else zero
    ssm0 = cache["ssm"] if has_ssm else zero
    (x, k_stack, v_stack, ks_stack, vs_stack, conv_stack, ssm_stack), _ = \
        jax.lax.scan(block, (x, k0, v0, ks0, vs0, conv0, ssm0), xs)
    x = apply_norm(cfg, params["final_norm"], x)
    last = jnp.clip(jnp.minimum(start + c, lengths) - 1 - start, 0, c - 1)
    idx = last[:, None, None].astype(jnp.int32)
    x_last = jnp.take_along_axis(x, jnp.broadcast_to(
        idx, (b, 1, x.shape[-1])), axis=1)[:, 0]
    logits = logits_from_hidden(cfg, params["embed"], x_last)          # (Nb,V)
    new_cache = dict(cache)
    if has_attn:
        new_cache["k"], new_cache["v"] = k_stack, v_stack
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = ks_stack, vs_stack
    if has_ssm:
        new_cache["conv"], new_cache["ssm"] = conv_stack, ssm_stack
    return logits, new_cache


# ----------------------------------------------------------------------
# Decode
# ----------------------------------------------------------------------

def verify_step(params, cfg: ModelConfig, tokens, cache, draft_len=None):
    """Score Kd draft tokens per lane in ONE forward pass — speculative
    decoding's verify round (serving/batch.py ``decode_round_spec``).

    tokens: (B, Kd); draft i is scored at absolute position ``pos + i``.
    The drafts' K/V are written into the cache exactly where sequential
    ``decode_step`` calls would write them; the caller owns acceptance
    and rollback (rejected dense slots are re-marked empty through
    ``cache_pos``; rejected paged slots become unreachable once the
    block table stops growing over them).  ``pos`` is NOT advanced —
    the caller sets it to ``pos + accepted``.

    ``draft_len`` (B,) optionally bounds the real drafts per lane: K/V
    writes for positions ``i >= draft_len[b]`` are routed to the trash
    block (paged) or dropped (dense) instead of landing at
    ``pos + i``.  Acceptance never consults those positions, and
    without the masking an undrafted lane riding a wide verify round
    near the cache ceiling could clamp a write onto one of its own
    *valid* slots (the paged beyond-table clamp) — corrupting history a
    live lane still reads.

    Returns (logits (B, Kd, V), new cache).  ``logits[:, i]`` are the
    next-token logits after draft i — bitwise the logits ``decode_step``
    would return fed the same tokens one at a time: every attention
    softmax reduces over the same cache width decode uses, and each
    position's projections/FFN rows are row subsets of the same matmuls
    (the ``chunk_qkv`` argument; tests/test_spec_decode.py asserts the
    bit-match).

    Attention models only — a rejected draft's recurrent (SSM) state
    could not be rolled back (the scheduler's spec guard gates on the
    same predicate).  MoE configs verify fine: dropless decode dispatch
    makes each token's expert output independent of the verify batch
    width, so verify logits still bit-match sequential decode.

    Quantized caches (``k_scale`` present): drafts are quantized per
    (slot, kv-head) before the scatter and scored against the
    dequantized cache view.  Rollback stays bit-stable — a rejected
    slot's int8 value+scale pair is simply overwritten when the true
    token later lands on the same slot, and per-slot quantization is
    elementwise deterministic, so the rewritten slot is identical to
    what a non-speculative run writes.
    """
    if cfg.has_ssm:
        raise ValueError("verify_step requires an attention-only model: "
                         "SSM state is sequential per token and cannot "
                         "score k draft positions in one pass")
    x = embed_tokens(cfg, params["embed"], tokens)
    b, kd, _ = x.shape
    pos = cache["pos"]                                                 # (B,)
    q_pos = pos[:, None] + jnp.arange(kd, dtype=jnp.int32)[None, :]    # (B,Kd)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    dh = cfg.resolved_head_dim
    paged = "block_tables" in cache
    live_w = None
    if draft_len is not None:
        live_w = jnp.arange(kd, dtype=jnp.int32)[None, :] < draft_len[:, None]

    cache_pos = bidx = slots = None
    if paged:
        bt = cache["block_tables"]                                     # (B,M)
        kpos = cache["kpos"]                                           # (S,)
        pb, bs = cache["k"].shape[1], cache["k"].shape[2]
        # flat pool slots for the drafts; same clamp story as
        # decode_step — positions past the table scribble slots whose
        # contents are never read
        blk = jnp.minimum(q_pos // bs, bt.shape[1] - 1)
        bid = jnp.take_along_axis(bt, blk, axis=1)                     # (B,Kd)
        write_tgt = bid * bs + q_pos % bs
        if live_w is not None:
            write_tgt = jnp.where(live_w, write_tgt, q_pos % bs)  # trash blk 0
        gather_idx = bt[:, kpos // bs] * bs + (kpos % bs)[None, :]     # (B,S)
        k_pos_view = jnp.broadcast_to(kpos[None, :], gather_idx.shape)
    else:
        sc = cache["k"].shape[2]
        slots = (q_pos % sc).astype(jnp.int32)
        if live_w is not None:
            slots = jnp.where(live_w, slots, sc)       # out of range: dropped
        bidx = jnp.arange(b)[:, None]
        cache_pos = cache["cache_pos"].at[bidx, slots].set(q_pos, mode="drop")

    quant = "k_scale" in cache
    cdt = jnp.dtype(cfg.compute_dtype)

    def block(carry, layer):
        x, k_stack, v_stack, ks_stack, vs_stack = carry
        lp = layer["lp"]
        window = layer["window"]
        idx = layer["idx"]
        h = apply_norm(cfg, lp["norm1"], x)
        q, k, v = attn_mod.chunk_qkv(cfg, lp["attn"], h, q_pos)
        k_l = jax.lax.dynamic_index_in_dim(k_stack, idx, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(v_stack, idx, 0, keepdims=False)
        if quant:
            ks_l = jax.lax.dynamic_index_in_dim(ks_stack, idx, 0,
                                                keepdims=False)
            vs_l = jax.lax.dynamic_index_in_dim(vs_stack, idx, 0,
                                                keepdims=False)
            k, ksc = attn_mod.quantize_kv(k)                   # (B,Kd,KV)
            v, vsc = attn_mod.quantize_kv(v)
        if paged:
            k_flat = k_l.reshape(pb * bs, cfg.n_kv_heads, dh)
            v_flat = v_l.reshape(pb * bs, cfg.n_kv_heads, dh)
            k_flat = k_flat.at[write_tgt].set(k.astype(k_flat.dtype))
            v_flat = v_flat.at[write_tgt].set(v.astype(v_flat.dtype))
            if quant:
                ks_flat = ks_l.reshape(pb * bs, cfg.n_kv_heads)
                vs_flat = vs_l.reshape(pb * bs, cfg.n_kv_heads)
                ks_flat = ks_flat.at[write_tgt].set(ksc)
                vs_flat = vs_flat.at[write_tgt].set(vsc)
                k_att = attn_mod.dequantize_kv(k_flat[gather_idx],
                                               ks_flat[gather_idx], cdt)
                v_att = attn_mod.dequantize_kv(v_flat[gather_idx],
                                               vs_flat[gather_idx], cdt)
                ks_l = ks_flat.reshape(pb, bs, cfg.n_kv_heads)
                vs_l = vs_flat.reshape(pb, bs, cfg.n_kv_heads)
            else:
                k_att, v_att = k_flat[gather_idx], v_flat[gather_idx]
            a_out = attn_mod.verify_attend(cfg, lp["attn"], q, k_att, v_att,
                                           q_pos, k_pos_view, window)
            k_l = k_flat.reshape(pb, bs, cfg.n_kv_heads, dh)
            v_l = v_flat.reshape(pb, bs, cfg.n_kv_heads, dh)
        else:
            k_l = k_l.at[bidx, slots].set(k.astype(k_l.dtype), mode="drop")
            v_l = v_l.at[bidx, slots].set(v.astype(v_l.dtype), mode="drop")
            if quant:
                ks_l = ks_l.at[bidx, slots].set(ksc, mode="drop")
                vs_l = vs_l.at[bidx, slots].set(vsc, mode="drop")
                k_att = attn_mod.dequantize_kv(k_l, ks_l, cdt)
                v_att = attn_mod.dequantize_kv(v_l, vs_l, cdt)
            else:
                k_att, v_att = k_l, v_l
            a_out = attn_mod.verify_attend(cfg, lp["attn"], q, k_att, v_att,
                                           q_pos, cache_pos, window,
                                           valid_k=cache_pos >= 0)
        x = x + a_out
        ch, _ = _channel_forward(cfg, lp, x, dropless=True)
        if ch is not None:
            x = x + ch
        k_stack = jax.lax.dynamic_update_index_in_dim(k_stack, k_l, idx, 0)
        v_stack = jax.lax.dynamic_update_index_in_dim(v_stack, v_l, idx, 0)
        if quant:
            ks_stack = jax.lax.dynamic_update_index_in_dim(
                ks_stack, ks_l, idx, 0)
            vs_stack = jax.lax.dynamic_update_index_in_dim(
                vs_stack, vs_l, idx, 0)
        return (x, k_stack, v_stack, ks_stack, vs_stack), None

    L = cfg.n_layers
    xs = {"lp": params["layers"], "window": windows,
          "idx": jnp.arange(L, dtype=jnp.int32)}
    zero = jnp.zeros((), x.dtype)
    ks0 = cache["k_scale"] if quant else zero
    vs0 = cache["v_scale"] if quant else zero
    (x, k_stack, v_stack, ks_stack, vs_stack), _ = jax.lax.scan(
        block, (x, cache["k"], cache["v"], ks0, vs0), xs)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params["embed"], x)               # (B,Kd,V)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_stack, v_stack
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = ks_stack, vs_stack
    if not paged:
        new_cache["cache_pos"] = cache_pos
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, embeds=None):
    """One decode step.  tokens: (B,) int32 (or embeds (B,1,D)).

    The cache may be dense (from :func:`init_decode_state` /
    :func:`prefill`) or block-paged (from
    :func:`init_paged_decode_state`) — ``cache_protocol.protocol_of``
    names which state families it carries and how (static under jit:
    key presence is pytree structure).  Returns (logits (B,V), new
    cache).
    """
    from repro.models.cache_protocol import protocol_of
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = embed_tokens(cfg, params["embed"], tokens[:, None])
    b = x.shape[0]
    pos = cache["pos"]                                                 # (B,)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    proto = protocol_of(cache, cfg)
    has_attn = proto.has_attention
    paged = proto.paged_attention

    cache_pos = bt = kpos = write_slot = gather_idx = None
    if paged:
        bt = cache["block_tables"]                                     # (B,M)
        kpos = cache["kpos"]                                           # (S,)
        bs = cache["k"].shape[2]
        # flat pool slot for the new token.  Positions that outrun the
        # block table clamp to its last entry; such writes are always
        # discarded garbage — an evicted lane's table is all trash
        # (block 0), and a live lane past its budget scribbles unread
        # slots of blocks it still owns (freed at finalize, and the
        # scheduler's reservation sizing keeps those slots inside the
        # lane's own allocation until then)
        blk = jnp.minimum(pos // bs, bt.shape[1] - 1)
        bid = jnp.take_along_axis(bt, blk[:, None], axis=1)[:, 0]
        write_slot = bid * bs + pos % bs                               # (B,)
        gather_idx = bt[:, kpos // bs] * bs + (kpos % bs)[None, :]     # (B,S)
    elif has_attn:
        sc = cache["k"].shape[2]
        slot = (pos % sc).astype(jnp.int32)
        cache_pos = cache["cache_pos"].at[jnp.arange(b), slot].set(pos)

    quant = has_attn and "k_scale" in cache

    def block(carry, layer):
        # The stacked k/v caches ride in the scan CARRY and are updated
        # with dynamic_update_index_in_dim at the current layer index:
        # XLA keeps a single in-place loop buffer.  Returning updated
        # per-layer slices as scan ys instead materializes a second full
        # cache stack (2 x 4.8 GB/dev on musicgen decode_32k; §Perf).
        x, k_stack, v_stack, ks_stack, vs_stack = carry
        lp = layer["lp"]
        window = layer["window"]
        idx = layer["idx"]
        new_parts = {}
        h = apply_norm(cfg, lp["norm1"], x)
        outs = []
        if has_attn:
            k_l = jax.lax.dynamic_index_in_dim(k_stack, idx, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(v_stack, idx, 0, keepdims=False)
            if paged and quant:
                ks_l = jax.lax.dynamic_index_in_dim(ks_stack, idx, 0,
                                                    keepdims=False)
                vs_l = jax.lax.dynamic_index_in_dim(vs_stack, idx, 0,
                                                    keepdims=False)
                a_out, k_l, v_l, ks_l, vs_l = attn_mod.attention_decode_paged(
                    cfg, lp["attn"], h, pos, k_l, v_l, write_slot,
                    gather_idx, kpos, bt, window,
                    k_scale=ks_l, v_scale=vs_l)
                ks_stack = jax.lax.dynamic_update_index_in_dim(
                    ks_stack, ks_l, idx, 0)
                vs_stack = jax.lax.dynamic_update_index_in_dim(
                    vs_stack, vs_l, idx, 0)
            elif paged:
                a_out, k_l, v_l = attn_mod.attention_decode_paged(
                    cfg, lp["attn"], h, pos, k_l, v_l, write_slot,
                    gather_idx, kpos, bt, window)
            elif quant:
                ks_l = jax.lax.dynamic_index_in_dim(ks_stack, idx, 0,
                                                    keepdims=False)
                vs_l = jax.lax.dynamic_index_in_dim(vs_stack, idx, 0,
                                                    keepdims=False)
                a_out, k_l, v_l, ks_l, vs_l = attn_mod.attention_decode(
                    cfg, lp["attn"], h, pos, k_l, v_l, cache_pos, window,
                    k_scale=ks_l, v_scale=vs_l)
                ks_stack = jax.lax.dynamic_update_index_in_dim(
                    ks_stack, ks_l, idx, 0)
                vs_stack = jax.lax.dynamic_update_index_in_dim(
                    vs_stack, vs_l, idx, 0)
            else:
                a_out, k_l, v_l = attn_mod.attention_decode(
                    cfg, lp["attn"], h, pos, k_l, v_l, cache_pos, window)
            outs.append(a_out)
            k_stack = jax.lax.dynamic_update_index_in_dim(k_stack, k_l, idx, 0)
            v_stack = jax.lax.dynamic_update_index_in_dim(v_stack, v_l, idx, 0)
        if cfg.has_ssm:
            s_out, (conv_s, ssm_s) = ssm_mod.ssm_decode(
                cfg, lp["ssm"], h, layer["conv"], layer["ssm"])
            outs.append(s_out)
            new_parts["conv"], new_parts["ssm"] = conv_s, ssm_s
        mix = (outs[0] + outs[1]) * 0.5 if len(outs) == 2 else outs[0]
        x = x + mix
        ch, _ = _channel_forward(cfg, lp, x, dropless=True)
        if ch is not None:
            x = x + ch
        return (x, k_stack, v_stack, ks_stack, vs_stack), new_parts

    L = cfg.n_layers
    xs = {"lp": params["layers"], "window": windows,
          "idx": jnp.arange(L, dtype=jnp.int32)}
    for key in ("conv", "ssm"):
        if key in cache:
            xs[key] = cache[key]

    zero = jnp.zeros((), x.dtype)
    k0 = cache.get("k") if has_attn else zero
    v0 = cache.get("v") if has_attn else zero
    ks0 = cache.get("k_scale") if quant else zero
    vs0 = cache.get("v_scale") if quant else zero
    (x, k_stack, v_stack, ks_stack, vs_stack), new_layer_caches = \
        jax.lax.scan(block, (x, k0, v0, ks0, vs0), xs)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params["embed"], x[:, 0])

    new_cache = {"pos": pos + 1}
    if has_attn:
        new_cache["k"] = k_stack
        new_cache["v"] = v_stack
        if quant:
            new_cache["k_scale"] = ks_stack
            new_cache["v_scale"] = vs_stack
        if paged:
            new_cache["kpos"] = kpos
            new_cache["block_tables"] = bt
        else:
            new_cache["cache_pos"] = cache_pos
    if cfg.has_ssm:
        new_cache["conv"] = new_layer_caches["conv"]
        new_cache["ssm"] = new_layer_caches["ssm"]
    return logits, new_cache
