"""Attention: MHA/GQA/MQA with RoPE, causal + sliding-window masks.

Two execution paths:
  * ``direct``  — full (S x S) score materialization, used for short seqs
    and as the semantic reference.
  * ``chunked`` — lax.scan over KV blocks with online (flash-style)
    softmax; the pure-JAX analogue of the Pallas flash kernel and the
    path used for long sequences so prefill memory stays O(S * block).

Decode attends one new token against the cache.  The cache stores keys
*post-RoPE* together with the absolute position of every slot
(``cache_pos``, -1 = empty), which makes full and ring-buffer
(sliding-window) caches uniform: validity and window masks are derived
from positions, not slot indices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

NEG_INF = -1e30


def attn_init(cfg: ModelConfig, key, dtype):
    dh = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * dh, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * dh, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * dh, dtype),
        "wo": dense_init(k4, cfg.n_heads * dh, cfg.d_model, dtype),
    }


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                                # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Score masking helpers
# ----------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, window, valid_k=None):
    """Additive bias (…, S_q, S_k): causal + optional sliding window.

    window == 0 means full attention.  q_pos/k_pos broadcast as
    (..., S_q, 1) vs (..., 1, S_k).
    """
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = kp <= qp
    ok = ok & jnp.where(window > 0, kp > qp - window, True)
    if valid_k is not None:
        ok = ok & valid_k[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF)


def _softcap(scores, cap: float):
    if cap and cap > 0:
        return jnp.tanh(scores / cap) * cap
    return scores


# ----------------------------------------------------------------------
# Core attention (q already grouped for GQA)
# ----------------------------------------------------------------------

def _gqa_scores_einsum(q, k):
    # q: (B, Sq, KV, G, Dh)   k: (B, Sk, KV, Dh).  Inputs stay in the
    # cache dtype (bf16 on TPU configs) with f32 accumulation — casting
    # k/v to f32 materializes a full-cache f32 copy (4.8 GB/dev on
    # musicgen decode_32k; EXPERIMENTS.md §Perf).
    return jnp.einsum("bqkgd,bskd->bkgqs", q.astype(k.dtype), k,
                      preferred_element_type=jnp.float32)


def direct_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, window, valid_k=None):
    """q: (B,Sq,H,Dh), k/v: (B,Sk,KV,Dh). Returns (B,Sq,H,Dh)."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = dh ** -0.5
    qg = q.reshape(b, sq, kv, g, dh)
    scores = _gqa_scores_einsum(qg * scale, k)
    scores = _softcap(scores, cfg.attn_logit_softcap)
    bias = _mask_bias(q_pos, k_pos, window, valid_k)                 # (B,Sq,Sk)
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def chunked_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, window,
                      valid_k=None, block: int = 512):
    """Online-softmax attention, scanning KV in blocks of ``block``.

    Semantics identical to :func:`direct_attention`; memory is
    O(Sq * block) instead of O(Sq * Sk).  This mirrors the Pallas flash
    kernel's streaming structure (kernels/flash_attention).  The scan
    body is checkpointed: under AD the per-block (Sq x block) score/prob
    tensors would otherwise ALL be saved, silently restoring the O(Sq*Sk)
    footprint the chunking exists to avoid.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    scale = dh ** -0.5
    pad = (-sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        vk = jnp.ones((b, sk), bool) if valid_k is None else valid_k
        valid_k = jnp.pad(vk, ((0, 0), (0, pad)), constant_values=False)
    n_blocks = k.shape[1] // block

    qg = (q * scale).reshape(b, sq, kv, g, dh)
    kb = k.reshape(b, n_blocks, block, kv, dh)
    vb = v.reshape(b, n_blocks, block, kv, dh)
    kpb = k_pos.reshape(b, n_blocks, block)
    vkb = None if valid_k is None else valid_k.reshape(b, n_blocks, block)

    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        if vkb is None:
            k_blk, v_blk, kp_blk = xs
            vk_blk = None
        else:
            k_blk, v_blk, kp_blk, vk_blk = xs
        s = _gqa_scores_einsum(qg, k_blk)                            # (B,KV,G,Sq,blk) f32
        s = _softcap(s, cfg.attn_logit_softcap)
        bias = _mask_bias(q_pos, kp_blk, window, vk_blk)             # (B,Sq,blk)
        s = s + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum("bkgqs,bskd->bkgqd",
                                          p.astype(v_blk.dtype), v_blk,
                                          preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, dh), jnp.float32)
    xs = (jnp.swapaxes(kb, 0, 1), jnp.swapaxes(vb, 0, 1), jnp.swapaxes(kpb, 0, 1))
    if vkb is not None:
        xs = xs + (jnp.swapaxes(vkb, 0, 1),)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, h, dh)  # (B,Sq,H,Dh)
    return out.astype(q.dtype)


# ----------------------------------------------------------------------
# Layer-level entry points
# ----------------------------------------------------------------------

CHUNKED_THRESHOLD = 2048


def attention_forward(cfg: ModelConfig, p, x, positions, window):
    """Full-sequence attention (train/prefill).  Returns (out, (k, v))."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    x = x.astype(cdt)
    q = (x @ p["wq"].astype(cdt)).reshape(b, s, cfg.n_heads, dh)
    k = (x @ p["wk"].astype(cdt)).reshape(b, s, cfg.n_kv_heads, dh)
    v = (x @ p["wv"].astype(cdt)).reshape(b, s, cfg.n_kv_heads, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.seq_shard_activations:
        # §Perf (context-parallel attention): queries stay sequence-
        # sharded over 'model'; only the (much thinner) k/v are gathered.
        # Position-based causal masks make the sharded-q math exact.
        from jax.sharding import PartitionSpec as P
        U = P.UNCONSTRAINED
        q = jax.lax.with_sharding_constraint(q, P(U, "model", U, U))
        k = jax.lax.with_sharding_constraint(k, P(U, None, U, U))
        v = jax.lax.with_sharding_constraint(v, P(U, None, U, U))
    if s > CHUNKED_THRESHOLD:
        out = chunked_attention(cfg, q, k, v, positions, positions, window)
    else:
        out = direct_attention(cfg, q, k, v, positions, positions, window)
    out = out.reshape(b, s, cfg.n_heads * dh) @ p["wo"].astype(cdt)
    return out, (k, v)


def chunk_qkv(cfg: ModelConfig, p, x, q_pos):
    """Q/K/V projections + RoPE for one prefill chunk.

    x: (B, C, D) chunk hidden states; q_pos: (B, C) absolute positions.
    Returns (q, k, v) each (B, C, heads, Dh), k post-RoPE — exactly the
    projections :func:`attention_forward` computes for those positions
    (row subsets of a matmul are bitwise stable, so chunking the prompt
    does not change a single K/V bit; see model.prefill_chunk).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    b, c, _ = x.shape
    dh = cfg.resolved_head_dim
    x = x.astype(cdt)
    q = (x @ p["wq"].astype(cdt)).reshape(b, c, cfg.n_heads, dh)
    k = (x @ p["wk"].astype(cdt)).reshape(b, c, cfg.n_kv_heads, dh)
    v = (x @ p["wv"].astype(cdt)).reshape(b, c, cfg.n_kv_heads, dh)
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)
    return q, k, v


def chunk_attend(cfg: ModelConfig, p, q, k_att, v_att, q_pos, k_pos, window):
    """Attention of a prefill chunk's queries over the lane's cache view.

    q: (B, C, H, Dh); k_att/v_att: (B, Sb, KV, Dh) — the cache view over
    the prompt bucket, already containing this chunk's K/V; k_pos:
    (B, Sb) the view's absolute positions.  The causal mask ``k <= q``
    covers everything: positions after the chunk are unwritten garbage
    but always masked, exactly as right-padding is in whole-prompt
    prefill.  CRITICALLY the softmax reduces over the same ``Sb`` width
    whole-prompt prefill uses — reductions over different lengths are
    not bitwise comparable, which is the one geometric constraint the
    chunked == unchunked bit-match rests on.  Returns (B, C, D).
    """
    b, c, _, dh = q.shape
    out = direct_attention(cfg, q, k_att, v_att, q_pos, k_pos, window)
    cdt = jnp.dtype(cfg.compute_dtype)
    return out.reshape(b, c, cfg.n_heads * dh) @ p["wo"].astype(cdt)


def verify_attend(cfg: ModelConfig, p, q, k_att, v_att, q_pos, k_pos, window,
                  valid_k=None):
    """Attention of a lane's Kd position-shifted verify queries over its
    full cache view — speculative decoding's multi-token scoring pass.

    q: (B, Kd, H, Dh) the draft tokens' queries; k_att/v_att:
    (B, Sc, KV, Dh) — the decode-width cache view, already containing
    ALL Kd drafts' own K/V; k_pos: (B, Sc) the view's absolute
    positions; valid_k: (B, Sc) slot validity (dense caches pass
    ``cache_pos >= 0`` exactly as :func:`attention_decode` does; paged
    views rely on the causal mask over true positions, as decode's
    ``kpos <= pos`` is causal for its single query).

    The bit-exactness contract (tests/test_spec_decode.py): the output
    row for draft i is bitwise the row ``attention_decode`` /
    ``attention_decode_paged`` would produce fed the drafts one token
    at a time.  Two facts carry it, both load-bearing:

      * the score and weighted-sum einsums are evaluated per query at
        decode's exact ``Sq = 1`` geometry (the loop below) — the
        backend's batched-contraction lowering is NOT row-stable
        across ``Sq`` (measured ~2e-7 relative drift at Sq=4 vs Sq=1
        on the CPU backend), so a single (B, Kd, Sc) score
        materialization can never bit-match sequential decode; the
        projections/norms/FFN rows feeding this function ARE bitwise
        row-stable (the ``chunk_qkv`` argument) and stay fused over Kd;
      * draft j > i's K/V are already written where sequential decode
        would NOT yet have written them — but those slots are causally
        masked (``k_pos > q_pos``) to an additive ``NEG_INF`` bias, so
        their probs underflow to exact +0.0 and contribute exact zeros
        to the weighted sum regardless of slot contents, precisely the
        trash-slot argument the paged decode path already rests on.

    Returns (B, Kd, D).
    """
    b, kd, _, dh = q.shape
    chunked = k_att.shape[1] > 64 * 1024  # same switch as the decode paths
    outs = []
    for i in range(kd):
        if chunked:
            o = chunked_attention(cfg, q[:, i:i + 1], k_att, v_att,
                                  q_pos[:, i:i + 1], k_pos, window,
                                  valid_k=valid_k, block=8192)
        else:
            o = direct_attention(cfg, q[:, i:i + 1], k_att, v_att,
                                 q_pos[:, i:i + 1], k_pos, window,
                                 valid_k=valid_k)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1)                            # (B,Kd,H,Dh)
    cdt = jnp.dtype(cfg.compute_dtype)
    return out.reshape(b, kd, cfg.n_heads * dh) @ p["wo"].astype(cdt)


def quantize_kv(x):
    """x (..., dh) -> (int8 q, f32 absmax scale (...,))."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(s, 1e-8)[..., None])
    return q.astype(jnp.int8), s


def dequantize_kv(q, s, dtype):
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


def attention_decode_paged(cfg: ModelConfig, p, x, pos, k_pages, v_pages,
                           write_slot, gather_idx, kpos, block_tables,
                           window, use_kernel=None,
                           k_scale=None, v_scale=None):
    """One-token decode against a block-paged KV cache.

    x: (B,1,D); pos: (B,) absolute position of the new token.
    k_pages/v_pages: (P, bs, KV, Dh) — this layer's page pool (page 0 is
    the trash block).  ``write_slot`` (B,) is the flat pool slot
    ``block_id * bs + pos % bs`` for the new token, precomputed once by
    the caller from the lane block tables; ``gather_idx`` (B, S) maps
    each lane's logical position to its flat pool slot; ``kpos`` (S,)
    are the logical positions themselves; ``block_tables`` (B, M) are
    the per-lane page ids (consumed by the Pallas kernel path).  Slot
    validity is derived
    from positions (``kpos <= pos``), so the gathered view is laid out
    exactly like the dense cache — greedy decoding through pages
    bit-matches the dense path (tests/test_scheduler.py).

    With ``k_scale``/``v_scale`` set ((P, bs, KV) f32 per-(slot,
    kv-head) scale pages, ``cfg.kv_quant``), the pages are int8: the
    new token's K/V are quantized before the scatter and the attention
    reads dequantize — fused in the quant Pallas kernel on TPU, as a
    transient gathered view on the jnp path.  A trash-routed write
    lands garbage values AND a garbage scale in page 0, which is safe
    for the same reason garbage values alone are: those slots are
    always masked, so their probs are exact zeros whatever the slot
    dequantizes to.

    ``use_kernel=None`` picks the Pallas paged-attention kernel on TPU
    and the pure-jnp gather path elsewhere; the jnp path is the
    semantic reference the kernel is tested against.
    Returns (out (B,1,D), k_pages, v_pages) — plus the updated scale
    pages when quantized.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    pb, bs = k_pages.shape[0], k_pages.shape[1]
    x = x.astype(cdt)
    q = (x @ p["wq"].astype(cdt)).reshape(b, 1, cfg.n_heads, dh)
    k = (x @ p["wk"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, dh)
    v = (x @ p["wv"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, dh)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    quant = k_scale is not None
    k_flat = k_pages.reshape(pb * bs, cfg.n_kv_heads, dh)
    v_flat = v_pages.reshape(pb * bs, cfg.n_kv_heads, dh)
    if quant:
        kq, ks = quantize_kv(k[:, 0])
        vq, vs = quantize_kv(v[:, 0])
        k_flat = k_flat.at[write_slot].set(kq)
        v_flat = v_flat.at[write_slot].set(vq)
        ks_flat = k_scale.reshape(pb * bs, cfg.n_kv_heads)
        vs_flat = v_scale.reshape(pb * bs, cfg.n_kv_heads)
        ks_flat = ks_flat.at[write_slot].set(ks)
        vs_flat = vs_flat.at[write_slot].set(vs)
    else:
        k_flat = k_flat.at[write_slot].set(k[:, 0].astype(k_flat.dtype))
        v_flat = v_flat.at[write_slot].set(v[:, 0].astype(v_flat.dtype))

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel and quant:
        from repro.kernels.paged_attention import paged_decode_attention_quant
        out = paged_decode_attention_quant(
            q, k_flat.reshape(pb, bs, cfg.n_kv_heads, dh),
            v_flat.reshape(pb, bs, cfg.n_kv_heads, dh),
            ks_flat.reshape(pb, bs, cfg.n_kv_heads),
            vs_flat.reshape(pb, bs, cfg.n_kv_heads),
            block_tables, pos + 1, window=window)
    elif use_kernel:
        from repro.kernels.paged_attention import paged_decode_attention
        out = paged_decode_attention(
            q, k_flat.reshape(pb, bs, cfg.n_kv_heads, dh),
            v_flat.reshape(pb, bs, cfg.n_kv_heads, dh),
            block_tables, pos + 1, window=window)
    else:
        # gather the lane's logical cache view (B, S, KV, Dh); transient
        # per layer, exactly the dense layout so masking/softmax match
        # the dense path bit-for-bit
        if quant:
            k_att = dequantize_kv(k_flat[gather_idx], ks_flat[gather_idx],
                                  cdt)
            v_att = dequantize_kv(v_flat[gather_idx], vs_flat[gather_idx],
                                  cdt)
        else:
            k_att = k_flat[gather_idx]
            v_att = v_flat[gather_idx]
        k_positions = jnp.broadcast_to(kpos[None, :], gather_idx.shape)
        valid = kpos[None, :] <= pos[:, None]
        if kpos.shape[0] > 64 * 1024:     # same switch as the dense path
            out = chunked_attention(cfg, q, k_att, v_att, pos[:, None],
                                    k_positions, window, valid_k=valid,
                                    block=8192)
        else:
            out = direct_attention(cfg, q, k_att, v_att, pos[:, None],
                                   k_positions, window, valid_k=valid)
    out = out.reshape(b, 1, cfg.n_heads * dh) @ p["wo"].astype(cdt)
    if quant:
        return (out, k_flat.reshape(pb, bs, cfg.n_kv_heads, dh),
                v_flat.reshape(pb, bs, cfg.n_kv_heads, dh),
                ks_flat.reshape(pb, bs, cfg.n_kv_heads),
                vs_flat.reshape(pb, bs, cfg.n_kv_heads))
    return (out, k_flat.reshape(pb, bs, cfg.n_kv_heads, dh),
            v_flat.reshape(pb, bs, cfg.n_kv_heads, dh))


def attention_decode(cfg: ModelConfig, p, x, pos, k_cache, v_cache, cache_pos, window,
                     k_scale=None, v_scale=None):
    """One-token decode.

    x: (B,1,D); pos: (B,) absolute position of the new token.
    k_cache/v_cache: (B,Sc,KV,Dh) — this layer's slice; the new token is
    written at slot pos %% Sc and the UPDATED slice is returned.  The
    caller (model.decode_step) threads the stacked cache as a scan CARRY
    with dynamic_update_index_in_dim so XLA updates it in place —
    stacking updated slices as scan ys instead doubles the cache
    footprint (2 x 4.8 GB/dev on musicgen decode_32k; §Perf).
    cache_pos: (B,Sc) absolute positions per slot (-1 = empty), already
    including the new token's slot.
    Returns (out (B,1,D), k_cache, v_cache).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    sc = k_cache.shape[1]
    x = x.astype(cdt)
    q = (x @ p["wq"].astype(cdt)).reshape(b, 1, cfg.n_heads, dh)
    k = (x @ p["wk"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, dh)
    v = (x @ p["wv"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, dh)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    slot = (pos % sc).astype(jnp.int32)
    bidx = jnp.arange(b)
    quant = k_scale is not None
    if quant:
        kq, ks = quantize_kv(k[:, 0])
        vq, vs = quantize_kv(v[:, 0])
        k_cache = k_cache.at[bidx, slot].set(kq)
        v_cache = v_cache.at[bidx, slot].set(vq)
        k_scale = k_scale.at[bidx, slot].set(ks)
        v_scale = v_scale.at[bidx, slot].set(vs)
        # transient per-layer dequantized view (one layer at a time)
        k_att = dequantize_kv(k_cache, k_scale, cdt)
        v_att = dequantize_kv(v_cache, v_scale, cdt)
    else:
        k_cache = k_cache.at[bidx, slot].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, slot].set(v[:, 0].astype(v_cache.dtype))
        k_att, v_att = k_cache, v_cache

    valid = cache_pos >= 0                                            # (B,Sc)
    if sc > 64 * 1024:
        out = chunked_attention(cfg, q, k_att, v_att, pos[:, None], cache_pos,
                                window, valid_k=valid, block=8192)
    else:
        out = direct_attention(cfg, q, k_att, v_att, pos[:, None], cache_pos,
                               window, valid_k=valid)
    out = out.reshape(b, 1, cfg.n_heads * dh) @ p["wo"].astype(cdt)
    if quant:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache
