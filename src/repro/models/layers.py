"""Basic layers: norms, MLPs, embeddings — pure-JAX, functional style.

Params are plain nested dicts of jnp arrays; every module is a pair of
``init_*`` / ``apply_*`` functions so layer stacks can be vmapped/scanned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.rms_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.rms_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_gated(p_scale, x, gate, eps=1e-6):
    """Mamba2 gated RMSNorm: norm(x * silu(gate)) * scale."""
    x = x * jax.nn.silu(gate)
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * p_scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------

def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name}")


def mlp_init(cfg: ModelConfig, key, d: int, d_ff: int, dtype):
    if cfg.mlp_gated:
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wi_gate": dense_init(k1, d, d_ff, dtype),
            "wi_up": dense_init(k2, d, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d, dtype),
        }
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d, d_ff, dtype), "wo": dense_init(k2, d_ff, d, dtype)}


def apply_mlp(cfg: ModelConfig, p, x):
    cdt = _dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    if cfg.mlp_gated:
        h = _act(cfg.activation, x @ p["wi_gate"].astype(cdt)) * (x @ p["wi_up"].astype(cdt))
        return h @ p["wo"].astype(cdt)
    h = _act(cfg.activation, x @ p["wi"].astype(cdt))
    return h @ p["wo"].astype(cdt)


# ----------------------------------------------------------------------
# Embeddings / head
# ----------------------------------------------------------------------

def embed_init(cfg: ModelConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {"embedding": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def embed_tokens(cfg: ModelConfig, p, tokens):
    cdt = _dtype(cfg.compute_dtype)
    x = p["embedding"].astype(cdt)[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    return x


def logits_from_hidden(cfg: ModelConfig, p, x):
    cdt = _dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        return x.astype(cdt) @ p["embedding"].astype(cdt).T
    return x.astype(cdt) @ p["lm_head"].astype(cdt)
