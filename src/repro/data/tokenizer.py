"""Character-level tokenizer.

Offline container => no sentencepiece/BPE assets; the synthetic task
suite is ASCII so a char vocab is lossless, keeps the tiny-model vocab
small, and makes output-token counts (the paper's latency/cost proxy)
directly comparable across methods.
"""

from __future__ import annotations

import string
from typing import List

PAD, BOS, EOS = 0, 1, 2
_SPECIALS = ["<pad>", "<bos>", "<eos>"]
_CHARS = string.printable  # 100 chars


class CharTokenizer:
    def __init__(self):
        self.itos = list(_SPECIALS) + list(_CHARS)
        self.stoi = {c: i for i, c in enumerate(self.itos)}
        self.vocab_size = len(self.itos)
        self.pad_id, self.bos_id, self.eos_id = PAD, BOS, EOS

    def encode(self, text: str, bos: bool = False, eos: bool = False) -> List[int]:
        ids = [self.stoi[c] for c in text if c in self.stoi]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i == EOS:
                break
            if i >= len(_SPECIALS):
                out.append(self.itos[i])
        return "".join(out)


_DEFAULT = None


def default_tokenizer() -> CharTokenizer:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = CharTokenizer()
    return _DEFAULT
