"""Synthetic task suite with machine-checkable answers and controllable
difficulty + verbosity.

Why synthetic: the container is offline (no MMLU/GSM8K/HF checkpoints),
and SATER's pipeline needs exactly two properties from its data — (1)
per-question correctness is checkable (drives Stage-II confidence labels
and all routing metrics) and (2) responses have a verbose and a concise
surface form (gives Stage-I something to compress).  Difficulty knobs let
benchmarks span "SLM solves easily" to "only the LLM (oracle) solves",
mirroring the paper's six benchmarks of varying type and complexity.

Benchmarks (paper analogue in brackets):
  modchain     [GSM8K]     chained modular arithmetic, diff = chain length
  kbhop        [MMLU]      multi-hop lookup over an in-context KB, diff = hops
  parity       [ReClor]    logical parity over bit strings, diff = length
  arith        [ARC-E]     single-op arithmetic, easy
  modchain-xl  [MATH-500]  OOD: longer chains than trained on
  kbhop-xl     [ARC-C]     OOD: more hops/entities than trained on

Responses always terminate with ``Answer: <ans>.``; verbose responses
prepend step-by-step working (the redundancy Stage-I learns to cut).
"""

from __future__ import annotations

import dataclasses
import random
import re
import zlib
from typing import Callable, Dict, List, Optional


def stable_hash(text: str) -> int:
    """Process-independent text hash.  Python's hash() is randomized per
    process (PYTHONHASHSEED), so seeding data generation or oracles with
    it makes benchmark items differ between runs; crc32 does not."""
    return zlib.crc32(text.encode())

REJECTION = "Sorry, I can't answer that."
CONF_PROMPT = "Please respond with a confidence level of [{level:.1f}]:\n"
ANSWER_RE = re.compile(r"Answer:\s*([^\s.]+)")


@dataclasses.dataclass
class TaskItem:
    benchmark: str
    difficulty: int
    question: str
    answer: str
    steps: List[str]               # verbose working lines

    def response(self, verbosity: int) -> str:
        """verbosity v in [0, len(steps)]: include the last v steps."""
        v = max(0, min(verbosity, len(self.steps)))
        lines = self.steps[:v] if v else []
        return " ".join(lines + [f"Answer: {self.answer}."])

    @property
    def concise(self) -> str:
        return self.response(0)

    @property
    def verbose(self) -> str:
        return self.response(len(self.steps))


def extract_answer(text: str) -> Optional[str]:
    m = ANSWER_RE.search(text)
    return m.group(1) if m else None


def is_correct(item: TaskItem, text: str) -> bool:
    return extract_answer(text) == item.answer


def is_rejection(text: str) -> bool:
    return text.strip().startswith(REJECTION[:10])


# ----------------------------------------------------------------------
# Generators
# ----------------------------------------------------------------------

def gen_modchain(rng: random.Random, difficulty: int, mod: int = 10) -> TaskItem:
    """((a op b) op c ...) mod p — difficulty = number of ops.

    mod=10 keeps every intermediate a single digit so a char-level model
    can learn the (digit, op, digit) transition table; longer chains
    compound error => a clean difficulty gradient (the GSM8K stand-in).
    Step strings are compact ("s1:+3=9.") so verbose responses fit the
    CPU-scale generation budget."""
    vals = [rng.randint(2, 9) for _ in range(difficulty + 1)]
    ops = [rng.choice(["+", "*"]) for _ in range(difficulty)]
    acc = vals[0]
    steps = []
    for i, op in enumerate(ops):
        nxt = vals[i + 1]
        acc = (acc + nxt) % mod if op == "+" else (acc * nxt) % mod
        steps.append(f"s{i+1}:{op}{nxt}={acc}.")
    expr = str(vals[0]) + "".join(f" {o} {v}" for o, v in zip(ops, vals[1:]))
    q = f"Compute ({expr}) mod {mod}."
    return TaskItem("modchain", difficulty, q, str(acc), steps)


def gen_kbhop(rng: random.Random, difficulty: int, n_entities: int = 6) -> TaskItem:
    """Multi-hop chasing over in-context facts — difficulty = hops.

    Compact surface form ("Bo>Ka.") keeps the whole prompt + verbose
    response inside the CPU-scale max_len; the skill tested (in-context
    pointer chasing / induction) is unchanged."""
    names = rng.sample([f"{a}{b}" for a in "BCDFGHJKLMNP" for b in "aeiou"],
                       n_entities)
    succ = {names[i]: names[(i + rng.randint(1, n_entities - 1)) % n_entities]
            for i in range(n_entities)}
    facts = [f"{a}>{b}." for a, b in succ.items()]
    rng.shuffle(facts)
    start = rng.choice(names)
    cur = start
    steps = []
    for h in range(difficulty):
        cur = succ[cur]
        steps.append(f"h{h+1}:{cur}.")
    q = (" ".join(facts) +
         f" From {start} follow > {difficulty} times. Who?")
    return TaskItem("kbhop", difficulty, q, cur, steps)


def gen_parity(rng: random.Random, difficulty: int) -> TaskItem:
    """Parity of a bit string — difficulty = length/4."""
    n = 4 * difficulty
    bits = [rng.randint(0, 1) for _ in range(n)]
    ones = sum(bits)
    steps = [f"b{i+1}:{sum(bits[4*i:4*i+4])}."
             for i in range(difficulty)]
    q = f"Is the number of 1s in {''.join(map(str, bits))} even or odd?"
    return TaskItem("parity", difficulty, q, "even" if ones % 2 == 0 else "odd", steps)


def gen_arith(rng: random.Random, difficulty: int) -> TaskItem:
    """Single-op small arithmetic (easy benchmark)."""
    a = rng.randint(2, 9 + 5 * difficulty)
    b = rng.randint(2, 9)
    op = rng.choice(["+", "-"])
    ans = a + b if op == "+" else a - b
    return TaskItem("arith", difficulty, f"Compute {a} {op} {b}.", str(ans),
                    [f"s1:{a}{op}{b}={ans}."])


GENERATORS: Dict[str, Callable] = {
    "modchain": gen_modchain,
    "kbhop": gen_kbhop,
    "parity": gen_parity,
    "arith": gen_arith,
}

# benchmark -> (generator, difficulty range)
BENCHMARKS: Dict[str, tuple] = {
    # in-domain (training distributions)
    "modchain": ("modchain", (1, 6)),
    "kbhop": ("kbhop", (1, 4)),
    "parity": ("parity", (1, 5)),
    "arith": ("arith", (1, 3)),
    # out-of-domain (harder variants, never trained on)
    "modchain-xl": ("modchain", (7, 10)),
    "kbhop-xl": ("kbhop", (5, 7)),
}

IN_DOMAIN = ("modchain", "kbhop", "parity", "arith")
OUT_OF_DOMAIN = ("modchain-xl", "kbhop-xl")


def make_benchmark(name: str, n: int, seed: int = 0) -> List[TaskItem]:
    gen_name, (lo, hi) = BENCHMARKS[name]
    gen = GENERATORS[gen_name]
    rng = random.Random(seed * 7919 + stable_hash(name) % 10000)
    items = []
    for i in range(n):
        d = lo + (i % (hi - lo + 1))
        it = gen(rng, d)
        it.benchmark = name
        items.append(it)
    return items


def make_training_mix(n_per_benchmark: int, seed: int = 0) -> List[TaskItem]:
    items = []
    for b in IN_DOMAIN:
        items.extend(make_benchmark(b, n_per_benchmark, seed=seed + 1))
    rng = random.Random(seed)
    rng.shuffle(items)
    return items
