"""Batching: task items -> padded token batches (host-side numpy, device
conversion at the jitted step boundary).

Batch kinds:
  * SFT:        {"tokens": (B,S), "loss_mask": (B,S)}  — mask on response
  * preference: {"chosen","chosen_mask","rejected","rejected_mask"}
  * prompts:    (B,S) left-padded token prompts + lengths, for the engine
"""

from __future__ import annotations

import random
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.data.tasks import CONF_PROMPT, TaskItem
from repro.data.tokenizer import CharTokenizer


def format_prompt(item: TaskItem, conf_level: Optional[float] = None) -> str:
    p = f"Q: {item.question}\nA: "
    if conf_level is not None:
        p = CONF_PROMPT.format(level=conf_level) + p
    return p


def encode_pair(tok: CharTokenizer, prompt: str, response: str,
                max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    p_ids = tok.encode(prompt, bos=True)
    r_ids = tok.encode(response, eos=True)
    ids = (p_ids + r_ids)[:max_len]
    mask = ([0] * len(p_ids) + [1] * len(r_ids))[:max_len]
    toks = np.full((max_len,), tok.pad_id, np.int32)
    m = np.zeros((max_len,), np.int32)
    toks[: len(ids)] = ids
    m[: len(mask)] = mask
    return toks, m


def sft_batches(pairs: Sequence[Tuple[str, str]], tok: CharTokenizer,
                batch_size: int, max_len: int, seed: int = 0,
                epochs: int = 1, drop_remainder: bool = True) -> Iterator[dict]:
    """pairs: list of (prompt, response) strings."""
    rng = random.Random(seed)
    idx = list(range(len(pairs)))
    for _ in range(epochs):
        rng.shuffle(idx)
        for i in range(0, len(idx) - (batch_size - 1 if drop_remainder else 0),
                       batch_size):
            chunk = idx[i:i + batch_size]
            if drop_remainder and len(chunk) < batch_size:
                break
            toks, masks = zip(*(encode_pair(tok, *pairs[j], max_len) for j in chunk))
            yield {"tokens": np.stack(toks), "loss_mask": np.stack(masks)}


def preference_batches(prefs: Sequence[Tuple[str, str, str]], tok: CharTokenizer,
                       batch_size: int, max_len: int, seed: int = 0,
                       epochs: int = 1) -> Iterator[dict]:
    """prefs: list of (prompt, chosen_response, rejected_response)."""
    rng = random.Random(seed)
    idx = list(range(len(prefs)))
    for _ in range(epochs):
        rng.shuffle(idx)
        for i in range(0, len(idx) - batch_size + 1, batch_size):
            chunk = idx[i:i + batch_size]
            enc_c = [encode_pair(tok, prefs[j][0], prefs[j][1], max_len) for j in chunk]
            enc_r = [encode_pair(tok, prefs[j][0], prefs[j][2], max_len) for j in chunk]
            yield {
                "chosen": np.stack([e[0] for e in enc_c]),
                "chosen_mask": np.stack([e[1] for e in enc_c]),
                "rejected": np.stack([e[0] for e in enc_r]),
                "rejected_mask": np.stack([e[1] for e in enc_r]),
            }


def encode_prompts(prompts: Sequence[str], tok: CharTokenizer,
                   max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """Right-padded prompt batch + lengths (engine prefill format)."""
    ids = [tok.encode(p, bos=True)[:max_len] for p in prompts]
    lens = np.array([len(i) for i in ids], np.int32)
    out = np.full((len(ids), max_len), tok.pad_id, np.int32)
    for r, i in enumerate(ids):
        out[r, : len(i)] = i
    return out, lens
