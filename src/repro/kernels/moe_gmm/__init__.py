from repro.kernels.moe_gmm.ops import moe_gmm  # noqa: F401
