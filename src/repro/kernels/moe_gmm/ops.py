"""Jitted public wrapper for the grouped expert matmul."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm.kernel import moe_gmm_pallas


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def moe_gmm(x, w, block_c: int = 128, block_f: int = 128, block_d: int = 256,
            interpret: bool = None):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F); pads C/F/D to blocks."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e, c, d = x.shape
    f = w.shape[-1]
    pc, pf, pd = (-c) % block_c, (-f) % block_f, (-d) % block_d
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    out = moe_gmm_pallas(x, w, block_c=block_c, block_f=block_f,
                         block_d=block_d, interpret=interpret)
    return out[:, :c, :f]
