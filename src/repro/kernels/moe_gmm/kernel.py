"""Grouped expert matmul — Pallas TPU kernel.

The MoE dispatch buffer (E, C, D) times per-expert weights (E, D, F) is
the compute hot-spot of the MoE archs (olmoe: 64 experts; llama4: 16).
Blocking: grid (E, C/bc, F/bf, D/bd), accumulating over the D axis in a
(bc x bf) f32 VMEM scratch — standard MXU-tiled matmul per expert, with
the expert dim as the outermost grid axis so weights stream once per
expert.  Block sizes are 128-multiples (MXU systolic dims).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    di = pl.program_id(3)
    n_d = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)        # (bc, bd)
    w = w_ref[0].astype(jnp.float32)        # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())))

    @pl.when(di == n_d - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_pallas(x, w, *, block_c: int = 128, block_f: int = 128,
                   block_d: int = 256, interpret: bool = False):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    e, c, d = x.shape
    f = w.shape[-1]
    grid = (e, pl.cdiv(c, block_c), pl.cdiv(f, block_f), pl.cdiv(d, block_d))
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda ee, ci, fi, di: (ee, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda ee, ci, fi, di: (ee, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ee, ci, fi, di: (ee, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
