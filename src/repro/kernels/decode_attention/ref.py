"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths, window: int = 0):
    """q: (B, H, 1, D); k, v: (B, KV, S, D); lengths: (B,) -> (B, H, 1, D)."""
    b, h, _, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d).astype(jnp.float32) * d ** -0.5
    s_mat = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32))
    kpos = jnp.arange(s)[None, :]
    mask = kpos < lengths[:, None]
    if window > 0:
        mask = mask & (kpos >= lengths[:, None] - window)
    s_mat = jnp.where(mask[:, None, None, :], s_mat, NEG_INF)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)
