"""Jitted public wrapper for decode attention."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k, v, lengths, window: int = 0, block_k: int = 512,
                     interpret: bool = None):
    """q: (B, 1, H, D); k, v: (B, S, KV, D); lengths: (B,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    s = kt.shape[2]
    pad = (-s) % block_k
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = decode_attention_pallas(qt, kt, vt, lengths.astype(jnp.int32),
                                  block_k=block_k, window=window,
                                  interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
