"""Single-token GQA decode attention over a long KV cache — Pallas TPU.

SATER's cascade decodes K vote lanes simultaneously; the per-step cost is
reading the KV cache (memory-bound).  This kernel streams the cache in
(block_k x D) VMEM tiles with flash-decode online softmax, masking
invalid slots by per-lane length and optional sliding window.

Grid: (batch, q_heads, S_cache/block_k); the last axis is sequential so
m/l/acc carry in VMEM scratch.  Lengths live in a (B,) int32 input block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
MIN_LANE = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_k: int, window: int):
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    k_start = ki * block_k
    in_range = k_start < length
    in_window = True if window <= 0 else (k_start + block_k - 1 >= length - window)

    @pl.when(in_range & in_window)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = kpos < length
        if window > 0:
            mask = mask & (kpos >= length - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                    # (1, 128)
        m_cur = jnp.max(s, axis=-1)[:, None]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        p = jnp.exp(s - m_new[:, :1])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)[:, None]
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, :1] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, block_k: int = 512,
                            window: int = 0, interpret: bool = False):
    """q: (B, H, 1, D); k, v: (B, KV, S, D); lengths: (B,) -> (B, H, 1, D).

    Valid cache slots for lane b are [0, lengths[b]) (or the last
    ``window`` of them); the new token's k/v must already be written.
    """
    b, h, one, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    group = h // kv
    grid = (b, h, pl.cdiv(s, block_k))
    kernel = functools.partial(_decode_kernel, scale=d ** -0.5,
                               block_k=block_k, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, hh, ki: (bb,)),
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, ki: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, ki: (bb, hh // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, ki: (bb, hh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, hh, ki: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, MIN_LANE), jnp.float32),
            pltpu.VMEM((1, MIN_LANE), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
