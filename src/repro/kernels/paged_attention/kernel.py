"""Paged single-token GQA decode attention — Pallas TPU.

The paged serving path (serving/scheduler.py ``paged=True``) keeps K/V
in a pool of ``(block_size x D)`` pages shared by all lanes; each lane
owns an ordered *block table* of page ids.  This kernel is the paged
sibling of kernels/decode_attention: same flash-decode online softmax
over a sequential cache-block grid axis, but the K/V tile for grid
step ``ki`` is fetched *through the block table* — the BlockSpec index
map reads ``block_table[b, ki]`` from a scalar-prefetch operand, so
the gather happens in the DMA engine and the discontiguous pool is
never materialized as a per-lane contiguous cache.

Grid: (batch, q_heads, max_blocks); the last axis is sequential so the
m/l/acc flash state carries in VMEM scratch.  Blocks at or past a
lane's length are skipped (their DMA still runs — same trade as the
dense decode kernel fetching past-length tiles).  The sliding window
is a traced scalar operand (the model's per-layer window scan value),
masking by absolute position ``ki * block_size + offset``.

Page 0 is the allocator's trash block; block-table entries past a
lane's allocation point at it and are always masked by length.

The quantized sibling (``_paged_quant_kernel``) fetches int8 pages
plus their per-(slot, kv-head) f32 scale pages through the same block
table and dequantizes *inside* the kernel — the f32 K/V tile exists
only in registers/VMEM for the one block being processed, never in
HBM, which is the whole point of the int8 cache layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
MIN_LANE = 128


def _flash_update(q, k, v, k_start, length, window, block_size,
                  m_ref, l_ref, acc_ref):
    """One online-softmax block update shared by the fp and quantized
    kernels: q (1, d) pre-scaled, k/v (bs, d) already f32."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (1, bs)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    mask = kpos < length
    mask = mask & jnp.where(window > 0, kpos >= length - window, True)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                    # (1, 128)
    m_cur = jnp.max(s, axis=-1)[:, None]
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    p = jnp.exp(s - m_new[:, :1])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)[:, None]
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, :1] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))


def _paged_kernel(bt_ref, len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, block_size: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    window = win_ref[0]
    k_start = ki * block_size
    in_range = k_start < length
    in_window = jnp.where(window > 0,
                          k_start + block_size - 1 >= length - window, True)

    @pl.when(in_range & in_window)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bs, d)
        v = v_ref[0, 0].astype(jnp.float32)
        _flash_update(q, k, v, k_start, length, window, block_size,
                      m_ref, l_ref, acc_ref)

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _paged_quant_kernel(bt_ref, len_ref, win_ref, q_ref, k_ref, v_ref,
                        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        scale: float, block_size: int):
    """Dequant-fused variant: k/v pages arrive int8 with per-(slot,
    kv-head) f32 scale pages gathered through the same block table;
    the f32 tile exists only for the block in flight."""
    b = pl.program_id(0)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    window = win_ref[0]
    k_start = ki * block_size
    in_range = k_start < length
    in_window = jnp.where(window > 0,
                          k_start + block_size - 1 >= length - window, True)

    @pl.when(in_range & in_window)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, d)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]     # (bs, d)*(bs, 1)
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
        _flash_update(q, k, v, k_start, length, window, block_size,
                      m_ref, l_ref, acc_ref)

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                  window, *, interpret: bool = False):
    """q: (B, H, 1, D); k_pages, v_pages: (P, KV, bs, D);
    block_tables: (B, M) int32 page ids; lengths: (B,); window: (1,)
    int32 (0 = full attention).  Returns (B, H, 1, D).

    Valid slots for lane b are logical positions [0, lengths[b]), laid
    out block-table order: position p lives in page
    ``block_tables[b, p // bs]`` at offset ``p % bs``.  The new token's
    K/V must already be written to its page.
    """
    b, h, _, d = q.shape
    kv, bs = k_pages.shape[1], k_pages.shape[2]
    m = block_tables.shape[1]
    group = h // kv
    grid = (b, h, m)
    kernel = functools.partial(_paged_kernel, scale=d ** -0.5, block_size=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,     # block_tables, lengths, window
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, ki, bt, ln, w:
                         (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bb, hh, ki, bt, ln, w:
                         (bt[bb, ki], hh // group, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bb, hh, ki, bt, ln, w:
                         (bt[bb, ki], hh // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, hh, ki, bt, ln, w:
                               (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, MIN_LANE), jnp.float32),
            pltpu.VMEM((1, MIN_LANE), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, window, q, k_pages, v_pages)


def paged_decode_attention_quant_pallas(q, k_pages, v_pages, k_scale,
                                        v_scale, block_tables, lengths,
                                        window, *, interpret: bool = False):
    """q: (B, H, 1, D); k_pages, v_pages: (P, KV, bs, D) int8;
    k_scale, v_scale: (P, KV, bs, 1) f32 per-(slot, kv-head) absmax
    scales; block_tables: (B, M) int32; lengths: (B,); window: (1,)
    int32.  Returns (B, H, 1, D) in q.dtype.

    Same grid and flash state as :func:`paged_decode_attention_pallas`;
    the scale pages ride two extra BlockSpecs through the identical
    block-table index map, and dequantization happens on the tile in
    VMEM — int8 is the only K/V representation that ever leaves HBM.
    """
    b, h, _, d = q.shape
    kv, bs = k_pages.shape[1], k_pages.shape[2]
    m = block_tables.shape[1]
    group = h // kv
    grid = (b, h, m)
    kernel = functools.partial(_paged_quant_kernel, scale=d ** -0.5,
                               block_size=bs)
    page_spec = pl.BlockSpec((1, 1, bs, d), lambda bb, hh, ki, bt, ln, w:
                             (bt[bb, ki], hh // group, 0, 0))
    scale_spec = pl.BlockSpec((1, 1, bs, 1), lambda bb, hh, ki, bt, ln, w:
                              (bt[bb, ki], hh // group, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,     # block_tables, lengths, window
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, ki, bt, ln, w:
                         (bb, hh, 0, 0)),
            page_spec, page_spec, scale_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, hh, ki, bt, ln, w:
                               (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, MIN_LANE), jnp.float32),
            pltpu.VMEM((1, MIN_LANE), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, window, q, k_pages, v_pages, k_scale, v_scale)
