from repro.kernels.paged_attention.ops import (  # noqa: F401
    paged_decode_attention, paged_decode_attention_quant)
