"""Jitted public wrapper for paged decode attention.

Layout contract matches the model layer: q ``(B, 1, H, D)`` and pages
``(P, bs, KV, D)`` (slot-major, like the dense cache's ``(B, S, KV,
D)`` with (page, offset) replacing (lane, position)); the kernel wants
heads outermost, so the wrapper transposes.  ``window`` may be a
traced scalar (the model passes the per-layer window from inside the
layer scan) — it is shipped to the kernel as a scalar-prefetch
operand, not baked into the compiled executable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention_pallas, paged_decode_attention_quant_pallas)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths,
                           window=0, interpret: bool = None):
    """q: (B, 1, H, D); k_pages, v_pages: (P, bs, KV, D);
    block_tables: (B, M) int32; lengths: (B,); window: int or scalar
    (0 = full).  Returns (B, 1, H, D).

    Off-TPU this runs the kernel in Pallas interpret mode (slow, exact
    semantics) so the whole paged path stays testable on CPU hosts.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = jnp.swapaxes(q, 1, 2)                       # (B, H, 1, D)
    kt = jnp.transpose(k_pages, (0, 2, 1, 3))        # (P, KV, bs, D)
    vt = jnp.transpose(v_pages, (0, 2, 1, 3))
    bt = jnp.maximum(block_tables.astype(jnp.int32), 0)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    out = paged_decode_attention_pallas(qt, kt, vt, bt,
                                        lengths.astype(jnp.int32), win,
                                        interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_quant(q, k_pages, v_pages, k_scale, v_scale,
                                 block_tables, lengths, window=0,
                                 interpret: bool = None):
    """Dequant-fused paged decode attention.

    q: (B, 1, H, D); k_pages, v_pages: (P, bs, KV, D) int8; k_scale,
    v_scale: (P, bs, KV) f32 per-(slot, kv-head) absmax scales (the
    model cache layout — slot-major, like the values); block_tables:
    (B, M) int32; lengths: (B,); window: int or scalar (0 = full).
    Returns (B, 1, H, D) in q.dtype.

    The kernel gathers int8 pages AND their scale pages through the
    block table and dequantizes in VMEM; off-TPU it runs in Pallas
    interpret mode like the fp kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = jnp.swapaxes(q, 1, 2)                       # (B, H, 1, D)
    kt = jnp.transpose(k_pages, (0, 2, 1, 3))        # (P, KV, bs, D)
    vt = jnp.transpose(v_pages, (0, 2, 1, 3))
    kst = jnp.transpose(k_scale, (0, 2, 1))[..., None]   # (P, KV, bs, 1)
    vst = jnp.transpose(v_scale, (0, 2, 1))[..., None]
    bt = jnp.maximum(block_tables.astype(jnp.int32), 0)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    out = paged_decode_attention_quant_pallas(
        qt, kt, vt, kst, vst, bt, lengths.astype(jnp.int32), win,
        interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
