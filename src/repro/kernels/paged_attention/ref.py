"""Pure-jnp oracle for paged decode attention: gather pages into the
contiguous per-lane layout, then plain masked decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               window: int = 0):
    """q: (B, H, 1, D); k_pages, v_pages: (P, KV, bs, D);
    block_tables: (B, M); lengths: (B,) -> (B, H, 1, D)."""
    b, h, _, d = q.shape
    kv, bs = k_pages.shape[1], k_pages.shape[2]
    m = block_tables.shape[1]
    s = m * bs
    g = h // kv
    # (B, M, KV, bs, D) -> (B, KV, M*bs, D): lane-contiguous logical cache
    k = jnp.transpose(k_pages[block_tables], (0, 2, 1, 3, 4)).reshape(b, kv, s, d)
    v = jnp.transpose(v_pages[block_tables], (0, 2, 1, 3, 4)).reshape(b, kv, s, d)
    qg = q.reshape(b, kv, g, d).astype(jnp.float32) * d ** -0.5
    s_mat = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32))
    kpos = jnp.arange(s)[None, :]
    mask = kpos < lengths[:, None]
    if window > 0:
        mask = mask & (kpos >= lengths[:, None] - window)
    s_mat = jnp.where(mask[:, None, None, :], s_mat, NEG_INF)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)


def paged_decode_attention_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                     block_tables, lengths, window: int = 0):
    """Quantized oracle: dequantize the int8 pages with their
    per-(slot, kv-head) scales, then run the fp reference.

    q: (B, H, 1, D); k_pages, v_pages: (P, KV, bs, D) int8; k_scale,
    v_scale: (P, KV, bs) f32; block_tables: (B, M); lengths: (B,)
    -> (B, H, 1, D)."""
    k = k_pages.astype(jnp.float32) * k_scale[..., None]
    v = v_pages.astype(jnp.float32) * v_scale[..., None]
    return paged_decode_attention_ref(q, k, v, block_tables, lengths, window)
