"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, window: int = 0, softcap: float = 0.0):
    """q: (B, H, S, D); k, v: (B, KV, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = d ** -0.5
    qg = q.reshape(b, kv, g, s, d).astype(jnp.float32) * scale
    s_mat = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    if softcap and softcap > 0:
        s_mat = jnp.tanh(s_mat / softcap) * softcap
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s_mat = jnp.where(mask, s_mat, NEG_INF)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)
