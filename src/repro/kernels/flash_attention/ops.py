"""Jitted public wrapper: layout handling + CPU-interpret dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, window: int = 0, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """q: (B, S, H, D); k, v: (B, S, KV, D) — model-layout entry point.

    Pads S to a block multiple, runs the Pallas kernel (interpret mode on
    non-TPU backends), unpads.  Padding sits in the causal future of real
    queries so results are unaffected.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, d = q.shape
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    pad = (-s) % max(block_q, block_k)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = flash_attention_pallas(qt, kt, vt, block_q=block_q, block_k=block_k,
                                 window=window, softcap=softcap,
                                 interpret=interpret)
    if pad:
        out = out[:, :, :s]
    return jnp.swapaxes(out, 1, 2)
