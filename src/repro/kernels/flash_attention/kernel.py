"""Flash (streaming-softmax) causal GQA attention — Pallas TPU kernel.

The cascade engine's prefill is generation-latency-critical for SATER
(the SLM must prefill K vote lanes); this kernel keeps the working set
in VMEM with (block_q x block_k) tiles and never materializes the
(S x S) score matrix.

Grid: (batch, q_heads, S_q/block_q, S_k/block_k) — the last axis is
sequential on TPU, so online-softmax state (m, l, acc) lives in VMEM
scratch and carries across k-blocks.  m/l are lane-replicated to 128
(MIN_LANE) so vector ops stay register-shaped on the VPU; block sizes
should be multiples of 128 for MXU alignment (enforced in ops.py).

Supports GQA via index-mapped kv heads, causal masking, and optional
sliding windows (window == 0 -> full causal).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
MIN_LANE = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_k: int, window: int,
                 softcap: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip blocks that are entirely in the causal future / outside window
    in_causal = k_start <= q_start + block_q - 1
    in_window = True if window <= 0 else \
        (k_start + block_k - 1 > q_start - window)

    @pl.when(in_causal & in_window)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap and softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos <= qpos
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # (bq, 128)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)[:, None]                  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        p = jnp.exp(s - m_new[:, :1])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)[:, None]
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_ref[...] * corr[:, :1] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, block_q: int = 128, block_k: int = 128,
                           window: int = 0, softcap: float = 0.0,
                           interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, KV, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0
    group = h // kv
    scale = d ** -0.5
    grid = (b, h, pl.cdiv(s, block_q), pl.cdiv(s, block_k))

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        window=window, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),   # m
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),          # acc
        ],
        interpret=interpret,
    )(q, k, v)
