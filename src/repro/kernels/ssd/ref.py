"""Pure-jnp oracle for the SSD kernel: the sequential recurrence itself
(the ground-truth semantics, not the chunked algorithm)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xbar, a, bmat, cmat):
    """Sequential scan.

    xbar: (B,S,H,P); a: (B,S,H); bmat/cmat: (B,S,N) (G=1, shared heads).
    Returns y (B,S,H,P), final state (B,H,P,N).
    """
    bsz, s, h, p = xbar.shape
    n = bmat.shape[-1]

    def step(hstate, xs):
        xb, at, bt, ct = xs           # (B,H,P), (B,H), (B,N), (B,N)
        hstate = hstate * jnp.exp(at)[:, :, None, None] + \
            jnp.einsum("bhp,bn->bhpn", xb, bt)
        y = jnp.einsum("bhpn,bn->bhp", hstate, ct)
        return hstate, y

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.swapaxes(xbar.astype(jnp.float32), 0, 1),
          jnp.swapaxes(a.astype(jnp.float32), 0, 1),
          jnp.swapaxes(bmat.astype(jnp.float32), 0, 1),
          jnp.swapaxes(cmat.astype(jnp.float32), 0, 1))
    hlast, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1).astype(xbar.dtype), hlast
