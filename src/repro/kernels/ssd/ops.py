"""Jitted public wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xbar, a, bmat, cmat, chunk: int = 128, interpret: bool = None):
    """xbar: (B,S,H,P); a: (B,S,H); bmat/cmat: (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).  Pads S to a chunk
    multiple with zeros (dt = 0 => identity decay, no state change).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, p = xbar.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    t = xbar.shape[1] // chunk
    xk = xbar.reshape(b, t, chunk, h, p).transpose(0, 3, 1, 2, 4)
    ak = a.reshape(b, t, chunk, h).transpose(0, 3, 1, 2)
    bk = bmat.reshape(b, t, chunk, n)
    ck = cmat.reshape(b, t, chunk, n)
    y, state = ssd_pallas(xk, ak, bk, ck, interpret=interpret)
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, t * chunk, h, p)
    return y[:, :s], state
