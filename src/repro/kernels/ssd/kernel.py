"""Mamba2 SSD chunked scan — Pallas TPU kernel.

The SSD duality splits the recurrence into an intra-chunk quadratic part
(two (Q x Q) / (Q x N) matmuls -> MXU work) and an inter-chunk state
recurrence.  On TPU the natural mapping is: grid (batch, heads, chunks)
with the chunk axis sequential, carrying the (P x N) state in VMEM
scratch — the HBM->VMEM streaming unit is one chunk of x/B/C per step.

Inputs (pre-chunked by ops.py):
  xbar: (B, H, T, Q, P)   x * dt
  a:    (B, H, T, Q)      dt * A   (log-decay, <= 0)
  bmat: (B, T, Q, N)      shared across heads (G=1)
  cmat: (B, T, Q, N)
Output: y (B, H, T, Q, P) plus the final state (B, H, P, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, h_ref, *,
                chunk: int):
    ti = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)           # (Q, P)
    a = a_ref[0, 0, 0].astype(jnp.float32)           # (Q,)
    bm = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)             # (Q, N)

    cum_a = jnp.cumsum(a)                            # (Q,)
    # intra-chunk: scores[i,j] = (C_i . B_j) * exp(cum_a[i] - cum_a[j]), j<=i
    dec = cum_a[:, None] - cum_a[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(tri, jnp.exp(dec), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ()))) * l_mat
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())))      # (Q, P)

    # inter-chunk: y += exp(cum_a)[:,None] * (C @ h^T);  h: (P, N)
    h = h_ref[...]
    y += jnp.exp(cum_a)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())))

    # state update: h' = exp(a_tot) * h + x^T @ (B * exp(cum_a[-1]-cum_a))
    dec_end = jnp.exp(cum_a[-1] - cum_a)[:, None]                     # (Q,1)
    new_state = jax.lax.dot_general(x, bm * dec_end, (((0,), (0,)), ((), ())))
    h_ref[...] = jnp.exp(cum_a[-1]) * h + new_state

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ti == n_t - 1)
    def _emit_state():
        state_out_ref[0, 0] = h_ref[...].astype(state_out_ref.dtype)


def ssd_pallas(xbar, a, bmat, cmat, *, interpret: bool = False):
    """xbar: (B,H,T,Q,P); a: (B,H,T,Q); bmat/cmat: (B,T,Q,N)."""
    b, h, t, q, p = xbar.shape
    n = bmat.shape[-1]
    kernel = functools.partial(_ssd_kernel, chunk=q)
    return pl.pallas_call(
        kernel,
        grid=(b, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bb, hh, ti: (bb, hh, ti, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bb, hh, ti: (bb, hh, ti, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bb, hh, ti: (bb, ti, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bb, hh, ti: (bb, ti, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bb, hh, ti: (bb, hh, ti, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, ti: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, q, p), xbar.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xbar, a, bmat, cmat)
