"""SATER Stage-II data construction: confidence-aware refusal tuning
(paper §3 Stage II).

Resample each question K=10 times with the Stage-I model; empirical
accuracy acc in {0, 0.1, ..., 1.0}.  For each threshold t in
{0.1, ..., 1.0}: prepend "Please respond with a confidence level of [t]:";
target = a random correct sample if acc >= t, else the rejection template
"Sorry, I can't answer that."  Trained with plain SFT (same LoRA setup as
Stage I, no preference loss).
"""

from __future__ import annotations

import random
from typing import List, Sequence, Tuple

from repro.core.confidence import rcv_schedule
from repro.core.preferences import SampledQuestion
from repro.data.pipeline import format_prompt
from repro.data.tasks import REJECTION


def build_refusal_dataset(samples: Sequence[SampledQuestion],
                          seed: int = 0,
                          thresholds: Sequence[float] = None
                          ) -> List[Tuple[str, str]]:
    """Returns (prompt_with_confidence, target_response) pairs."""
    rng = random.Random(seed)
    thresholds = thresholds or rcv_schedule()
    out = []
    for sq in samples:
        flags = sq.correct_flags
        correct_texts = [t for t, f in zip(sq.texts, flags) if f]
        acc = sq.accuracy
        for t in thresholds:
            prompt = format_prompt(sq.item, conf_level=t)
            if acc >= t and correct_texts:
                target = rng.choice(correct_texts)
            else:
                target = REJECTION
            out.append((prompt, target))
    rng.shuffle(out)
    return out
