"""Token-level cost model (paper §2.1).

Defaults follow the paper: SLM $0.08 / M output tokens (Groq pricing),
LLM $1.10 (DeepSeek-V3) => output-cost ratio 1:13.75; input price is 1/4
of the respective output price.  Alternative ratios 1:25/1:50/1:100 are
explored in §5.1 — build them with :func:`with_ratio`.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostModel:
    slm_out: float = 0.08
    llm_out: float = 1.10
    input_fraction: float = 0.25

    @property
    def slm_in(self) -> float:
        return self.slm_out * self.input_fraction

    @property
    def llm_in(self) -> float:
        return self.llm_out * self.input_fraction

    @property
    def ratio(self) -> float:
        return self.llm_out / self.slm_out

    def slm_cost(self, t_in: int, t_out: int) -> float:
        return self.slm_in * t_in + self.slm_out * t_out

    def llm_cost(self, t_in: int, t_out: float) -> float:
        return self.llm_in * t_in + self.llm_out * t_out


def with_ratio(ratio: float, llm_out: float = 1.10) -> CostModel:
    """Cost model with a given LLM:SLM output-price ratio."""
    return CostModel(slm_out=llm_out / ratio, llm_out=llm_out)


DEFAULT = CostModel()          # 1:13.75
RATIOS = {13.75: DEFAULT, 25: with_ratio(25), 50: with_ratio(50),
          100: with_ratio(100)}
