"""Beyond-paper extension: multi-tier cascading.

The paper's Limitation §1 names multi-model collaborative routing as
future work.  This module generalizes SATER's two-model cascade to an
ordered chain of tiers

    tier_0 (cheapest SLM) -> tier_1 -> ... -> tier_{T-1} (terminal LLM)

where every non-terminal tier is a SATER-trained model queried with its
own (tau, mode, K) policy; a query falls through to the next tier when
the confidence-weighted vote stays below that tier's threshold.  The
terminal tier always answers.

Questions are streamed: each tier batches only its surviving questions
through the serving scheduler, and with ``stream_early_stop`` a tier's
vote lanes are killed in compute as soon as its tau is decided.  Each
question's K vote lanes travel as one RequestGroup, so a tier whose
``slm.share_prefix`` is set (paged serving) prefills every surviving
question once and shares its prompt KV blocks across the K lanes — the
"prompt once" cost model below is then real serving behaviour, not an
accounting convention.

Semantics kept from the paper's single-hop cascade:
  * per-tier K parallel samples + RCV/FCV weighted voting with early
    stopping (voting.decide_with_early_stop),
  * latency is token-count-based: AGL accumulates the *decision* latency
    of every tier that ran plus the accepted tier's generation; AROL is
    the overhead versus calling the terminal tier directly,
  * cost is token-level per tier with per-tier prices.

The two-tier special case reproduces routing.cascade_outcomes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import voting
from repro.core.confidence import fcv_schedule, rcv_schedule
from repro.core.routing import (SLM, VoteEarlyStop, make_scheduler, sample_k,
                                sample_k_streamed)
from repro.data.pipeline import format_prompt
from repro.data.tasks import TaskItem
from repro.serving.scheduler import Request, RequestGroup, SchedStats


@dataclasses.dataclass
class Tier:
    """A non-terminal cascade tier: a SATER model + its query policy."""
    slm: SLM
    tau: float = 0.6
    mode: str = "FCV"            # RCV | FCV
    k: int = 10
    out_price: float = 0.08      # $ / 1M output tokens
    in_price: float = 0.02

    def levels(self) -> List[Optional[float]]:
        return rcv_schedule(self.k) if self.mode == "RCV" \
            else fcv_schedule(self.k)


@dataclasses.dataclass
class TerminalTier:
    """The always-answers tier (API LLM or oracle)."""
    llm: object                  # OracleLLM / ModelLLM
    out_price: float = 1.10
    in_price: float = 0.275


@dataclasses.dataclass
class MultiOutcome:
    accepted_tier: int           # index in the chain (T-1 = terminal)
    correct: bool
    cost: float                  # absolute $ for this question
    agl: int                     # generation latency if non-terminal won
    arol: int                    # overhead latency if terminal answered


def _apply_placement(tiers: Sequence[Tier],
                     placement: "Optional[Dict[int, list]]") -> List[Tier]:
    """Resolve ``placement`` — tier index -> explicit device slice — into
    a tier chain whose SLMs carry per-tier meshes (``SLM.mesh``), so each
    placed tier's scheduler decodes under shard_map on exactly its slice
    (launch/mesh.make_tier_mesh).  Distinct tiers placed on DISJOINT
    slices therefore decode concurrently — the device-level overlap the
    pipelined driver's split-phase host loop exposes.

    Placement changes SLM object identity, which is what keys loop
    fusion: two tiers sharing one SLM *and* one slice still fuse onto a
    single loop (the replaced SLM is memoized per (slm, slice) pair),
    while the same SLM placed on two different slices deliberately
    un-fuses into two loops with duplicated params — concurrency bought
    with memory.  Unplaced tiers are left untouched.
    """
    if not placement:
        return list(tiers)
    from repro.launch.mesh import make_tier_mesh
    for t_i in placement:
        if not 0 <= t_i < len(tiers):
            raise ValueError(f"placement names tier {t_i} but the chain "
                             f"has {len(tiers)} tiers")
    memo: Dict[tuple, SLM] = {}
    out: List[Tier] = []
    for t_i, tier in enumerate(tiers):
        devs = placement.get(t_i)
        if devs is None:
            out.append(tier)
            continue
        mkey = (id(tier.slm), tuple(id(d) for d in devs))
        slm = memo.get(mkey)
        if slm is None:
            slm = dataclasses.replace(tier.slm, mesh=make_tier_mesh(devs))
            memo[mkey] = slm
        out.append(dataclasses.replace(tier, slm=slm))
    return out


def run_cascade(tiers: Sequence[Tier], terminal: TerminalTier,
                items: Sequence[TaskItem], key,
                stream_early_stop: bool = False,
                return_stats: bool = False,
                placement: "Optional[Dict[int, list]]" = None):
    """Drive every question through the tier chain, one tier at a time
    (each tier is a *barrier*: tier i+1 starts only after tier i has
    drained — see :func:`run_cascade_pipelined` for the overlapped
    form).

    Each tier streams only the questions that fell through every tier
    above it through the scheduler (continuous batching over the
    surviving K-lane vote groups), so deeper tiers never generate for
    already-answered questions.  With stream_early_stop=True, a tier's
    vote groups are additionally killed mid-flight by the VoteEarlyStop
    policy the moment that tier's tau is decided (true compute early
    stop); otherwise lanes run to completion and early stopping is the
    paper's token-accounting simulation (voting.decide_with_early_stop).

    With ``return_stats=True`` returns ``(outcomes, tier_stats)`` where
    ``tier_stats[i]`` is tier i's serving :class:`SchedStats` (None for
    a tier that ran in simulation mode or had no survivors).

    ``placement`` (tier index -> device slice, see
    :func:`_apply_placement`) pins each placed tier's decode to its own
    mesh slice.  Under this driver's per-tier barriers the slices run
    back-to-back — it is the *serialized* placement baseline the
    pipelined driver's overlap is measured against.
    """
    tiers = _apply_placement(tiers, placement)
    n = len(items)
    prompt_toks = [len(format_prompt(it)) for it in items]
    cost = [0.0] * n
    overhead = [0] * n        # decision latency accumulated on the way down
    out: List[Optional[MultiOutcome]] = [None] * n
    alive = list(range(n))
    tier_stats: List[Optional[SchedStats]] = []

    for t_i, tier in enumerate(tiers):
        key, sub = jax.random.split(key)
        if not alive:
            tier_stats.append(None)
            continue
        sub_items = [items[i] for i in alive]
        if stream_early_stop:
            results, st = sample_k_streamed(tier.slm, sub_items,
                                            tier.levels(), sub, tier.tau,
                                            seed_offset=t_i)
            decisions = [r.decision for r in results]
            tier_stats.append(st)
        else:
            votes = sample_k(tier.slm, sub_items, tier.levels(), sub,
                             seed_offset=t_i)
            decisions = [voting.decide_with_early_stop(vs, tier.tau)
                         for vs in votes]
            tier_stats.append(None)
        next_alive: List[int] = []
        for dec, qi in zip(decisions, alive):
            # tier cost: prompt once (KV cache shared across samples) +
            # the sampled tokens actually generated before the decision
            cost[qi] += (tier.in_price * prompt_toks[qi]
                         + tier.out_price * dec.used_tokens) / 1e6
            if dec.accepted:
                out[qi] = MultiOutcome(
                    accepted_tier=t_i,
                    correct=dec.answer == items[qi].answer,
                    cost=cost[qi],
                    agl=overhead[qi] + dec.decision_tokens,
                    arol=0)
            else:
                overhead[qi] += dec.decision_tokens
                next_alive.append(qi)
        alive = next_alive

    for qi in alive:
        lc, lt = terminal.llm.answer(items[qi])
        cost[qi] += (terminal.in_price * prompt_toks[qi]
                     + terminal.out_price * lt) / 1e6
        out[qi] = MultiOutcome(accepted_tier=len(tiers), correct=lc,
                               cost=cost[qi], agl=0, arol=overhead[qi])
    if return_stats:
        return out, tier_stats
    return out


# ----------------------------------------------------------------------
# Pipelined cascading: escalate mid-flight instead of per-tier barriers
# ----------------------------------------------------------------------

@dataclasses.dataclass
class PipelineStats:
    """What the pipelined host loop did, versus the barrier path.

    ``overlap_fraction`` is the share of host-loop iterations during
    which at least two tiers had decode compute in flight at once —
    exactly the overlap the barrier path forbids (its tiers run
    back-to-back, so its overlap is 0 by construction).  ``rounds`` and
    ``generated_tokens`` aggregate over every tier's serving loop;
    fused loops (tiers sharing one SLM, and therefore one lane pool)
    additionally pack escalated groups into lanes the moment earlier
    tiers free them, which shows up as strictly fewer total rounds than
    the barrier path's ramp/drain per tier.  ``ttd_s[qi]`` is
    question qi's time from tier-0 submission to its *final* routing
    decision (terminal-bound questions: the rejection that sent them
    there — the terminal call itself is outside the serving loop).
    """
    wall_s: float = 0.0
    host_iters: int = 0
    overlap_iters: int = 0
    overlap_fraction: float = 0.0
    rounds: int = 0
    generated_tokens: int = 0
    fused_loops: int = 0         # loops serving >1 tier (same-SLM fusion)
    n_loops: int = 0
    escalated: List[int] = dataclasses.field(default_factory=list)
    ttd_s: List[float] = dataclasses.field(default_factory=list)
    loop_stats: List[SchedStats] = dataclasses.field(default_factory=list)
    # speculative draft feeding (draft_rejected + spec_k tiers)
    spec_rounds: int = 0         # rounds that ran the verify path
    drafted_tokens: int = 0      # draft tokens fed to verify rounds
    accepted_draft_tokens: int = 0   # drafts committed by verification


def run_cascade_pipelined(tiers: Sequence[Tier], terminal: TerminalTier,
                          items: Sequence[TaskItem], key,
                          draft_rejected: bool = False,
                          placement: "Optional[Dict[int, list]]" = None
                          ) -> "tuple[List[MultiOutcome], PipelineStats]":
    """The cascade with *pipelined* tiers: each question's tier-(i+1)
    vote group is submitted the moment tier i's ``VoteEarlyStop``
    rejects it, so successive tiers' compute overlaps instead of
    running as sequential barriers (``run_cascade``).

    One :class:`~repro.serving.scheduler.ServingLoop` is opened per
    *distinct* tier SLM and all loops are interleaved in one host loop,
    split-phase: every active loop's decode round is dispatched before
    any is harvested, so one tier's host-side harvest/vote work overlaps
    the other tiers' device compute (JAX async dispatch).  Tiers that
    share an SLM object (the repo's multi-tier example reuses one SATER
    model with different tau/K policies) fuse onto a single loop and
    lane pool: an escalated group refills a lane the moment an earlier
    tier's completion frees it — one ramp and one drain for the whole
    cascade instead of one per tier.

    Decisions come from the same per-group ``VoteEarlyStop`` bound the
    barrier path uses (per-group tau, since one fused policy may serve
    several tiers), so with greedy decoding the accept/route decisions
    — and therefore accuracy and the tier histogram — match
    ``run_cascade(..., stream_early_stop=True)`` exactly; sampled
    decoding follows the scheduler's usual batch-composition contract.

    ``draft_rejected=True`` turns each rejection into a speedup for the
    tier it escalates to: the rejected group's representative
    completion (its lowest-uid surviving lane — a deterministic pick)
    is attached as a *draft* to every lane of the next tier's group,
    verified ``spec_k`` tokens per round instead of decoded one by one
    (``serving/batch.decode_round_spec``).  Tiers whose SLM has no
    ``spec_k`` simply ignore the drafts.  Verification commits exactly
    the tokens the next tier would have sampled anyway, so completions,
    decisions, accuracy, and the tier histogram are unchanged — only
    round counts and wall-clock drop, in proportion to inter-tier
    agreement on the escalated questions.

    ``placement`` (tier index -> device slice, see
    :func:`_apply_placement`) pins each placed tier to its own mesh
    slice.  Combined with this driver's split-phase host loop, tiers on
    disjoint slices decode *device*-concurrently — tier 0's next round
    and the escalation tier's verify round are genuinely in flight at
    once, not merely interleaved on one device — so wall-clock drops
    strictly below :func:`run_cascade` with the same placement.

    Returns ``(outcomes, PipelineStats)``.
    """
    tiers = _apply_placement(tiers, placement)
    n = len(items)
    kmax = max((t.k for t in tiers), default=1)
    prompt_toks = [len(format_prompt(it)) for it in items]
    cost = [0.0] * n
    overhead = [0] * n
    out: List[Optional[MultiOutcome]] = [None] * n
    t0 = time.time()
    stats = PipelineStats(ttd_s=[0.0] * n, escalated=[0] * len(tiers))

    # gid namespacing: tier t_i's group for question qi is t_i * n + qi,
    # its lanes' uids gid * kmax + j — unique within and across loops.
    def tier_group(t_i: int, qi: int) -> RequestGroup:
        tier = tiers[t_i]
        gid = t_i * n + qi
        return RequestGroup([
            Request(uid=gid * kmax + j,
                    prompt=format_prompt(items[qi], conf_level=lvl),
                    group=gid, meta={"level": lvl})
            for j, lvl in enumerate(tier.levels())])

    # one loop per distinct SLM; same-SLM tiers fuse onto one lane pool
    loops: List = []
    policies: List[VoteEarlyStop] = []
    loop_of: Dict[int, int] = {}     # tier index -> loop index
    if n and tiers:
        slm_loop: Dict[int, int] = {}
        for t_i, tier in enumerate(tiers):
            li = slm_loop.get(id(tier.slm))
            if li is None:
                li = len(loops)
                slm_loop[id(tier.slm)] = li
                key, sub = jax.random.split(key)
                policy = VoteEarlyStop(tier.tau, {})
                loops.append(make_scheduler(tier.slm, n * kmax).loop(
                    sub, stop_policy=policy))
                policies.append(policy)
            loop_of[t_i] = li
        stats.n_loops = len(loops)
        tiers_per_loop = [sum(1 for t in loop_of.values() if t == li)
                          for li in range(len(loops))]
        stats.fused_loops = sum(1 for c in tiers_per_loop if c > 1)

    def submit_tier(t_i: int, qi: int,
                    draft: Optional[List[int]] = None) -> None:
        gid = t_i * n + qi
        tier = tiers[t_i]
        policies[loop_of[t_i]].add_group(gid, tier.levels(), tau=tier.tau)
        group = tier_group(t_i, qi)
        drafts = None
        if draft and tier.slm.spec_k is not None:
            drafts = {m.uid: draft for m in group.requests}
        loops[loop_of[t_i]].submit([group], draft_tokens=drafts)

    for qi in range(n):
        if tiers:
            submit_tier(0, qi)

    # per-gid completion accounting (a group's decision is final only
    # when all K of its lanes have completed — kills included)
    gid_done: Dict[int, int] = {}
    gid_gen: Dict[int, int] = {}
    processed: set = set()
    # draft capture: the rejected group's representative completion,
    # fed to the next tier on escalation (lowest surviving uid — a
    # deterministic pick, so drafting never perturbs the trace)
    gid_draft: Dict[int, "tuple[int, List[int]]"] = {}

    def process_decisions(touched) -> None:
        """Settle every group decision that became processable this
        iteration.  A decision is created inside VoteEarlyStop.observe
        — i.e. while one of the group's completions is harvested — and
        becomes final only once all K completions (kills and drops
        included) have arrived, so only the gids touched by this
        iteration's completions need checking: O(new completions), not
        O(all decisions ever) per host iteration."""
        for gid in touched:
            t_i = gid // n
            dec = policies[loop_of[t_i]].decisions.get(gid)
            if dec is None or gid in processed or \
                    gid_done.get(gid, 0) < tiers[t_i].k:
                continue
            processed.add(gid)
            qi = gid % n
            tier = tiers[t_i]
            draft = gid_draft.pop(gid, (None, None))[1]
            dec = dataclasses.replace(dec, used_tokens=gid_gen[gid])
            cost[qi] += (tier.in_price * prompt_toks[qi]
                         + tier.out_price * dec.used_tokens) / 1e6
            if dec.accepted:
                out[qi] = MultiOutcome(
                    accepted_tier=t_i,
                    correct=dec.answer == items[qi].answer,
                    cost=cost[qi],
                    agl=overhead[qi] + dec.decision_tokens,
                    arol=0)
                stats.ttd_s[qi] = time.time() - t0
            else:
                overhead[qi] += dec.decision_tokens
                stats.escalated[t_i] += 1
                if t_i + 1 < len(tiers):
                    submit_tier(t_i + 1, qi,
                                draft=draft if draft_rejected else None)
                else:
                    stats.ttd_s[qi] = time.time() - t0

    while any(lp.has_work for lp in loops):
        # split-phase: launch every active loop's round before blocking
        # on any — one loop's harvest overlaps the others' device work
        dispatched = [lp for lp in loops if lp.has_work and lp.dispatch()]
        stats.host_iters += 1
        live_tiers = {gid // n for lp in dispatched
                      for gid in lp.live_groups()}
        if len(live_tiers) >= 2:
            stats.overlap_iters += 1
        touched: set = set()
        for lp in loops:
            for comp in (lp.harvest() if lp in dispatched
                         else lp.take_completed()):
                gid_done[comp.group] = gid_done.get(comp.group, 0) + 1
                gid_gen[comp.group] = (gid_gen.get(comp.group, 0)
                                       + int(comp.gen_len))
                touched.add(comp.group)
                if draft_rejected and not comp.cancelled and comp.gen_len:
                    best = gid_draft.get(comp.group)
                    if best is None or comp.uid < best[0]:
                        gid_draft[comp.group] = (comp.uid,
                                                 [int(t) for t in comp.tokens])
        process_decisions(touched)

    for lp in loops:
        stats.loop_stats.append(lp.close())
    stats.rounds = sum(s.rounds for s in stats.loop_stats)
    stats.generated_tokens = sum(s.generated_tokens
                                 for s in stats.loop_stats)
    stats.spec_rounds = sum(s.spec_rounds for s in stats.loop_stats)
    stats.drafted_tokens = sum(s.drafted_tokens for s in stats.loop_stats)
    stats.accepted_draft_tokens = sum(s.accepted_draft_tokens
                                      for s in stats.loop_stats)
    if stats.host_iters:
        stats.overlap_fraction = stats.overlap_iters / stats.host_iters

    for qi in range(n):
        if out[qi] is None:
            lc, lt = terminal.llm.answer(items[qi])
            cost[qi] += (terminal.in_price * prompt_toks[qi]
                         + terminal.out_price * lt) / 1e6
            out[qi] = MultiOutcome(accepted_tier=len(tiers), correct=lc,
                                   cost=cost[qi], agl=0, arol=overhead[qi])
            if not tiers:
                stats.ttd_s[qi] = time.time() - t0
    stats.wall_s = time.time() - t0
    return out, stats


def summarize(outcomes: Sequence[MultiOutcome], n_tiers: int) -> dict:
    accepted = [o for o in outcomes if o.accepted_tier < n_tiers]
    fell = [o for o in outcomes if o.accepted_tier == n_tiers]
    return {
        "accuracy": float(np.mean([o.correct for o in outcomes])),
        "cost": float(sum(o.cost for o in outcomes)),
        "tier_histogram": [
            sum(1 for o in outcomes if o.accepted_tier == t)
            for t in range(n_tiers + 1)],
        "AGL": float(np.mean([o.agl for o in accepted])) if accepted else 0.0,
        "AROL": float(np.mean([o.arol for o in fell])) if fell else 0.0,
    }
