"""Beyond-paper extension: multi-tier cascading.

The paper's Limitation §1 names multi-model collaborative routing as
future work.  This module generalizes SATER's two-model cascade to an
ordered chain of tiers

    tier_0 (cheapest SLM) -> tier_1 -> ... -> tier_{T-1} (terminal LLM)

where every non-terminal tier is a SATER-trained model queried with its
own (tau, mode, K) policy; a query falls through to the next tier when
the confidence-weighted vote stays below that tier's threshold.  The
terminal tier always answers.

Semantics kept from the paper's single-hop cascade:
  * per-tier K parallel samples + RCV/FCV weighted voting with early
    stopping (voting.decide_with_early_stop),
  * latency is token-count-based: AGL accumulates the *decision* latency
    of every tier that ran plus the accepted tier's generation; AROL is
    the overhead versus calling the terminal tier directly,
  * cost is token-level per tier with per-tier prices.

The two-tier special case reproduces routing.cascade_outcomes.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core import voting
from repro.core.confidence import fcv_schedule, rcv_schedule
from repro.core.routing import SLM, sample_k
from repro.data.pipeline import format_prompt
from repro.data.tasks import TaskItem


@dataclasses.dataclass
class Tier:
    """A non-terminal cascade tier: a SATER model + its query policy."""
    slm: SLM
    tau: float = 0.6
    mode: str = "FCV"            # RCV | FCV
    k: int = 10
    out_price: float = 0.08      # $ / 1M output tokens
    in_price: float = 0.02

    def levels(self) -> List[Optional[float]]:
        return rcv_schedule(self.k) if self.mode == "RCV" \
            else fcv_schedule(self.k)


@dataclasses.dataclass
class TerminalTier:
    """The always-answers tier (API LLM or oracle)."""
    llm: object                  # OracleLLM / ModelLLM
    out_price: float = 1.10
    in_price: float = 0.275


@dataclasses.dataclass
class MultiOutcome:
    accepted_tier: int           # index in the chain (T-1 = terminal)
    correct: bool
    cost: float                  # absolute $ for this question
    agl: int                     # generation latency if non-terminal won
    arol: int                    # overhead latency if terminal answered


def run_cascade(tiers: Sequence[Tier], terminal: TerminalTier,
                items: Sequence[TaskItem], key) -> List[MultiOutcome]:
    """Drive every question through the tier chain (batched per tier)."""
    n = len(items)
    votes_per_tier = []
    for t_i, tier in enumerate(tiers):
        key, sub = jax.random.split(key)
        votes_per_tier.append(
            sample_k(tier.slm, items, tier.levels(), sub, seed_offset=t_i))

    out: List[MultiOutcome] = []
    for qi, item in enumerate(items):
        prompt_toks = len(format_prompt(item))
        cost = 0.0
        overhead = 0          # decision latency accumulated on the way down
        decided: Optional[MultiOutcome] = None
        for t_i, tier in enumerate(tiers):
            dec = voting.decide_with_early_stop(votes_per_tier[t_i][qi],
                                                tier.tau)
            # tier cost: prompt once (KV cache shared across samples) +
            # the sampled tokens actually generated before the decision
            cost += (tier.in_price * prompt_toks
                     + tier.out_price * dec.used_tokens) / 1e6
            if dec.accepted:
                decided = MultiOutcome(
                    accepted_tier=t_i,
                    correct=dec.answer == item.answer,
                    cost=cost,
                    agl=overhead + dec.decision_tokens,
                    arol=0)
                break
            overhead += dec.decision_tokens
        if decided is None:
            lc, lt = terminal.llm.answer(item)
            cost += (terminal.in_price * prompt_toks
                     + terminal.out_price * lt) / 1e6
            decided = MultiOutcome(
                accepted_tier=len(tiers), correct=lc, cost=cost,
                agl=0, arol=overhead)
        out.append(decided)
    return out


def summarize(outcomes: Sequence[MultiOutcome], n_tiers: int) -> dict:
    accepted = [o for o in outcomes if o.accepted_tier < n_tiers]
    fell = [o for o in outcomes if o.accepted_tier == n_tiers]
    return {
        "accuracy": float(np.mean([o.correct for o in outcomes])),
        "cost": float(sum(o.cost for o in outcomes)),
        "tier_histogram": [
            sum(1 for o in outcomes if o.accepted_tier == t)
            for t in range(n_tiers + 1)],
        "AGL": float(np.mean([o.agl for o in accepted])) if accepted else 0.0,
        "AROL": float(np.mean([o.arol for o in fell])) if fell else 0.0,
    }
