"""Beyond-paper extension: multi-tier cascading.

The paper's Limitation §1 names multi-model collaborative routing as
future work.  This module generalizes SATER's two-model cascade to an
ordered chain of tiers

    tier_0 (cheapest SLM) -> tier_1 -> ... -> tier_{T-1} (terminal LLM)

where every non-terminal tier is a SATER-trained model queried with its
own (tau, mode, K) policy; a query falls through to the next tier when
the confidence-weighted vote stays below that tier's threshold.  The
terminal tier always answers.

Questions are streamed: each tier batches only its surviving questions
through the serving scheduler, and with ``stream_early_stop`` a tier's
vote lanes are killed in compute as soon as its tau is decided.  Each
question's K vote lanes travel as one RequestGroup, so a tier whose
``slm.share_prefix`` is set (paged serving) prefills every surviving
question once and shares its prompt KV blocks across the K lanes — the
"prompt once" cost model below is then real serving behaviour, not an
accounting convention.

Semantics kept from the paper's single-hop cascade:
  * per-tier K parallel samples + RCV/FCV weighted voting with early
    stopping (voting.decide_with_early_stop),
  * latency is token-count-based: AGL accumulates the *decision* latency
    of every tier that ran plus the accepted tier's generation; AROL is
    the overhead versus calling the terminal tier directly,
  * cost is token-level per tier with per-tier prices.

The two-tier special case reproduces routing.cascade_outcomes.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core import voting
from repro.core.confidence import fcv_schedule, rcv_schedule
from repro.core.routing import SLM, sample_k, sample_k_streamed
from repro.data.pipeline import format_prompt
from repro.data.tasks import TaskItem


@dataclasses.dataclass
class Tier:
    """A non-terminal cascade tier: a SATER model + its query policy."""
    slm: SLM
    tau: float = 0.6
    mode: str = "FCV"            # RCV | FCV
    k: int = 10
    out_price: float = 0.08      # $ / 1M output tokens
    in_price: float = 0.02

    def levels(self) -> List[Optional[float]]:
        return rcv_schedule(self.k) if self.mode == "RCV" \
            else fcv_schedule(self.k)


@dataclasses.dataclass
class TerminalTier:
    """The always-answers tier (API LLM or oracle)."""
    llm: object                  # OracleLLM / ModelLLM
    out_price: float = 1.10
    in_price: float = 0.275


@dataclasses.dataclass
class MultiOutcome:
    accepted_tier: int           # index in the chain (T-1 = terminal)
    correct: bool
    cost: float                  # absolute $ for this question
    agl: int                     # generation latency if non-terminal won
    arol: int                    # overhead latency if terminal answered


def run_cascade(tiers: Sequence[Tier], terminal: TerminalTier,
                items: Sequence[TaskItem], key,
                stream_early_stop: bool = False) -> List[MultiOutcome]:
    """Drive every question through the tier chain.

    Each tier streams only the questions that fell through every tier
    above it through the scheduler (continuous batching over the
    surviving K-lane vote groups), so deeper tiers never generate for
    already-answered questions.  With stream_early_stop=True, a tier's
    vote groups are additionally killed mid-flight by the VoteEarlyStop
    policy the moment that tier's tau is decided (true compute early
    stop); otherwise lanes run to completion and early stopping is the
    paper's token-accounting simulation (voting.decide_with_early_stop).
    """
    n = len(items)
    prompt_toks = [len(format_prompt(it)) for it in items]
    cost = [0.0] * n
    overhead = [0] * n        # decision latency accumulated on the way down
    out: List[Optional[MultiOutcome]] = [None] * n
    alive = list(range(n))

    for t_i, tier in enumerate(tiers):
        key, sub = jax.random.split(key)
        if not alive:
            continue
        sub_items = [items[i] for i in alive]
        if stream_early_stop:
            results, _ = sample_k_streamed(tier.slm, sub_items, tier.levels(),
                                           sub, tier.tau, seed_offset=t_i)
            decisions = [r.decision for r in results]
        else:
            votes = sample_k(tier.slm, sub_items, tier.levels(), sub,
                             seed_offset=t_i)
            decisions = [voting.decide_with_early_stop(vs, tier.tau)
                         for vs in votes]
        next_alive: List[int] = []
        for dec, qi in zip(decisions, alive):
            # tier cost: prompt once (KV cache shared across samples) +
            # the sampled tokens actually generated before the decision
            cost[qi] += (tier.in_price * prompt_toks[qi]
                         + tier.out_price * dec.used_tokens) / 1e6
            if dec.accepted:
                out[qi] = MultiOutcome(
                    accepted_tier=t_i,
                    correct=dec.answer == items[qi].answer,
                    cost=cost[qi],
                    agl=overhead[qi] + dec.decision_tokens,
                    arol=0)
            else:
                overhead[qi] += dec.decision_tokens
                next_alive.append(qi)
        alive = next_alive

    for qi in alive:
        lc, lt = terminal.llm.answer(items[qi])
        cost[qi] += (terminal.in_price * prompt_toks[qi]
                     + terminal.out_price * lt) / 1e6
        out[qi] = MultiOutcome(accepted_tier=len(tiers), correct=lc,
                               cost=cost[qi], agl=0, arol=overhead[qi])
    return out


def summarize(outcomes: Sequence[MultiOutcome], n_tiers: int) -> dict:
    accepted = [o for o in outcomes if o.accepted_tier < n_tiers]
    fell = [o for o in outcomes if o.accepted_tier == n_tiers]
    return {
        "accuracy": float(np.mean([o.correct for o in outcomes])),
        "cost": float(sum(o.cost for o in outcomes)),
        "tier_histogram": [
            sum(1 for o in outcomes if o.accepted_tier == t)
            for t in range(n_tiers + 1)],
        "AGL": float(np.mean([o.agl for o in accepted])) if accepted else 0.0,
        "AROL": float(np.mean([o.arol for o in fell])) if fell else 0.0,
    }
