"""Confidence-weighted majority voting + early stopping (paper §3, Eq. 6).

Weight: w_k = 0.55 + alpha * (p_k - 0.55), alpha = 0.5 (0.55 = the
average confidence).  Score of candidate A_m:
    delta(A_m) = sum_k w_k * 1[a_k == A_m] / sum_k w_k
over ALL K votes — rejected votes contribute weight to the denominator
but to no candidate, so heavy rejection drives every delta below tau and
the query routes to the LLM.

Early stopping (parallel sampling semantics, paper §2.2 "Latency"):
samples complete in gen-length order; after each completion we check
whether the final decision is already determined no matter how the
still-running samples vote — if the best candidate's guaranteed lower
bound >= tau we accept now, if even the optimistic upper bound of every
candidate (incl. unseen ones) < tau we route now.  Otherwise we wait;
the fallback decision time is the longest sample.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import List, Optional, Sequence, Tuple

from repro.core.confidence import Vote

ALPHA = 0.5
MEAN_CONF = 0.55


def weight(p: float, alpha: float = ALPHA) -> float:
    return MEAN_CONF + alpha * (p - MEAN_CONF)


def vote_scores(votes: Sequence[Vote], alpha: float = ALPHA):
    """delta(A_m) over all candidates.  Returns (scores dict, total_w)."""
    total_w = sum(weight(v.confidence, alpha) for v in votes)
    scores = defaultdict(float)
    for v in votes:
        if not v.rejected and v.answer is not None:
            scores[v.answer] += weight(v.confidence, alpha)
    if total_w <= 0:
        return {}, 0.0
    return {a: w / total_w for a, w in scores.items()}, total_w


def best_answer(votes: Sequence[Vote], alpha: float = ALPHA
                ) -> Tuple[Optional[str], float]:
    scores, _ = vote_scores(votes, alpha)
    if not scores:
        return None, 0.0
    a = max(scores, key=scores.get)
    return a, scores[a]


@dataclasses.dataclass
class CascadeDecision:
    answer: Optional[str]        # None => route to LLM
    score: float
    accepted: bool
    decision_tokens: int         # latency proxy at decision time
    used_tokens: int             # cost proxy: sum of per-lane tokens until stop
    n_votes_seen: int


def decide_with_early_stop(votes: List[Vote], tau: float,
                           alpha: float = ALPHA) -> CascadeDecision:
    """Simulate parallel sampling with early stopping.

    All K lanes generate concurrently; lane k finishes at time
    votes[k].gen_tokens.  We process completions in time order and stop
    as soon as the accept/route decision is forced.
    """
    if tau <= 0:
        # tau=0 = SLM-only endpoint: never route, take the full vote
        return decide_no_early_stop(votes, tau, alpha)
    k = len(votes)
    order = sorted(range(k), key=lambda i: votes[i].gen_tokens)
    all_w = [weight(v.confidence, alpha) for v in votes]
    total_w = sum(all_w)

    seen_w = defaultdict(float)   # candidate -> accumulated weight
    decision_t = votes[order[-1]].gen_tokens if k else 0
    n_seen = k
    accepted = False
    answer, score = None, 0.0

    pending_w = total_w
    for rank, i in enumerate(order):
        v = votes[i]
        pending_w -= all_w[i]
        if not v.rejected and v.answer is not None:
            seen_w[v.answer] += all_w[i]
        best_seen = max(seen_w.values()) if seen_w else 0.0
        # lower bound: leader gets nothing more; upper bound: any candidate
        # (even unseen) could still absorb all pending weight
        lo = best_seen / total_w
        hi = (best_seen + pending_w) / total_w if seen_w else pending_w / total_w
        if seen_w and lo >= tau:
            accepted = True
            answer = max(seen_w, key=seen_w.get)
            score = lo
            decision_t = v.gen_tokens
            n_seen = rank + 1
            break
        if hi < tau:
            accepted = False
            answer = None
            score = hi
            decision_t = v.gen_tokens
            n_seen = rank + 1
            break
    else:
        # all samples finished: final decision from complete scores
        scores, _ = vote_scores(votes, alpha)
        if scores:
            a = max(scores, key=scores.get)
            if scores[a] >= tau:
                accepted, answer, score = True, a, scores[a]
            else:
                accepted, answer, score = False, None, scores[a]
        elif tau <= 0:
            accepted = True          # tau=0: never route (SLM-only endpoint)
        decision_t = votes[order[-1]].gen_tokens if k else 0
        n_seen = k

    # cost: every lane ran until min(its completion, decision time)
    used = sum(min(v.gen_tokens, decision_t) for v in votes)
    return CascadeDecision(answer, score, accepted, decision_t, used, n_seen)


def decide_no_early_stop(votes: List[Vote], tau: float,
                         alpha: float = ALPHA) -> CascadeDecision:
    """Vanilla SC-style decision: wait for all samples (baseline)."""
    scores, _ = vote_scores(votes, alpha)
    t_max = max((v.gen_tokens for v in votes), default=0)
    used = sum(v.gen_tokens for v in votes)
    if scores:
        a = max(scores, key=scores.get)
        if scores[a] >= tau:
            return CascadeDecision(a, scores[a], True, t_max, used, len(votes))
        return CascadeDecision(None, scores[a], False, t_max, used, len(votes))
    # no parseable answer at all: tau=0 still keeps the query on the SLM
    return CascadeDecision(None, 0.0, tau <= 0, t_max, used, len(votes))
