"""Evaluation framework (paper §2): cost-performance curves, ToA / ToGA /
ToA-100 / ToGR, and the latency metrics AGL / AROL.

Conventions (faithful to the paper):
  * costs are normalized so LLM-only == 1  (sum_i C_i^l in the denominator),
  * M_l's per-question output tokens are replaced by the dataset-level
    average (avoids the curve shifting right on long LLM outputs),
  * in cascade mode the prompt is prefilled once regardless of K samples,
  * "-100" variants assume M_l answers everything correctly,
  * ToA is trapezoid area of the curve over the [C_s, C_l] x [P_s, ...]
    box, normalized so random routing = 0.5; ToGA = ToA - 0.5;
    ToGR = ToGA-100(router) / ToGA-100(golden router).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.cost import CostModel


@dataclasses.dataclass
class QuestionRecord:
    """Per-question evaluation record (one benchmark)."""
    slm_correct: bool            # SLM's final (voted or single) answer correct
    llm_correct: bool
    slm_in_tokens: int
    slm_out_tokens: int          # SLM output tokens if answered by SLM
    llm_out_tokens: int          # LLM output tokens (actual)
    score: float                 # router confidence s_i (higher => keep on SLM)
    # cascade-only extras
    cascade_out_tokens: Optional[int] = None   # sum over lanes until stop
    decision_tokens: Optional[int] = None      # AGL/AROL latency proxy
    accepted: Optional[bool] = None            # cascade accepted (not routed)


THRESHOLDS = [round(0.1 * i, 1) for i in range(11)]


def _llm_avg_out(records: Sequence[QuestionRecord]) -> float:
    return float(np.mean([r.llm_out_tokens for r in records]))


def curve_points(records: Sequence[QuestionRecord], cm: CostModel,
                 cascade: bool = False, assume_llm_perfect: bool = False,
                 thresholds: Sequence[float] = THRESHOLDS):
    """Cost-performance points (cost normalized to LLM-only = 1).

    Pre-generation: route iff score < tau  (tau=0 => all SLM).
    Cascade: SLM always generates (cascade_out_tokens); route adds LLM cost.
    """
    llm_avg = _llm_avg_out(records)
    denom = sum(cm.llm_cost(r.slm_in_tokens, llm_avg) for r in records)
    pts = []
    for tau in thresholds:
        cost = 0.0
        perf = 0.0
        for r in records:
            routed = r.score < tau
            p_llm = 1.0 if assume_llm_perfect else float(r.llm_correct)
            if cascade:
                # prompt prefilled once (KV cache), K lanes' output tokens
                cost += cm.slm_cost(r.slm_in_tokens, r.cascade_out_tokens)
                if routed:
                    cost += cm.llm_cost(r.slm_in_tokens, llm_avg)
                    perf += p_llm
                else:
                    perf += float(r.slm_correct)
            else:
                if routed:
                    cost += cm.llm_cost(r.slm_in_tokens, llm_avg)
                    perf += p_llm
                else:
                    cost += cm.slm_cost(r.slm_in_tokens, r.slm_out_tokens)
                    perf += float(r.slm_correct)
        pts.append((cost / denom, perf / len(records)))
    return pts


def toa(points, c_s: float, p_s: float, c_l: float = 1.0) -> float:
    """Normalized trade-off area over the [c_s, c_l] x [p_s, ..] box.

    Curve points are (cost, perf); reference lines Cost=c_l and Perf=p_s.
    Random routing (straight segment) yields 0.5 by construction.
    """
    pts = sorted(set(points))
    # clip to the box and integrate (perf - p_s) d cost
    xs = [p[0] for p in pts]
    ys = [p[1] for p in pts]
    area = 0.0
    for i in range(len(pts) - 1):
        x0, x1 = xs[i], xs[i + 1]
        y0, y1 = ys[i], ys[i + 1]
        if x1 <= c_s or x0 >= c_l or x1 <= x0:
            continue
        # clip segment to [c_s, c_l]
        if x0 < c_s:
            y0 = y0 + (y1 - y0) * (c_s - x0) / (x1 - x0)
            x0 = c_s
        if x1 > c_l:
            y1 = y0 + (y1 - y0) * (c_l - x0) / (x1 - x0)
            x1 = c_l
        area += 0.5 * (max(y0 - p_s, 0.0) + max(y1 - p_s, 0.0)) * (x1 - x0)
    p_l = ys[-1] if ys else p_s
    box = (c_l - c_s) * (p_l - p_s)
    if box <= 1e-12:
        return 0.5
    return float(area / box)


def _endpoints(records, cm: CostModel, assume_llm_perfect: bool):
    llm_avg = _llm_avg_out(records)
    denom = sum(cm.llm_cost(r.slm_in_tokens, llm_avg) for r in records)
    c_s = sum(cm.slm_cost(r.slm_in_tokens, r.slm_out_tokens) for r in records) / denom
    p_s = float(np.mean([r.slm_correct for r in records]))
    p_l = 1.0 if assume_llm_perfect else float(np.mean([r.llm_correct for r in records]))
    return c_s, p_s, 1.0, p_l


def toa_summary(records: Sequence[QuestionRecord], cm: CostModel,
                cascade: bool = False) -> dict:
    """ToA, ToGA, ToA-100, ToGA-100, ToGR for one benchmark."""
    out = {}
    for perfect in (False, True):
        pts = curve_points(records, cm, cascade=cascade,
                           assume_llm_perfect=perfect)
        c_s, p_s, c_l, p_l = _endpoints(records, cm, perfect)
        pts = [(c_s, p_s)] + pts + [(c_l, p_l)]
        a = toa(pts, c_s, p_s, c_l)
        key = "toa_100" if perfect else "toa"
        out[key] = a
        out["toga_100" if perfect else "toga"] = a - 0.5

    # golden router: score = 1 if SLM correct else 0 (assume_llm_perfect)
    golden = [dataclasses.replace(r, score=1.0 if r.slm_correct else 0.0)
              for r in records]
    gpts = curve_points(golden, cm, cascade=cascade, assume_llm_perfect=True,
                        thresholds=[0.0, 0.5, 1.0])
    c_s, p_s, c_l, p_l = _endpoints(golden, cm, True)
    gpts = [(c_s, p_s)] + gpts + [(c_l, p_l)]
    golden_toga = toa(gpts, c_s, p_s, c_l) - 0.5
    out["toga_100_golden"] = golden_toga
    out["togr"] = out["toga_100"] / golden_toga if abs(golden_toga) > 1e-9 else 0.0
    return out


# ----------------------------------------------------------------------
# Latency metrics (cascade)
# ----------------------------------------------------------------------

def latency_summary(records: Sequence[QuestionRecord]) -> dict:
    """AGL: mean decision tokens over questions answered by the SLM.
    AROL: mean decision tokens over questions that fell back to the LLM
    (the extra wait vs. calling the LLM directly)."""
    agl = [r.decision_tokens for r in records if r.accepted]
    arol = [r.decision_tokens for r in records if not r.accepted]
    return {
        "AGL": float(np.mean(agl)) if agl else 0.0,
        "AROL": float(np.mean(arol)) if arol else 0.0,
        "frac_accepted": len(agl) / max(len(records), 1),
    }


def accuracy_cost(records: Sequence[QuestionRecord], cm: CostModel,
                  tau: float, cascade: bool = False,
                  assume_llm_perfect: bool = False) -> dict:
    pts = curve_points(records, cm, cascade=cascade,
                       assume_llm_perfect=assume_llm_perfect,
                       thresholds=[tau])
    return {"cost": pts[0][0], "accuracy": pts[0][1]}


# ----------------------------------------------------------------------
# Outcome-based API (SATER pre-gen & cascade, where behaviour depends on
# the prompted threshold itself rather than a fixed scalar score)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class RouteOutcome:
    """What happened for one question at one threshold."""
    routed: bool
    slm_correct: bool            # correctness of the SLM answer (if kept)
    slm_engaged: bool            # SLM saw the prompt (always true in cascade)
    slm_in_tokens: int
    slm_out_tokens: int          # SLM output tokens spent at this threshold
    llm_correct: bool
    llm_out_tokens: int
    decision_tokens: int = 0     # cascade latency proxy


def points_from_outcomes(outcomes_by_tau, cm: CostModel,
                         assume_llm_perfect: bool = False):
    """outcomes_by_tau: {tau: [RouteOutcome,...]} -> sorted curve points."""
    any_rows = next(iter(outcomes_by_tau.values()))
    llm_avg = float(np.mean([o.llm_out_tokens for o in any_rows]))
    denom = sum(cm.llm_cost(o.slm_in_tokens, llm_avg) for o in any_rows)
    pts = []
    for tau in sorted(outcomes_by_tau):
        cost, perf = 0.0, 0.0
        rows = outcomes_by_tau[tau]
        for o in rows:
            if o.slm_engaged:
                cost += cm.slm_cost(o.slm_in_tokens, o.slm_out_tokens)
            if o.routed:
                cost += cm.llm_cost(o.slm_in_tokens, llm_avg)
                perf += 1.0 if assume_llm_perfect else float(o.llm_correct)
            else:
                perf += float(o.slm_correct)
        pts.append((cost / denom, perf / len(rows)))
    return pts


def golden_toga_100(slm_correct: Sequence[bool], slm_in: Sequence[int],
                    slm_out: Sequence[int], cm: CostModel,
                    llm_out: Sequence[int]) -> float:
    """ToGA-100 of the perfect router (routes exactly the SLM-wrong set)."""
    recs = [QuestionRecord(sc, True, i, o, lo, 1.0 if sc else 0.0)
            for sc, i, o, lo in zip(slm_correct, slm_in, slm_out, llm_out)]
    pts = curve_points(recs, cm, assume_llm_perfect=True,
                       thresholds=[0.0, 0.5, 1.0])
    c_s, p_s, c_l, p_l = _endpoints(recs, cm, True)
    pts = [(c_s, p_s)] + pts + [(c_l, p_l)]
    return toa(pts, c_s, p_s, c_l) - 0.5


def outcome_toa_summary(outcomes_by_tau, cm: CostModel,
                        endpoint_slm: tuple, golden: float) -> dict:
    """ToA metrics from threshold-dependent outcomes.

    endpoint_slm: (C_s, P_s) of single-sample SLM-only inference.
    golden: golden ToGA-100 for this benchmark (method-independent).
    """
    out = {}
    c_s, p_s = endpoint_slm
    for perfect in (False, True):
        pts = points_from_outcomes(outcomes_by_tau, cm, assume_llm_perfect=perfect)
        any_rows = next(iter(outcomes_by_tau.values()))
        p_l = 1.0 if perfect else float(np.mean([o.llm_correct for o in any_rows]))
        pts = [(c_s, p_s)] + pts + [(1.0, p_l)]
        a = toa(pts, c_s, p_s, 1.0)
        out["toa_100" if perfect else "toa"] = a
        out["toga_100" if perfect else "toga"] = a - 0.5
    out["toga_100_golden"] = golden
    out["togr"] = out["toga_100"] / golden if abs(golden) > 1e-9 else 0.0
    return out


def outcome_latency(outcomes: Sequence[RouteOutcome]) -> dict:
    agl = [o.decision_tokens for o in outcomes if not o.routed]
    arol = [o.decision_tokens for o in outcomes if o.routed]
    return {
        "AGL": float(np.mean(agl)) if agl else 0.0,
        "AROL": float(np.mean(arol)) if arol else 0.0,
        "frac_accepted": len(agl) / max(len(outcomes), 1),
    }
