"""Experiment pipeline: trains the tiny CPU-scale SLMs through the full
SATER recipe and caches every artifact, so examples/ and benchmarks/
share one set of models.

Stages (mirrors the paper; DESIGN.md §1):
  base    : SFT on mostly-verbose responses over the 4 in-domain
            benchmarks (the "Instruct model" stand-in)
  stage1  : sample K/question -> shortest-correct vs longest-incorrect
            preference pairs -> DPO(beta=1) + 0.2*SFT   ["TE" model]
  stage2  : resample with stage1 -> empirical accuracies -> confidence-
            conditioned refusal SFT                      [SATER model]

Artifacts are .npz checkpoints under --artifacts (default
benchmarks/artifacts), keyed by the experiment scale tag.
"""

from __future__ import annotations

import dataclasses
import os
import random
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import routing as routing_lib
from repro.core.dpo import DPOConfig, make_full_dpo_step
from repro.core.preferences import build_preference_pairs
from repro.core.refusal import build_refusal_dataset
from repro.data import tasks as tasks_lib
from repro.data.pipeline import format_prompt, preference_batches, sft_batches
from repro.data.tokenizer import default_tokenizer
from repro.models import model as model_lib
from repro.serving.engine import GenConfig
from repro.training import checkpoint
from repro.training.optimizer import adamw, cosine_warmup_schedule
from repro.training.trainer import make_sft_step, train_loop


@dataclasses.dataclass
class ExperimentScale:
    tag: str = "small"
    d_model: int = 160
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    # data / training sizes
    n_train_per_benchmark: int = 3000
    n_stage_questions: int = 240      # questions sampled for stages I/II
    n_eval: int = 60                  # eval questions per benchmark
    sft_epochs: int = 3
    dpo_epochs: int = 2
    refusal_epochs: int = 3
    batch_size: int = 16
    max_len: int = 192
    stage2_max_len: int = 224    # conf-prompt + sampled answer fits
    k_samples: int = 8
    max_new_tokens: int = 80
    lane_budget: int = 80
    seed: int = 0


TINY = ExperimentScale(tag="tiny", d_model=128, n_layers=4, d_ff=384,
                       n_train_per_benchmark=2000, n_stage_questions=320,
                       n_eval=40, sft_epochs=4, dpo_epochs=6,
                       refusal_epochs=2, k_samples=10, max_new_tokens=72,
                       max_len=160, stage2_max_len=208)
SMALL = ExperimentScale()
# a larger local model usable as M_l (ModelLLM)
LLM_SCALE = ExperimentScale(tag="llm", d_model=256, n_layers=6, n_heads=8,
                            d_ff=768, sft_epochs=4)


def model_config(x: ExperimentScale) -> ModelConfig:
    tok = default_tokenizer()
    return ModelConfig(
        name=f"slm-{x.tag}", arch_type="dense", n_layers=x.n_layers,
        d_model=x.d_model, n_heads=x.n_heads, n_kv_heads=x.n_heads,
        head_dim=x.d_model // x.n_heads, d_ff=x.d_ff,
        vocab_size=tok.vocab_size, remat=False,
        source="SATER CPU-scale reproduction model")


def make_slm(params, x: ExperimentScale, temperature: float = 0.7) -> routing_lib.SLM:
    return routing_lib.SLM(
        params, model_config(x), default_tokenizer(),
        GenConfig(max_new_tokens=x.max_new_tokens, temperature=temperature,
                  top_p=1.0),
        max_prompt_len=x.max_len, lane_budget=x.lane_budget)


# ----------------------------------------------------------------------
# Data
# ----------------------------------------------------------------------

def base_sft_pairs(x: ExperimentScale) -> List[Tuple[str, str]]:
    """Mostly-verbose SFT data (the paper's base models are verbose)."""
    rng = random.Random(x.seed + 17)
    items = tasks_lib.make_training_mix(x.n_train_per_benchmark, seed=x.seed)
    pairs = []
    for it in items:
        if rng.random() < 0.8:
            resp = it.verbose
        else:
            resp = it.response(rng.randint(0, len(it.steps)))
        pairs.append((format_prompt(it), resp))
    return pairs


def stage_questions(x: ExperimentScale) -> List[tasks_lib.TaskItem]:
    per = max(10, x.n_stage_questions // len(tasks_lib.IN_DOMAIN))
    items = []
    for b in tasks_lib.IN_DOMAIN:
        items.extend(tasks_lib.make_benchmark(b, per, seed=x.seed + 101))
    return items


def eval_items(x: ExperimentScale, benchmark: str) -> List[tasks_lib.TaskItem]:
    return tasks_lib.make_benchmark(benchmark, x.n_eval, seed=x.seed + 7777)


# ----------------------------------------------------------------------
# Training stages
# ----------------------------------------------------------------------

def train_base(x: ExperimentScale, log=print):
    cfg = model_config(x)
    tok = default_tokenizer()
    pairs = base_sft_pairs(x)
    steps_per_epoch = len(pairs) // x.batch_size
    total = steps_per_epoch * x.sft_epochs
    opt = adamw(cosine_warmup_schedule(3e-3, total), weight_decay=0.01)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(x.seed))
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.int32(0)}
    step = make_sft_step(cfg, opt)
    batches = ({k: jnp.asarray(v) for k, v in b.items()}
               for b in sft_batches(pairs, tok, x.batch_size, x.max_len,
                                    seed=x.seed, epochs=x.sft_epochs))

    def ckpt(state, i):
        checkpoint.save(f"benchmarks/artifacts/{x.tag}_base_step{i}",
                        state["params"])

    state, hist = train_loop(step, state, batches, log_every=50, log_fn=log,
                             checkpoint_every=250, checkpoint_fn=ckpt)
    return state["params"], hist


def run_stage1(x: ExperimentScale, base_params, log=print):
    """Long-to-short DPO.  Returns (params, sampled_questions, pairs)."""
    cfg = model_config(x)
    tok = default_tokenizer()
    slm = make_slm(base_params, x)
    items = stage_questions(x)
    log(f"[stage1] sampling {len(items)} questions x {x.k_samples}")
    samples = routing_lib.collect_samples(slm, items, x.k_samples,
                                          jax.random.PRNGKey(x.seed + 1))
    prefs = build_preference_pairs(samples)
    log(f"[stage1] {len(prefs)} preference pairs "
        f"(mean acc {np.mean([s.accuracy for s in samples]):.2f})")
    if not prefs:
        log("[stage1] WARNING: no pairs; returning base params")
        return base_params, samples, prefs
    steps_per_epoch = max(1, len(prefs) // x.batch_size)
    total = steps_per_epoch * x.dpo_epochs
    opt = adamw(cosine_warmup_schedule(1e-4, total), weight_decay=0.01)
    step = make_full_dpo_step(cfg, opt, DPOConfig())
    state = {"params": base_params, "ref_params": base_params,
             "opt_state": opt.init(base_params), "step": jnp.int32(0)}
    batches = ({k: jnp.asarray(v) for k, v in b.items()}
               for b in preference_batches(prefs, tok, min(x.batch_size, 8),
                                           x.max_len, seed=x.seed,
                                           epochs=x.dpo_epochs))
    state, hist = train_loop(step, state, batches, log_every=20, log_fn=log)
    return state["params"], samples, prefs


def run_stage2(x: ExperimentScale, stage1_params, log=print):
    """Confidence-aware refusal SFT.  Returns params."""
    cfg = model_config(x)
    tok = default_tokenizer()
    slm = make_slm(stage1_params, x)
    items = stage_questions(x)
    log(f"[stage2] resampling {len(items)} questions x {x.k_samples}")
    samples = routing_lib.collect_samples(slm, items, x.k_samples,
                                          jax.random.PRNGKey(x.seed + 2))
    data = build_refusal_dataset(samples, seed=x.seed)
    log(f"[stage2] {len(data)} refusal-SFT examples")
    steps_per_epoch = max(1, len(data) // x.batch_size)
    total = steps_per_epoch * x.refusal_epochs
    opt = adamw(cosine_warmup_schedule(1e-3, total), weight_decay=0.01)
    step = make_sft_step(cfg, opt)
    state = {"params": stage1_params, "opt_state": opt.init(stage1_params),
             "step": jnp.int32(0)}
    batches = ({k: jnp.asarray(v) for k, v in b.items()}
               for b in sft_batches(data, tok, x.batch_size, x.stage2_max_len,
                                    seed=x.seed + 3, epochs=x.refusal_epochs))
    state, hist = train_loop(step, state, batches, log_every=50, log_fn=log)
    return state["params"]


# ----------------------------------------------------------------------
# Cached pipeline
# ----------------------------------------------------------------------

def artifact_path(artifacts: str, x: ExperimentScale, name: str) -> str:
    return os.path.join(artifacts, f"{x.tag}_{name}")


def get_models(x: ExperimentScale, artifacts: str = "benchmarks/artifacts",
               log=print):
    """Returns {"base","stage1","stage2"} params, training+caching as needed."""
    os.makedirs(artifacts, exist_ok=True)
    out = {}
    p_base = artifact_path(artifacts, x, "base")
    if os.path.exists(p_base + ".npz"):
        out["base"] = checkpoint.restore(p_base)
        log(f"[cache] base <- {p_base}")
    else:
        t0 = time.time()
        out["base"], _ = train_base(x, log=log)
        checkpoint.save(p_base, out["base"])
        log(f"[train] base in {time.time()-t0:.0f}s")

    p_s1 = artifact_path(artifacts, x, "stage1")
    if os.path.exists(p_s1 + ".npz"):
        out["stage1"] = checkpoint.restore(p_s1)
        log(f"[cache] stage1 <- {p_s1}")
    else:
        t0 = time.time()
        out["stage1"], _, _ = run_stage1(x, out["base"], log=log)
        checkpoint.save(p_s1, out["stage1"])
        log(f"[train] stage1 in {time.time()-t0:.0f}s")

    p_s2 = artifact_path(artifacts, x, "stage2")
    if os.path.exists(p_s2 + ".npz"):
        out["stage2"] = checkpoint.restore(p_s2)
        log(f"[cache] stage2 <- {p_s2}")
    else:
        t0 = time.time()
        out["stage2"] = run_stage2(x, out["stage1"], log=log)
        checkpoint.save(p_s2, out["stage2"])
        log(f"[train] stage2 in {time.time()-t0:.0f}s")
    return out


SCALES = {"tiny": TINY, "small": SMALL}
