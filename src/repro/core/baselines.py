"""Routing baselines the paper compares against (Table 1 / Table 7).

Pre-generation:
  * BERTRouter   — transformer encoder classifier on the prompt (the
    paper trains a BERT; ours reuses the model substrate at ~BERT-tiny
    scale with a pooled binary head).
  * KNNRouter    — hashed char-n-gram features, k-NN over train labels
    (RouterBench-style).
  * HybridLLMRouter — MLP on the same features trained with SOFT labels
    (empirical SLM accuracy from multi-sampling), per Ding et al. 2024.
Cascade-adjacent scorers:
  * margin_scores   — top1-top2 vote margin from SC samples
    (margin-sampling baseline, Table 7).
  * FrugalGPTScorer — correctness classifier on (prompt, generated
    answer) pairs, per Chen et al. 2023.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.voting import vote_scores
from repro.data.tokenizer import CharTokenizer, default_tokenizer
from repro.models import model as model_lib
from repro.serving.batch import make_buckets, pick_bucket
from repro.training.optimizer import adamw, cosine_warmup_schedule


# ----------------------------------------------------------------------
# Hashed n-gram featurizer (shared by KNN / HybridLLM / FrugalGPT)
# ----------------------------------------------------------------------

def featurize(texts: Sequence[str], dim: int = 512, n: int = 3) -> np.ndarray:
    out = np.zeros((len(texts), dim), np.float32)
    for i, t in enumerate(texts):
        for j in range(max(len(t) - n + 1, 1)):
            h = int(hashlib.blake2s(t[j:j + n].encode(), digest_size=4
                                    ).hexdigest(), 16)
            out[i, h % dim] += 1.0
    norms = np.linalg.norm(out, axis=1, keepdims=True)
    return out / np.maximum(norms, 1e-8)


# ----------------------------------------------------------------------
# KNN
# ----------------------------------------------------------------------

@dataclasses.dataclass
class KNNRouter:
    k: int = 15
    dim: int = 512

    def fit(self, texts: Sequence[str], labels: Sequence[float]):
        self.x = featurize(texts, self.dim)
        self.y = np.asarray(labels, np.float32)
        return self

    def score(self, texts: Sequence[str]) -> np.ndarray:
        q = featurize(texts, self.dim)
        sims = q @ self.x.T
        idx = np.argsort(-sims, axis=1)[:, :self.k]
        return self.y[idx].mean(axis=1)


# ----------------------------------------------------------------------
# MLP on soft labels (HybridLLM)
# ----------------------------------------------------------------------

class HybridLLMRouter:
    def __init__(self, dim: int = 512, hidden: int = 128, epochs: int = 200,
                 lr: float = 3e-3, seed: int = 0):
        self.dim, self.hidden, self.epochs, self.lr = dim, hidden, epochs, lr
        self.seed = seed

    def fit(self, texts: Sequence[str], soft_labels: Sequence[float]):
        x = jnp.asarray(featurize(texts, self.dim))
        y = jnp.asarray(np.asarray(soft_labels, np.float32))
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        params = {
            "w1": jax.random.normal(k1, (self.dim, self.hidden)) * 0.05,
            "b1": jnp.zeros((self.hidden,)),
            "w2": jax.random.normal(k2, (self.hidden, 1)) * 0.05,
            "b2": jnp.zeros((1,)),
        }

        def logit(p, x):
            h = jnp.tanh(x @ p["w1"] + p["b1"])
            return (h @ p["w2"] + p["b2"])[:, 0]

        def loss(p):
            z = logit(p, x)
            return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))

        opt = adamw(lambda s: self.lr, weight_decay=1e-4, clip_norm=0.0)
        state = opt.init(params)

        @jax.jit
        def step(params, state):
            g = jax.grad(loss)(params)
            return opt.update(g, state, params)

        for _ in range(self.epochs):
            params, state = step(params, state)
        self.params = params
        self._logit = jax.jit(logit)
        return self

    def score(self, texts: Sequence[str]) -> np.ndarray:
        x = jnp.asarray(featurize(texts, self.dim))
        return np.asarray(jax.nn.sigmoid(self._logit(self.params, x)))


# ----------------------------------------------------------------------
# Transformer ("BERT") classifier on the model substrate
# ----------------------------------------------------------------------

def _cls_config(vocab: int) -> ModelConfig:
    return ModelConfig(
        name="bert-router", arch_type="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=vocab,
        remat=False, source="baseline classifier")


class BERTRouter:
    def __init__(self, tokenizer: Optional[CharTokenizer] = None,
                 max_len: int = 256, epochs: int = 8, batch: int = 32,
                 lr: float = 3e-4, seed: int = 0):
        self.tok = tokenizer or default_tokenizer()
        self.max_len, self.epochs, self.batch, self.lr = max_len, epochs, batch, lr
        self.seed = seed
        self.cfg = _cls_config(self.tok.vocab_size)

    def _encode(self, texts):
        out = np.zeros((len(texts), self.max_len), np.int32)
        mask = np.zeros((len(texts), self.max_len), np.float32)
        for i, t in enumerate(texts):
            ids = self.tok.encode(t, bos=True)[: self.max_len]
            out[i, : len(ids)] = ids
            mask[i, : len(ids)] = 1.0
        return out, mask

    def fit(self, texts: Sequence[str], labels: Sequence[float]):
        x, m = self._encode(texts)
        y = np.asarray(labels, np.float32)
        key = jax.random.PRNGKey(self.seed)
        params = {
            "lm": model_lib.init_params(self.cfg, key),
            "head": jax.random.normal(key, (self.cfg.d_model,)) * 0.02,
            "bias": jnp.zeros(()),
        }

        def logit(p, toks, mask):
            _, _, hidden = model_lib.forward(p["lm"], self.cfg, tokens=toks,
                                             return_hidden=True)
            pooled = jnp.sum(hidden * mask[..., None], 1) / jnp.maximum(
                jnp.sum(mask, 1, keepdims=True), 1.0)
            return pooled @ p["head"] + p["bias"]

        def loss(p, toks, mask, yy):
            z = logit(p, toks, mask)
            return jnp.mean(jnp.maximum(z, 0) - z * yy +
                            jnp.log1p(jnp.exp(-jnp.abs(z))))

        n_steps = max(1, (len(texts) // self.batch) * self.epochs)
        opt = adamw(cosine_warmup_schedule(self.lr, n_steps), clip_norm=1.0)
        state = opt.init(params)

        @jax.jit
        def step(params, state, toks, mask, yy):
            g = jax.grad(loss)(params, toks, mask, yy)
            return opt.update(g, state, params)

        rng = np.random.RandomState(self.seed)
        for _ in range(self.epochs):
            order = rng.permutation(len(texts))
            for i in range(0, len(order) - self.batch + 1, self.batch):
                j = order[i:i + self.batch]
                params, state = step(params, state, jnp.asarray(x[j]),
                                     jnp.asarray(m[j]), jnp.asarray(y[j]))
        self.params = params
        self._logit = jax.jit(logit)
        return self

    def score(self, texts: Sequence[str]) -> np.ndarray:
        """Bucketed scoring (same padding scheme as serving/batch):
        texts are grouped by the smallest length bucket that fits and
        chunk sizes padded to powers of two, so short prompts don't pay
        max_len FLOPs and the jitted logit compiles once per bucket
        pair instead of once per ragged batch."""
        len_buckets = make_buckets(self.max_len)
        chunk_buckets = make_buckets(64, 8)
        ids = [self.tok.encode(t, bos=True)[: self.max_len] for t in texts]
        groups = collections.defaultdict(list)
        for i, seq in enumerate(ids):
            groups[pick_bucket(len(seq), len_buckets)].append(i)
        out = np.zeros((len(texts),), np.float32)
        for width in sorted(groups):
            idxs = groups[width]
            for c0 in range(0, len(idxs), 64):
                chunk = idxs[c0:c0 + 64]
                n = pick_bucket(len(chunk), chunk_buckets)
                x = np.zeros((n, width), np.int32)
                m = np.zeros((n, width), np.float32)
                for r, i in enumerate(chunk):
                    x[r, : len(ids[i])] = ids[i]
                    m[r, : len(ids[i])] = 1.0
                z = self._logit(self.params, jnp.asarray(x), jnp.asarray(m))
                out[chunk] = np.asarray(jax.nn.sigmoid(z))[: len(chunk)]
        return out


# ----------------------------------------------------------------------
# Margin sampling + FrugalGPT
# ----------------------------------------------------------------------

def margin_scores(votes_by_item) -> np.ndarray:
    """Top1-top2 weighted-vote margin from SC samples."""
    out = []
    for votes in votes_by_item:
        scores, _ = vote_scores(votes)
        vals = sorted(scores.values(), reverse=True)
        if not vals:
            out.append(0.0)
        elif len(vals) == 1:
            out.append(vals[0])
        else:
            out.append(vals[0] - vals[1])
    return np.asarray(out, np.float32)


class FrugalGPTScorer(HybridLLMRouter):
    """Correctness classifier on (prompt || answer) text."""

    def fit_pairs(self, prompts, answers, correct):
        texts = [p + " || " + a for p, a in zip(prompts, answers)]
        return super().fit(texts, np.asarray(correct, np.float32))

    def score_pairs(self, prompts, answers):
        texts = [p + " || " + a for p, a in zip(prompts, answers)]
        return super().score(texts)
