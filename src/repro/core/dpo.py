"""SATER Stage I: shortest-response preference optimization.

Loss (paper Eq. 4-5):  L = L_DPO + lambda * L_SFT
  * L_DPO: sigmoid preference loss, beta = 1.0,
  * L_SFT: NLL of the chosen (shortest-correct) response,
  * lambda = 0.2 stabilizes training (paper: lower beta/lambda collapses
    output quality).

Reference model: with LoRA, pi_ref == the base model (adapters off) and
pi_theta == base (+) adapters, so one weight set serves both — two
forward passes, no second model copy (DESIGN.md §2).

Batches are token-level:
  {"chosen": (B,S), "chosen_mask": (B,S), "rejected": (B,S),
   "rejected_mask": (B,S)}
where *_mask is 1 on response tokens (the prompt prefix and padding are
excluded from both the preference log-ratios and the SFT term).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.training import lora as lora_lib
from repro.training.optimizer import Optimizer


@dataclasses.dataclass(frozen=True)
class DPOConfig:
    beta: float = 1.0
    sft_lambda: float = 0.2


def sequence_logprob(params, cfg: ModelConfig, tokens, resp_mask):
    """Sum log p(token_t | <t) over response tokens.  tokens: (B,S).

    Same fused max/exp-sum/one-hot-dot formulation as model.lm_loss: no
    f32 (B,S,V) materialization and vocab-sharded reductions under a
    mesh (cfg.shard_logits_vocab)."""
    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]
    mask = resp_mask[:, 1:].astype(jnp.float32)
    logits, _ = model_lib.forward(params, cfg, tokens=inputs)
    logits = model_lib._maybe_vocab_shard(cfg, logits)
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = labels[..., None] == jnp.arange(lf.shape[-1], dtype=labels.dtype)
    label_logit = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    ll = label_logit - lse
    return jnp.sum(ll * mask, axis=-1), jnp.sum(mask, axis=-1)


def dpo_loss(policy_params, ref_params, cfg: ModelConfig, batch,
             dcfg: DPOConfig):
    """Combined DPO + SFT loss on one preference batch."""
    b = batch["chosen"].shape[0]
    # one forward for policy, one for reference, each on [chosen; rejected]
    tokens = jnp.concatenate([batch["chosen"], batch["rejected"]], axis=0)
    masks = jnp.concatenate([batch["chosen_mask"], batch["rejected_mask"]], axis=0)
    lp_pol, ntok = sequence_logprob(policy_params, cfg, tokens, masks)
    lp_ref, _ = sequence_logprob(ref_params, cfg, tokens, masks)
    lp_ref = jax.lax.stop_gradient(lp_ref)

    pol_c, pol_r = lp_pol[:b], lp_pol[b:]
    ref_c, ref_r = lp_ref[:b], lp_ref[b:]
    logits = dcfg.beta * ((pol_c - ref_c) - (pol_r - ref_r))
    pref_loss = -jnp.mean(jax.nn.log_sigmoid(logits))
    sft_loss = -jnp.mean(pol_c / jnp.maximum(ntok[:b], 1.0))
    loss = pref_loss + dcfg.sft_lambda * sft_loss
    metrics = {
        "dpo_loss": pref_loss,
        "sft_loss": sft_loss,
        "reward_margin": jnp.mean(logits) / dcfg.beta,
        "pref_acc": jnp.mean((logits > 0).astype(jnp.float32)),
    }
    return loss, metrics


def make_dpo_step(cfg: ModelConfig, opt: Optimizer, lcfg: lora_lib.LoraConfig,
                  dcfg: DPOConfig = DPOConfig()):
    """LoRA DPO step.  state = {base, lora, opt_state, step}.

    The reference forward reuses ``base`` directly (adapters off).
    """

    def step(state, batch):
        def lf(lora_tree):
            merged = lora_lib.merge(state["base"], lora_tree, lcfg)
            return dpo_loss(merged, state["base"], cfg, batch, dcfg)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["lora"])
        new_lora, new_opt = opt.update(grads, state["opt_state"], state["lora"])
        metrics = dict(metrics, loss=loss)
        return {"base": state["base"], "lora": new_lora, "opt_state": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def make_full_dpo_step(cfg: ModelConfig, opt: Optimizer,
                       dcfg: DPOConfig = DPOConfig()):
    """Full-parameter DPO step (used for the tiny CPU-scale models where
    LoRA capacity would bottleneck the reproduction).

    state = {params, ref_params, opt_state, step}.
    """

    def step(state, batch):
        def lf(p):
            return dpo_loss(p, state["ref_params"], cfg, batch, dcfg)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        new_params, new_opt = opt.update(grads, state["opt_state"], state["params"])
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "ref_params": state["ref_params"],
                "opt_state": new_opt, "step": state["step"] + 1}, metrics

    return step
