"""SATER Stage-I data construction (paper §3 Stage I).

Sample each training question K=10 times; positive = shortest *correct*
response; negative = longest *incorrect* response whose length is at
least 1.5x the positive's.  Questions lacking either side are skipped.
(The paper notes using the longest *correct* response as the negative
instead costs >2% accuracy — we keep their choice.)
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.data.tasks import TaskItem, is_correct
from repro.data.pipeline import format_prompt

MIN_LEN_RATIO = 1.5


@dataclasses.dataclass
class SampledQuestion:
    item: TaskItem
    texts: List[str]          # K sampled responses
    gen_lens: List[int]       # token lengths

    @property
    def correct_flags(self) -> List[bool]:
        return [is_correct(self.item, t) for t in self.texts]

    @property
    def accuracy(self) -> float:
        f = self.correct_flags
        return sum(f) / len(f) if f else 0.0


def build_preference_pairs(samples: Sequence[SampledQuestion],
                           min_ratio: float = MIN_LEN_RATIO
                           ) -> List[Tuple[str, str, str]]:
    """Returns (prompt, chosen, rejected) triples."""
    pairs = []
    for sq in samples:
        flags = sq.correct_flags
        correct = [(t, l) for t, l, f in zip(sq.texts, sq.gen_lens, flags) if f]
        wrong = [(t, l) for t, l, f in zip(sq.texts, sq.gen_lens, flags) if not f]
        if not correct or not wrong:
            continue
        chosen, c_len = min(correct, key=lambda x: x[1])
        rejected, r_len = max(wrong, key=lambda x: x[1])
        if r_len < min_ratio * c_len:
            continue
        pairs.append((format_prompt(sq.item), chosen, rejected))
    return pairs


def empirical_accuracies(samples: Sequence[SampledQuestion]) -> np.ndarray:
    return np.array([sq.accuracy for sq in samples], np.float32)
