"""Routing drivers: pre-generation and cascade, for SATER models and the
SC baselines — ties together engine + confidence + voting + metrics.

An ``SLM`` bundles params/config/tokenizer/generation settings.  The LLM
side is an :class:`OracleLLM` (configurable accuracy/length profile —
the paper's "(100)" setting is ``OracleLLM(accuracy=1.0)``) or a
:class:`ModelLLM` wrapping a larger locally-trained model.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import voting
from repro.core.confidence import Vote, fcv_schedule, parse_vote, rcv_schedule
from repro.core.metrics import RouteOutcome, THRESHOLDS
from repro.core.preferences import SampledQuestion
from repro.data.pipeline import encode_prompts, format_prompt
from repro.data.tasks import TaskItem, is_correct
from repro.data.tokenizer import CharTokenizer
from repro.serving.engine import GenConfig, decode_texts, generate


@dataclasses.dataclass
class SLM:
    params: dict
    cfg: ModelConfig
    tokenizer: CharTokenizer
    gcfg: GenConfig
    max_prompt_len: int = 320
    lane_budget: int = 96        # max batch lanes per engine call


@dataclasses.dataclass
class OracleLLM:
    """LLM stand-in with a difficulty-dependent accuracy profile."""
    accuracy: float = 1.0
    avg_out_tokens: int = 60
    per_difficulty_decay: float = 0.0   # acc - decay * difficulty
    seed: int = 0

    def answer(self, item: TaskItem) -> tuple:
        rng = random.Random((hash(item.question) ^ self.seed) & 0xFFFFFFFF)
        acc = max(0.0, self.accuracy - self.per_difficulty_decay * item.difficulty)
        correct = rng.random() < acc
        toks = max(8, int(rng.gauss(self.avg_out_tokens,
                                    self.avg_out_tokens * 0.25)))
        return correct, toks


@dataclasses.dataclass
class ModelLLM:
    """A larger locally-trained model acting as M_l."""
    slm: SLM

    def answer(self, item: TaskItem) -> tuple:
        texts, lens = batch_generate(self.slm, [format_prompt(item)],
                                     jax.random.PRNGKey(hash(item.question) & 0xFFFF))
        return is_correct(item, texts[0]), int(lens[0])


# ----------------------------------------------------------------------
# Batched generation over prompt lists
# ----------------------------------------------------------------------

def batch_generate(slm: SLM, prompts: Sequence[str], key):
    """Generate one response per prompt (chunked to lane_budget)."""
    texts: List[str] = []
    lens: List[int] = []
    for i in range(0, len(prompts), slm.lane_budget):
        chunk = prompts[i:i + slm.lane_budget]
        toks, tlens = encode_prompts(chunk, slm.tokenizer, slm.max_prompt_len)
        key, sub = jax.random.split(key)
        gen, glens = generate(slm.params, slm.cfg, toks, tlens, sub, slm.gcfg)
        texts.extend(decode_texts(slm.tokenizer, gen))
        lens.extend(int(g) for g in glens)
    return texts, lens


def sample_k(slm: SLM, items: Sequence[TaskItem], levels: Sequence[Optional[float]],
             key, seed_offset: int = 0) -> List[List[Vote]]:
    """K = len(levels) samples per item; level None = no confidence prompt
    (vanilla SC).  Returns votes[item][k]."""
    prompts = []
    for item in items:
        for lvl in levels:
            prompts.append(format_prompt(item, conf_level=lvl))
    key = jax.random.fold_in(key, seed_offset)
    texts, lens = batch_generate(slm, prompts, key)
    votes: List[List[Vote]] = []
    k = len(levels)
    for qi in range(len(items)):
        vs = []
        for j, lvl in enumerate(levels):
            t = texts[qi * k + j]
            vs.append(parse_vote(t, lvl if lvl is not None else voting.MEAN_CONF,
                                 lens[qi * k + j]))
        votes.append(vs)
    return votes


def collect_samples(slm: SLM, items: Sequence[TaskItem], k: int, key,
                    level: Optional[float] = None) -> List[SampledQuestion]:
    """K same-level samples per item (Stage-I/II data collection)."""
    votes = sample_k(slm, items, [level] * k, key)
    return [SampledQuestion(item, [v.text for v in vs], [v.gen_tokens for v in vs])
            for item, vs in zip(items, votes)]


# ----------------------------------------------------------------------
# Pre-generation routing (SATER: prompt at tau, route on rejection)
# ----------------------------------------------------------------------

def pregen_outcomes_sater(slm: SLM, items: Sequence[TaskItem], llm, key,
                          thresholds: Sequence[float] = None
                          ) -> Dict[float, List[RouteOutcome]]:
    """One generation per (item, level); threshold tau uses level tau.

    tau = 0.0 keeps everything on the SLM (uses the lowest level's
    response); tau = 1.0-level rejections route.
    """
    thresholds = thresholds or THRESHOLDS
    levels = rcv_schedule()                      # 0.1 .. 1.0
    votes = sample_k(slm, items, levels, key)
    llm_ans = [llm.answer(it) for it in items]
    out: Dict[float, List[RouteOutcome]] = {}
    for tau in thresholds:
        lvl_idx = 0 if tau <= levels[0] else min(
            range(len(levels)), key=lambda i: abs(levels[i] - tau))
        rows = []
        for qi, item in enumerate(items):
            v = votes[qi][lvl_idx]
            routed = v.rejected and tau > 0.0
            correct = (not v.rejected) and is_correct(item, v.text)
            lc, lt = llm_ans[qi]
            rows.append(RouteOutcome(
                routed=routed, slm_correct=correct, slm_engaged=True,
                slm_in_tokens=len(format_prompt(item)),
                slm_out_tokens=v.gen_tokens,
                llm_correct=lc, llm_out_tokens=lt,
                decision_tokens=v.gen_tokens))
        out[tau] = rows
    return out


# ----------------------------------------------------------------------
# Cascade routing
# ----------------------------------------------------------------------

CASCADE_MODES = ("SC", "RCV", "FCV")


def cascade_outcomes(slm: SLM, items: Sequence[TaskItem], llm, key,
                     mode: str = "RCV", k: int = 10,
                     thresholds: Sequence[float] = None,
                     early_stop: Optional[bool] = None
                     ) -> Dict[float, List[RouteOutcome]]:
    """Cascade with K parallel samples and weighted voting.

    mode: SC  — no confidence prompts, uniform weights, no early stop
          RCV — levels 0.1..1.0, early stop
          FCV — all at 1.0, early stop
    """
    thresholds = thresholds or THRESHOLDS
    if mode == "SC":
        levels: List[Optional[float]] = [None] * k
        early = False if early_stop is None else early_stop
    elif mode == "RCV":
        levels = rcv_schedule(k)
        early = True if early_stop is None else early_stop
    elif mode == "FCV":
        levels = fcv_schedule(k)
        early = True if early_stop is None else early_stop
    else:
        raise ValueError(mode)
    votes = sample_k(slm, items, levels, key)
    llm_ans = [llm.answer(it) for it in items]

    out: Dict[float, List[RouteOutcome]] = {}
    for tau in thresholds:
        rows = []
        for qi, item in enumerate(items):
            vs = votes[qi]
            if early:
                dec = voting.decide_with_early_stop(vs, tau)
            else:
                dec = voting.decide_no_early_stop(vs, tau)
            correct = dec.accepted and dec.answer == item.answer
            lc, lt = llm_ans[qi]
            rows.append(RouteOutcome(
                routed=not dec.accepted, slm_correct=correct, slm_engaged=True,
                slm_in_tokens=len(format_prompt(item)),
                slm_out_tokens=dec.used_tokens,
                llm_correct=lc, llm_out_tokens=lt,
                decision_tokens=dec.decision_tokens))
        out[tau] = rows
    return out


# ----------------------------------------------------------------------
# SLM-only endpoint (single unprompted inference) for curve endpoints
# ----------------------------------------------------------------------

def slm_only_endpoint(slm: SLM, items: Sequence[TaskItem], llm, key, cm):
    texts, lens = batch_generate(slm, [format_prompt(it) for it in items], key)
    llm_avg = float(np.mean([llm.answer(it)[1] for it in items]))
    denom = sum(cm.llm_cost(len(format_prompt(it)), llm_avg) for it in items)
    c_s = sum(cm.slm_cost(len(format_prompt(it)), l)
              for it, l in zip(items, lens)) / denom
    p_s = float(np.mean([is_correct(it, t) for it, t in zip(items, texts)]))
    slm_out = [int(l) for l in lens]
    slm_corr = [is_correct(it, t) for it, t in zip(items, texts)]
    return (c_s, p_s), slm_corr, slm_out, texts
