"""Routing drivers: pre-generation and cascade, for SATER models and the
SC baselines — ties together engine + confidence + voting + metrics.

An ``SLM`` bundles params/config/tokenizer/generation settings.  The LLM
side is an :class:`OracleLLM` (configurable accuracy/length profile —
the paper's "(100)" setting is ``OracleLLM(accuracy=1.0)``) or a
:class:`ModelLLM` wrapping a larger locally-trained model.
"""

from __future__ import annotations

import collections
import dataclasses
import random
from typing import Dict, List, Mapping, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import voting
from repro.core.confidence import Vote, fcv_schedule, parse_vote, rcv_schedule
from repro.core.metrics import RouteOutcome, THRESHOLDS
from repro.core.preferences import SampledQuestion
from repro.data.pipeline import format_prompt
from repro.data.tasks import TaskItem, is_correct, stable_hash
from repro.data.tokenizer import CharTokenizer
from repro.serving.batch import GenConfig, make_buckets, pick_bucket
from repro.serving.scheduler import (Completion, Request, RequestGroup,
                                     Scheduler, StopPolicy)


@dataclasses.dataclass
class SLM:
    params: dict
    cfg: ModelConfig
    tokenizer: CharTokenizer
    gcfg: GenConfig
    max_prompt_len: int = 320
    lane_budget: int = 96        # max concurrent decode lanes
    round_tokens: int = 16       # decode round length (early-stop grain)
    paged: bool = False          # block-paged KV cache (serving/block_pool)
    block_size: int = 32         # cache slots per block when paged
    share_prefix: bool = False   # prefill vote groups once + prefix cache
    #                              (requires paged; see serving/scheduler)
    chunk_size: "int | None" = None      # chunked prefill chunk width
    prefill_budget: "int | None" = None  # per-round prefill token budget
    spec_k: "int | None" = None          # speculative verify width: accept
    #                                      queued draft tokens (e.g. a
    #                                      rejected tier's completion) up to
    #                                      k per round (serving/scheduler)
    state_slots: "int | None" = None     # recurrent-state slot cap for a
    #                                      paged SSM/hybrid tier (default:
    #                                      one slot per lane); admission
    #                                      backpressures on slot exhaustion
    #                                      like KV-block exhaustion
    mesh: "object | None" = None         # jax Mesh: shard lanes/KV over its
    #                                      'data' axis and pin decode to its
    #                                      devices (cascade tier placement —
    #                                      launch/mesh.make_tier_mesh); the
    #                                      serving loop requires model=1
    kv_quant: bool = False       # int8 KV cache with per-(slot, head) f32
    #                              scales (dense and paged; serving output is
    #                              tolerance-comparable to fp, not bit-equal)
    quantize: "str | None" = None        # weight quantization for the tier:
    #                                      "int8" round-trips every matmul
    #                                      weight through per-output-channel
    #                                      absmax int8 at scheduler build
    #                                      (memoized — quantize once per SLM)


@dataclasses.dataclass
class OracleLLM:
    """LLM stand-in with a difficulty-dependent accuracy profile."""
    accuracy: float = 1.0
    avg_out_tokens: int = 60
    per_difficulty_decay: float = 0.0   # acc - decay * difficulty
    seed: int = 0

    def answer(self, item: TaskItem) -> tuple:
        rng = random.Random((stable_hash(item.question) ^ self.seed)
                            & 0xFFFFFFFF)
        acc = max(0.0, self.accuracy - self.per_difficulty_decay * item.difficulty)
        correct = rng.random() < acc
        toks = max(8, int(rng.gauss(self.avg_out_tokens,
                                    self.avg_out_tokens * 0.25)))
        return correct, toks


@dataclasses.dataclass
class ModelLLM:
    """A larger locally-trained model acting as M_l."""
    slm: SLM

    def answer(self, item: TaskItem) -> tuple:
        texts, lens = batch_generate(
            self.slm, [format_prompt(item)],
            jax.random.PRNGKey(stable_hash(item.question) & 0xFFFF))
        return is_correct(item, texts[0]), int(lens[0])


# ----------------------------------------------------------------------
# Weight quantization for cheap cascade tiers
# ----------------------------------------------------------------------

def quantize_params_int8(params):
    """Round-trip every matmul-shaped weight through per-output-channel
    absmax int8: ``q = round(w / s)`` with ``s = absmax(column) / 127``,
    returned as ``q * s`` in the original dtype.

    Only leaves with ndim >= 2 are touched (matmul weights, embeddings);
    norm gains / biases / router scalars stay exact.  The round-trip
    representation keeps every downstream apply site unchanged (they all
    cast weights to the compute dtype anyway) while making the tier's
    numerics exactly those of an int8-weight deployment.
    """
    import jax.numpy as jnp

    def q(w):
        if w.ndim < 2:
            return w
        wf = w.astype(jnp.float32)
        s = jnp.max(jnp.abs(wf), axis=-2, keepdims=True) / 127.0
        qw = jnp.round(wf / jnp.maximum(s, 1e-8))
        return (qw * s).astype(w.dtype)

    return jax.tree.map(q, params)


# quantize-once memo: SLMs are rebuilt per call site but reuse one params
# tree; key on id(params) and hold a reference so the id can't recycle
_QUANT_PARAMS: Dict[int, tuple] = {}


def _tier_params(slm: SLM):
    """The params tree a scheduler for this SLM should serve — the
    original weights, or their memoized int8 round-trip when
    ``slm.quantize`` is set."""
    if slm.quantize is None:
        return slm.params
    if slm.quantize != "int8":
        raise ValueError(
            f"unsupported SLM.quantize={slm.quantize!r}: only 'int8' "
            "(per-output-channel absmax round-trip) is implemented")
    hit = _QUANT_PARAMS.get(id(slm.params))
    if hit is not None:
        return hit[1]
    quantized = quantize_params_int8(slm.params)
    _QUANT_PARAMS[id(slm.params)] = (slm.params, quantized)
    return quantized


# ----------------------------------------------------------------------
# Streaming generation through the continuous-batching scheduler
# ----------------------------------------------------------------------

def make_scheduler(slm: SLM, n_requests: int) -> Scheduler:
    """Scheduler over the SLM's lane pool.  The pool width is bucketed
    to the request count so small calls don't decode a full-width pool
    while big ones still compile once per width bucket.

    Quantized tiers funnel through here too: ``slm.kv_quant`` flips the
    model config's int8-KV flag and ``slm.quantize`` swaps in the
    memoized int8-round-tripped weights — so a multi-tier cascade can
    mix precisions per tier with no cascade-side changes
    (core/cascade_multi builds each tier's scheduler via this exact
    function)."""
    n_lanes = pick_bucket(min(max(n_requests, 1), slm.lane_budget),
                          make_buckets(slm.lane_budget, 1))
    if slm.mesh is not None:
        # sharded lanes: the pool splits evenly over the mesh's data
        # axis, and every shard needs >= 2 lanes (the scheduler's
        # size-1 batch-dim rule), so round the bucket up accordingly
        s = slm.mesh.shape["data"]
        n_lanes = max(2 * s, -(-n_lanes // s) * s)
    cfg = slm.cfg
    if slm.kv_quant and not cfg.kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    return Scheduler(_tier_params(slm), cfg, slm.tokenizer, slm.gcfg,
                     n_lanes=n_lanes, round_tokens=slm.round_tokens,
                     max_prompt_len=slm.max_prompt_len, paged=slm.paged,
                     block_size=slm.block_size,
                     share_prefix=slm.share_prefix,
                     chunk_size=slm.chunk_size,
                     prefill_budget=slm.prefill_budget,
                     spec_k=slm.spec_k, state_slots=slm.state_slots,
                     mesh=slm.mesh)


def batch_generate(slm: SLM, prompts: Sequence[str], key):
    """Generate one response per prompt, streamed through the scheduler
    (requests beyond the lane pool are admitted as lanes free up)."""
    reqs = [Request(uid=i, prompt=p) for i, p in enumerate(prompts)]
    comps, _ = make_scheduler(slm, len(reqs)).run(reqs, key)
    return [c.text for c in comps], [int(c.gen_len) for c in comps]


def _vote_requests(items: Sequence[TaskItem],
                   levels: Sequence[Optional[float]]) -> List[RequestGroup]:
    """One RequestGroup of K vote lanes per question.  A sharing
    scheduler admits each group atomically and prefills its prompt once
    (FCV/SC levels are uniform, so the K prompts are token-identical);
    a dense or non-sharing scheduler dissolves the groups into the same
    K independent requests as before."""
    k = len(levels)
    return [RequestGroup([
        Request(uid=qi * k + j, prompt=format_prompt(item, conf_level=lvl),
                group=qi, meta={"level": lvl})
        for j, lvl in enumerate(levels)])
        for qi, item in enumerate(items)]


def _parse_completion(comp: Completion) -> Vote:
    lvl = comp.meta.get("level") if comp.meta else None
    return parse_vote(comp.text, lvl if lvl is not None else voting.MEAN_CONF,
                      int(comp.gen_len))


def sample_k(slm: SLM, items: Sequence[TaskItem], levels: Sequence[Optional[float]],
             key, seed_offset: int = 0) -> List[List[Vote]]:
    """K = len(levels) samples per item; level None = no confidence prompt
    (vanilla SC).  Returns votes[item][k].

    Every lane runs to EOS/budget (no StopPolicy) so the returned votes
    support post-hoc multi-tau early-stop simulation; use
    sample_k_streamed for generation that actually stops.
    """
    reqs = _vote_requests(items, levels)
    key = jax.random.fold_in(key, seed_offset)
    comps, _ = make_scheduler(slm, len(items) * len(levels)).run(reqs, key)
    k = len(levels)
    return [[_parse_completion(c) for c in comps[qi * k:(qi + 1) * k]]
            for qi in range(len(items))]


def collect_samples(slm: SLM, items: Sequence[TaskItem], k: int, key,
                    level: Optional[float] = None) -> List[SampledQuestion]:
    """K same-level samples per item (Stage-I/II data collection)."""
    votes = sample_k(slm, items, [level] * k, key)
    return [SampledQuestion(item, [v.text for v in vs], [v.gen_tokens for v in vs])
            for item, vs in zip(items, votes)]


# ----------------------------------------------------------------------
# Vote-aware early stopping as a scheduler StopPolicy
# ----------------------------------------------------------------------

class VoteEarlyStop(StopPolicy):
    """Kills all K lanes of a question the moment the confidence-weighted
    vote is decided — the scheduler-native form of
    voting.decide_with_early_stop.

    Lane weights are known *before* generation (they depend only on the
    prompted confidence level), so after each lane finishes we can bound
    the final score: if the current leader's guaranteed share already
    clears tau we accept, and if even the optimistic share of any
    candidate stays below tau we route; either way the remaining lanes
    of that group are evicted mid-flight.
    """

    def __init__(self, tau: float,
                 group_levels: Mapping[int, Sequence[Optional[float]]],
                 alpha: float = voting.ALPHA, parse=None):
        self.tau, self.alpha = tau, alpha
        self._parse = parse or _parse_completion
        self._total_w: Dict[int, float] = {}
        self._pending_w: Dict[int, float] = {}
        self._pending_n: Dict[int, int] = {}
        self._seen: Dict[int, Dict[str, float]] = {}
        self._votes: Dict[int, List[Vote]] = {}
        self._tau: Dict[int, float] = {}
        for g, levels in group_levels.items():
            self.add_group(g, levels)
        self.decisions: Dict[int, voting.CascadeDecision] = {}

    def add_group(self, g: int, levels: Sequence[Optional[float]],
                  tau: Optional[float] = None) -> None:
        """Register a vote group after construction — the streaming
        form used by the pipelined cascade, which submits a question's
        tier-(i+1) group only once tier i rejects it.  ``tau``
        overrides the policy default per group, so one policy (and one
        fused ServingLoop) can serve tiers with different thresholds."""
        ws = [voting.weight(l if l is not None else voting.MEAN_CONF,
                            self.alpha) for l in levels]
        self._total_w[g] = sum(ws)
        self._pending_w[g] = sum(ws)
        self._pending_n[g] = len(ws)
        self._seen[g] = collections.defaultdict(float)
        self._votes[g] = []
        self._tau[g] = self.tau if tau is None else tau

    def observe(self, comp: Completion):
        g = comp.group
        if g not in self._total_w or g in self.decisions:
            return ()
        v = self._parse(comp)
        self._votes[g].append(v)
        self._pending_w[g] -= voting.weight(v.confidence, self.alpha)
        self._pending_n[g] -= 1
        if not v.rejected and v.answer is not None:
            self._seen[g][v.answer] += voting.weight(v.confidence, self.alpha)
        total_w, seen = self._total_w[g], self._seen[g]
        tau = self._tau[g]
        n_seen = len(self._votes[g])
        if tau > 0 and total_w > 0:
            best = max(seen.values()) if seen else 0.0
            pend = max(self._pending_w[g], 0.0)
            lo = best / total_w
            hi = (best + pend) / total_w if seen else pend / total_w
            if seen and lo >= tau:
                ans = max(seen, key=seen.get)
                self.decisions[g] = voting.CascadeDecision(
                    ans, lo, True, v.gen_tokens, 0, n_seen)
                return (g,)
            if hi < tau:
                self.decisions[g] = voting.CascadeDecision(
                    None, hi, False, v.gen_tokens, 0, n_seen)
                return (g,)
        if self._pending_n[g] == 0:    # group complete: full-vote decision
            self.decisions[g] = voting.decide_no_early_stop(
                self._votes[g], tau, self.alpha)
        return ()


@dataclasses.dataclass
class StreamResult:
    """Per-question outcome of a streamed (true early stop) vote run."""
    decision: voting.CascadeDecision
    votes: List[Vote]
    generated_tokens: int        # tokens actually decoded across K lanes


def sample_k_streamed(slm: SLM, items: Sequence[TaskItem],
                      levels: Sequence[Optional[float]], key, tau: float,
                      seed_offset: int = 0, early_stop: bool = True):
    """K vote lanes per item through the scheduler with (optionally) the
    VoteEarlyStop policy actually cancelling decided groups mid-flight.

    Unlike sample_k, stopped lanes really generate fewer tokens; the
    decisions come from the policy (or the full vote when it never
    fired).  Returns ([StreamResult per item], SchedStats).

    Vote groups are submitted as RequestGroups: with
    ``slm.share_prefix`` (paged), each question's K lanes are admitted
    atomically and prefilled once, the prompt KV refcount-shared across
    the group — a kill by VoteEarlyStop then releases shared blocks by
    decrementing holds (the last holder frees), never double-freeing.
    """
    reqs = _vote_requests(items, levels)
    key = jax.random.fold_in(key, seed_offset)
    policy = (VoteEarlyStop(tau, {qi: levels for qi in range(len(items))})
              if early_stop else None)
    # explicitly over the streaming loop (submit -> drain ==
    # Scheduler.run bit-for-bit): the pipelined cascade drives the very
    # same loop one step at a time, escalating rejections mid-flight
    loop = make_scheduler(slm, len(items) * len(levels)).loop(
        key, stop_policy=policy)
    loop.submit(reqs)
    comps = loop.drain()
    stats = loop.close()
    k = len(levels)
    out: List[StreamResult] = []
    for qi in range(len(items)):
        group = comps[qi * k:(qi + 1) * k]
        votes = [_parse_completion(c) for c in group]
        gen = int(sum(c.gen_len for c in group))
        if policy is not None and qi in policy.decisions:
            dec = dataclasses.replace(policy.decisions[qi], used_tokens=gen)
        else:
            dec = dataclasses.replace(
                voting.decide_no_early_stop(votes, tau), used_tokens=gen)
        out.append(StreamResult(dec, votes, gen))
    return out, stats


# ----------------------------------------------------------------------
# Pre-generation routing (SATER: prompt at tau, route on rejection)
# ----------------------------------------------------------------------

def pregen_outcomes_sater(slm: SLM, items: Sequence[TaskItem], llm, key,
                          thresholds: Sequence[float] = None
                          ) -> Dict[float, List[RouteOutcome]]:
    """One generation per (item, level); threshold tau uses level tau.

    tau = 0.0 keeps everything on the SLM (uses the lowest level's
    response); tau = 1.0-level rejections route.
    """
    thresholds = thresholds or THRESHOLDS
    levels = rcv_schedule()                      # 0.1 .. 1.0
    votes = sample_k(slm, items, levels, key)
    llm_ans = [llm.answer(it) for it in items]
    out: Dict[float, List[RouteOutcome]] = {}
    for tau in thresholds:
        lvl_idx = 0 if tau <= levels[0] else min(
            range(len(levels)), key=lambda i: abs(levels[i] - tau))
        rows = []
        for qi, item in enumerate(items):
            v = votes[qi][lvl_idx]
            routed = v.rejected and tau > 0.0
            correct = (not v.rejected) and is_correct(item, v.text)
            lc, lt = llm_ans[qi]
            rows.append(RouteOutcome(
                routed=routed, slm_correct=correct, slm_engaged=True,
                slm_in_tokens=len(format_prompt(item)),
                slm_out_tokens=v.gen_tokens,
                llm_correct=lc, llm_out_tokens=lt,
                decision_tokens=v.gen_tokens))
        out[tau] = rows
    return out


# ----------------------------------------------------------------------
# Cascade routing
# ----------------------------------------------------------------------

CASCADE_MODES = ("SC", "RCV", "FCV")


def mode_levels(mode: str, k: int) -> List[Optional[float]]:
    """Confidence-level schedule for a cascade mode.

    SC  — no confidence prompts (uniform weights); RCV — levels
    0.1..1.0; FCV — all at 1.0.
    """
    if mode == "SC":
        return [None] * k
    if mode == "RCV":
        return rcv_schedule(k)
    if mode == "FCV":
        return fcv_schedule(k)
    raise ValueError(mode)


def cascade_outcomes(slm: SLM, items: Sequence[TaskItem], llm, key,
                     mode: str = "RCV", k: int = 10,
                     thresholds: Sequence[float] = None,
                     early_stop: Optional[bool] = None
                     ) -> Dict[float, List[RouteOutcome]]:
    """Cascade with K parallel samples and weighted voting.

    mode: SC  — no confidence prompts, uniform weights, no early stop
          RCV — levels 0.1..1.0, early stop
          FCV — all at 1.0, early stop
    """
    thresholds = thresholds or THRESHOLDS
    levels = mode_levels(mode, k)
    early = (mode != "SC") if early_stop is None else early_stop
    votes = sample_k(slm, items, levels, key)
    llm_ans = [llm.answer(it) for it in items]

    out: Dict[float, List[RouteOutcome]] = {}
    for tau in thresholds:
        rows = []
        for qi, item in enumerate(items):
            vs = votes[qi]
            if early:
                dec = voting.decide_with_early_stop(vs, tau)
            else:
                dec = voting.decide_no_early_stop(vs, tau)
            correct = dec.accepted and dec.answer == item.answer
            lc, lt = llm_ans[qi]
            rows.append(RouteOutcome(
                routed=not dec.accepted, slm_correct=correct, slm_engaged=True,
                slm_in_tokens=len(format_prompt(item)),
                slm_out_tokens=dec.used_tokens,
                llm_correct=lc, llm_out_tokens=lt,
                decision_tokens=dec.decision_tokens))
        out[tau] = rows
    return out


def cascade_outcomes_streamed(slm: SLM, items: Sequence[TaskItem], llm, key,
                              mode: str = "RCV", k: int = 10, tau: float = 0.6,
                              early_stop: bool = True):
    """Single-tau cascade where early stopping happens in *compute*:
    decided questions' lanes are killed mid-flight by VoteEarlyStop and
    the freed lanes serve the next pending request.

    Unlike cascade_outcomes (which generates fully and simulates early
    stop per tau), this runs one tau and returns
    (rows, SchedStats) where SchedStats.generated_tokens counts tokens
    the hardware actually decoded.
    """
    results, stats = sample_k_streamed(slm, items, mode_levels(mode, k),
                                       key, tau, early_stop=early_stop)
    llm_ans = [llm.answer(it) for it in items]
    rows = []
    for qi, item in enumerate(items):
        dec = results[qi].decision
        lc, lt = llm_ans[qi]
        rows.append(RouteOutcome(
            routed=not dec.accepted,
            slm_correct=dec.accepted and dec.answer == item.answer,
            slm_engaged=True,
            slm_in_tokens=len(format_prompt(item)),
            slm_out_tokens=dec.used_tokens,
            llm_correct=lc, llm_out_tokens=lt,
            decision_tokens=dec.decision_tokens))
    return rows, stats


# ----------------------------------------------------------------------
# SLM-only endpoint (single unprompted inference) for curve endpoints
# ----------------------------------------------------------------------

def slm_only_endpoint(slm: SLM, items: Sequence[TaskItem], llm, key, cm):
    texts, lens = batch_generate(slm, [format_prompt(it) for it in items], key)
    llm_avg = float(np.mean([llm.answer(it)[1] for it in items]))
    denom = sum(cm.llm_cost(len(format_prompt(it)), llm_avg) for it in items)
    c_s = sum(cm.slm_cost(len(format_prompt(it)), l)
              for it, l in zip(items, lens)) / denom
    p_s = float(np.mean([is_correct(it, t) for it, t in zip(items, texts)]))
    slm_out = [int(l) for l in lens]
    slm_corr = [is_correct(it, t) for it, t in zip(items, texts)]
    return (c_s, p_s), slm_corr, slm_out, texts
