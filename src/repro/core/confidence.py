"""Behavioural confidence extraction (SATER's confidence channel).

SATER never reads logits: a Stage-II model prompted at level p either
answers (asserting confidence >= p) or emits the rejection template.
This keeps the router API-compatible (works through a text interface),
which is why the same trained model serves both routing modes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.data.tasks import extract_answer, is_rejection


@dataclasses.dataclass
class Vote:
    answer: Optional[str]     # None => rejected / unparseable
    confidence: float         # the prompted level p_k
    gen_tokens: int           # output length (latency/cost proxy)
    text: str = ""

    @property
    def rejected(self) -> bool:
        return self.answer is None


def parse_vote(text: str, prompted_level: float, gen_tokens: int) -> Vote:
    if is_rejection(text):
        return Vote(None, prompted_level, gen_tokens, text)
    return Vote(extract_answer(text), prompted_level, gen_tokens, text)


def rcv_schedule(k: int = 10):
    """Ranged Confidence Voting: levels 0.1 .. 1.0."""
    return [round((i + 1) / k, 1) for i in range(k)]


def fcv_schedule(k: int = 10):
    """Fixed Confidence Voting: all at 1.0."""
    return [1.0] * k
