"""Multi-pod dry-run: lower + compile every (arch x input-shape) pair on
the production meshes, with no device allocation (ShapeDtypeStruct).

Shapes:
  train_4k    -> train_step   (full fwd+bwd+AdamW update; sater-slm-8b
                               lowers the SATER DPO LoRA step instead)
  prefill_32k -> prefill_step (last-position logits + cache build)
  decode_32k  -> serve_step   (1 new token against a seq_len cache)
  long_500k   -> serve_step   (batch=1; dense archs use the sliding-
                               window variant, DESIGN.md §4)

Per run we record compiled.memory_analysis(), compiled.cost_analysis(),
and collective bytes parsed from the optimized HLO -- the roofline
inputs (EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh pod --out benchmarks/results
"""

import argparse
import dataclasses
import json
import os
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, ModelConfig, get_config
from repro.distributed import sharding as sh
from repro.launch.analytics import (analytic_bytes, analytic_flops,
                                    collective_bytes_structural)
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.training import lora as lora_lib
from repro.training.optimizer import adamw, cosine_warmup_schedule

SLIDING_FALLBACK_WINDOW = 8192

# archs whose long_500k run uses the sliding-window variant (full
# attention otherwise quadratic/cache-infeasible at 500k)
_NATIVE_SUBQUADRATIC = {"mamba2-1.3b", "hymba-1.5b", "gemma3-1b"}


def shape_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Shape-specific config tweaks (long-context sliding variant)."""
    if shape_name == "long_500k" and cfg.name not in _NATIVE_SUBQUADRATIC \
            and cfg.has_attention:
        cfg = dataclasses.replace(cfg, sliding_window=SLIDING_FALLBACK_WINDOW,
                                  global_every=0)
    return cfg


# ----------------------------------------------------------------------
# Abstract inputs
# ----------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def abstract_params(cfg: ModelConfig, dtype_override=None):
    tree = jax.eval_shape(lambda k: model_lib.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))
    if dtype_override is not None:
        dt = jnp.dtype(dtype_override)
        tree = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, dt if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype),
            tree)
    return tree


def input_specs(cfg: ModelConfig, shape_name: str, mesh, cache_mode: str = "auto"):
    """(abstract args, in_specs) for the step function of this shape."""
    shp = INPUT_SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    tok_spec = sh.tokens_spec(mesh, b)
    bax = tok_spec[0]
    if shp.kind == "train":
        if cfg.embedding_inputs:
            batch = {"embeds": _sds((b, s, cfg.d_model), cfg.compute_dtype),
                     "labels": _sds((b, s), jnp.int32),
                     "loss_mask": _sds((b, s), jnp.int32)}
            specs = {"embeds": P(bax, None, None), "labels": tok_spec,
                     "loss_mask": tok_spec}
        else:
            batch = {"tokens": _sds((b, s), jnp.int32),
                     "loss_mask": _sds((b, s), jnp.int32)}
            specs = {"tokens": tok_spec, "loss_mask": tok_spec}
        return batch, specs
    if shp.kind == "prefill":
        if cfg.embedding_inputs:
            batch = {"embeds": _sds((b, s, cfg.d_model), cfg.compute_dtype),
                     "lengths": _sds((b,), jnp.int32)}
            specs = {"embeds": P(bax, None, None), "lengths": P(bax)}
        else:
            batch = {"tokens": _sds((b, s), jnp.int32),
                     "lengths": _sds((b,), jnp.int32)}
            specs = {"tokens": tok_spec, "lengths": P(bax)}
        return batch, specs
    # decode: one token per lane + cache of seq_len
    cache = jax.eval_shape(
        lambda: model_lib.init_decode_state(cfg, b, s,
                                            jnp.dtype(cfg.compute_dtype)))
    cache_spec = sh.cache_specs(cfg, mesh, b, mode=cache_mode)
    batch = {"tokens": _sds((b,), jnp.int32), "cache": cache}
    specs = {"tokens": P(bax), "cache": cache_spec}
    return batch, specs


# ----------------------------------------------------------------------
# Step builders
# ----------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, gspecs=None, batch_spec0="data"):
    """gspecs (§Perf iteration): pin gradients to the parameter sharding
    so FSDP-sharded weights get reduce-scattered grads instead of
    full-tensor all-reduces.  batch_spec0: mesh axes of the batch dim
    (used to keep microbatch slices data-sharded)."""
    opt = adamw(cosine_warmup_schedule(1e-4, 1000))
    # NOTE: an explicit f32->bf16 whole-tree cast here (mixed-precision
    # "compute copy") was tried and REFUTED: XLA keeps both copies live
    # and temp grew ~3 GB/dev (llama4, pixtral) with no collective win —
    # the per-use astype inside the layers already converts post-gather.
    # See EXPERIMENTS.md §Perf.

    def loss_fn(params, batch):
        if cfg.embedding_inputs:
            logits, aux = model_lib.forward(params, cfg, embeds=batch["embeds"])
            labels, mask = batch["labels"], batch["loss_mask"]
        else:
            logits, aux = model_lib.forward(params, cfg,
                                            tokens=batch["tokens"][:, :-1])
            labels = batch["tokens"][:, 1:]
            mask = batch["loss_mask"][:, 1:]
        loss, metrics = model_lib.lm_loss(cfg, logits, labels, mask, aux)
        return loss, metrics

    def microbatched_loss(params, batch):
        mb = cfg.microbatches
        if mb <= 1:
            return loss_fn(params, batch)

        # checkpoint the microbatch body: without it, scan-based grad
        # accumulation saves every microbatch's residuals simultaneously
        # and the peak is no better than the unsplit batch.
        @jax.checkpoint
        def one(carry, sub):
            loss, metrics = loss_fn(params, sub)
            return carry, (loss, metrics)

        def split(x):
            # Keep the per-microbatch batch dim data-sharded: without the
            # constraint GSPMD tries to shard the (tiny) microbatch axis
            # and falls back to full replication of the batch (101 GB/dev
            # regression on llama3 train_4k — EXPERIMENTS.md §Perf).
            y = x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            spec = P(None, batch_spec0, *([None] * (y.ndim - 2)))
            return jax.lax.with_sharding_constraint(y, spec)

        subs = jax.tree.map(split, batch)
        _, (losses, ms) = jax.lax.scan(one, 0, subs)
        return jnp.mean(losses), jax.tree.map(jnp.mean, ms)

    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            microbatched_loss, has_aux=True)(state["params"], batch)
        if gspecs is not None:
            grads = jax.lax.with_sharding_constraint(grads, gspecs)
        new_params, new_opt = opt.update(grads, state["opt_state"],
                                         state["params"])
        return {"params": new_params, "opt_state": new_opt,
                "step": state["step"] + 1}, dict(metrics, loss=loss)

    return step


def make_dpo_train_step(cfg: ModelConfig, pspecs=None, batch_spec0="data"):
    """SATER Stage-I step (LoRA policy vs base reference) — the
    paper-representative train config (sater-slm-8b x train_4k).

    pspecs pins the merged (base + LoRA) weights to the base sharding
    (§Perf iteration 3 — stops XLA all-gathering merged weights)."""
    from repro.core.dpo import DPOConfig, dpo_loss
    lcfg = lora_lib.LoraConfig()
    opt = adamw(cosine_warmup_schedule(1e-4, 1000))
    dcfg = DPOConfig()

    cdt = jnp.dtype(cfg.compute_dtype)

    def cast(tree):
        # §Perf iteration 4: carry scanned weights in compute dtype so
        # per-layer weight movement/collectives are bf16, not f32
        return jax.tree.map(
            lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p, tree)

    def microbatched(base_c, lt, batch):
        # The base+LoRA merge happens INSIDE the checkpointed microbatch
        # body: the backward scan then carries d(lora) (rank-8 factors,
        # KBs) instead of d(merged_weights) — carrying the latter
        # materialized + all-gathered two full f32 weight stacks
        # (2 x 7.5 GB/dev on sater-slm-8b; EXPERIMENTS.md §Perf).
        def merged_loss(sub):
            merged = lora_lib.merge(base_c, lt, lcfg, spec_tree=pspecs)
            return dpo_loss(merged, base_c, cfg, sub, dcfg)

        mb = cfg.microbatches
        if mb <= 1:
            return merged_loss(batch)

        def split(x):
            y = x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            spec = P(None, batch_spec0, *([None] * (y.ndim - 2)))
            return jax.lax.with_sharding_constraint(y, spec)

        @jax.checkpoint
        def one(carry, sub):
            loss, metrics = merged_loss(sub)
            return carry, (loss, metrics)

        subs = jax.tree.map(split, batch)
        _, (losses, ms) = jax.lax.scan(one, 0, subs)
        return jnp.mean(losses), jax.tree.map(jnp.mean, ms)

    def step(state, batch):
        base_c = cast(state["base"])

        def lf(lt):
            return microbatched(base_c, cast(lt), batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["lora"])
        new_lora, new_opt = opt.update(grads, state["opt_state"], state["lora"])
        return {"base": state["base"], "lora": new_lora,
                "opt_state": new_opt, "step": state["step"] + 1}, \
            dict(metrics, loss=loss)

    return step


def make_prefill_step(cfg: ModelConfig):
    def step(params, batch):
        if cfg.embedding_inputs:
            return model_lib.prefill(params, cfg, embeds=batch["embeds"],
                                     lengths=batch["lengths"], last_only=True)
        return model_lib.prefill(params, cfg, tokens=batch["tokens"],
                                 lengths=batch["lengths"], last_only=True)
    return step


def make_serve_step(cfg: ModelConfig):
    def step(params, batch):
        return model_lib.decode_step(params, cfg, batch["tokens"],
                                     batch["cache"])
    return step


# ----------------------------------------------------------------------
# Runner
# ----------------------------------------------------------------------

def run_one(arch: str, shape_name: str, mesh_kind: str,
            microbatches: int = 0, save_hlo: str = "",
            seq_shard: bool = False, cache_mode: str = "auto",
            moe_shard: bool = False, moe_chunks_override: int = 0,
            kv_quant: bool = False, moe_shard_map: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    cfg = get_config(arch)
    cfg = shape_config(cfg, shape_name)
    shp = INPUT_SHAPES[shape_name]
    # Baseline fit requirements (16 GB HBM / v5e chip; DESIGN.md §5):
    #  * vocab-sharded logits (128k-262k vocabs don't fit unsharded),
    #  * expert-sharded MoE dispatch buffers,
    #  * grad-accumulation microbatches for the 1M-token train step.
    msz = int(mesh.shape["model"])
    n_tok = shp.global_batch * shp.seq_len
    moe_chunks = 1
    if cfg.is_moe and shp.kind in ("train", "prefill") and n_tok > 32768:
        # bound the replicated (T*k, D) dispatch rows to ~32k tokens/chunk
        per_call = n_tok if shp.kind == "prefill" else n_tok // 8
        moe_chunks = max(1, per_call // 32768)
    if moe_chunks_override:
        moe_chunks = moe_chunks_override
    cfg = dataclasses.replace(
        cfg,
        shard_logits_vocab=(cfg.vocab_size % msz == 0),
        shard_moe_dispatch=cfg.is_moe,
        moe_dispatch_chunks=moe_chunks,
        microbatches=(microbatches or
                      ((16 if cfg.param_count() > 3e10 else 8)
                       if shp.kind == "train" else 1)))
    # each microbatch slice must still cover every batch shard: a slice
    # smaller than the (pod x data) batch sharding forces replication
    # (llama4 multipod train regressed to 33.6 GB/dev — §Perf C2 class)
    if shp.kind == "train" and cfg.microbatches > 1:
        shards = 1
        for ax in (("pod", "data") if mesh_kind == "multipod" else ("data",)):
            shards *= int(mesh.shape[ax])
        eff_batch = shp.global_batch // (2 if arch == "sater-slm-8b" else 1)
        cfg = dataclasses.replace(
            cfg, microbatches=max(1, min(cfg.microbatches,
                                         eff_batch // shards)))
    if seq_shard:
        cfg = dataclasses.replace(cfg, seq_shard_activations=True)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if moe_shard_map:
        from repro.models import moe_shard_map as msm
        msm.set_mesh(mesh)
        cfg = dataclasses.replace(cfg, moe_shard_map=True)
    if moe_shard:
        cfg = dataclasses.replace(cfg, shard_moe_dispatch=True)

    # Decode/prefill cache sharding: when kv heads don't divide the model
    # axis, head-dim sharding forces a per-layer cache reshard (the k/v
    # projections are fused-head sharded).  Sequence-sharding the cache
    # (flash-decode) avoids it entirely: -99.9% decode collectives on
    # llama3 (EXPERIMENTS.md §Perf).  kv%msz==0 archs keep plain head TP.
    if cache_mode == "auto" and cfg.has_attention and shp.kind != "train" \
            and cfg.n_kv_heads % msz != 0:
        cache_mode = "seq"
    batch, batch_specs = input_specs(cfg, shape_name, mesh, cache_mode)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "devices": int(len(mesh.devices.flatten())),
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count(),
              "microbatches": cfg.microbatches,
              "seq_shard": seq_shard, "cache_mode": cache_mode}

    if shp.kind == "train":
        if arch == "sater-slm-8b":
            params = abstract_params(cfg)
            lcfg = lora_lib.LoraConfig()
            lora_tree = jax.eval_shape(
                lambda k: lora_lib.init_lora(params, lcfg, k),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            opt = adamw(cosine_warmup_schedule(1e-4, 1000))
            opt_state = jax.eval_shape(opt.init, lora_tree)
            pspecs = sh.param_specs(cfg, params, mesh)
            lspecs = jax.tree.map(lambda l: P(*([None] * l.ndim)), lora_tree)
            state = {"base": params, "lora": lora_tree,
                     "opt_state": opt_state,
                     "step": _sds((), jnp.int32)}
            state_specs = {
                "base": pspecs,
                "lora": lspecs,
                "opt_state": {"mu": lspecs, "nu": lspecs, "step": P()},
                "step": P()}
            # DPO batches: chosen/rejected pairs at half batch (2x forward)
            b, s = shp.global_batch // 2, shp.seq_len
            tok_spec = sh.tokens_spec(mesh, b)
            step = make_dpo_train_step(cfg, batch_spec0=tok_spec[0])
            batch = {k: _sds((b, s), jnp.int32)
                     for k in ("chosen", "chosen_mask", "rejected",
                               "rejected_mask")}
            batch_specs = {k: tok_spec for k in batch}
            result["step_kind"] = "dpo_train"
        else:
            params = abstract_params(cfg)
            opt = adamw(cosine_warmup_schedule(1e-4, 1000))
            opt_state = jax.eval_shape(opt.init, params)
            pspecs = sh.param_specs(cfg, params, mesh)
            state = {"params": params, "opt_state": opt_state,
                     "step": _sds((), jnp.int32)}
            state_specs = {"params": pspecs,
                           "opt_state": sh.opt_state_specs(cfg, params, mesh),
                           "step": P()}
            step = make_train_step(cfg, gspecs=(pspecs if seq_shard else None),
                                   batch_spec0=sh.tokens_spec(mesh, shp.global_batch)[0])
            result["step_kind"] = "train"
        args = (state, batch)
        specs = (state_specs, batch_specs)
        donate = (0,)
    else:
        params = abstract_params(cfg, dtype_override=cfg.compute_dtype)
        pspecs = sh.param_specs(cfg, params, mesh)
        b = shp.global_batch
        bax = sh.tokens_spec(mesh, b)[0]
        if shp.kind == "prefill":
            step = make_prefill_step(cfg)
            result["step_kind"] = "prefill"
            donate = ()
            # out: (last-position logits (B,V), cache) — the cache MUST
            # be head/batch-sharded or it alone is 10-40 GB/dev at 32k.
            out_specs = (P(bax, None),
                         sh.cache_specs(cfg, mesh, b, mode=cache_mode))
        else:
            step = make_serve_step(cfg)
            result["step_kind"] = "serve"
            donate = (1,)          # cache buffers are update-in-place
            out_specs = (P(bax, None),
                         sh.cache_specs(cfg, mesh, b, mode=cache_mode))
        args = (params, batch)
        specs = (pspecs, batch_specs)

    in_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                is_leaf=lambda x: isinstance(x, P))
    out_shardings = None
    if shp.kind in ("prefill", "decode"):
        out_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                     out_specs,
                                     is_leaf=lambda x: isinstance(x, P))

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=in_shardings,
                          out_shardings=out_shardings,
                          donate_argnums=donate).lower(*args)
        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
    cost = compiled.cost_analysis()
    if cost:
        result["hlo_flops"] = float(cost.get("flops", -1))
        result["hlo_bytes"] = float(cost.get("bytes accessed", -1))
        result["hlo_transcendentals"] = float(cost.get("transcendentals", -1))
    hlo = compiled.as_text()
    result["collectives"] = collective_bytes_structural(hlo)
    result["hlo_size"] = len(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    n_tok = shp.global_batch * shp.seq_len
    act = cfg.active_param_count()
    if shp.kind == "train":
        result["model_flops"] = 6 * act * n_tok
    elif shp.kind == "prefill":
        result["model_flops"] = 2 * act * n_tok
    else:
        result["model_flops"] = 2 * act * shp.global_batch
    # analytic global step FLOPs/bytes (HLO cost analysis counts scan
    # bodies once — see launch/analytics.py)
    result["analytic_flops"] = analytic_flops(cfg, shp)
    result["analytic_bytes"] = analytic_bytes(cfg, shp)
    return result


def main():
    # the CLI lowers against 512 simulated host devices; must run before
    # anything initializes the jax backend (argparse below does not).
    # Importing this module stays device-free so tests can use the step
    # builders on whatever mesh the process already has.
    from repro.launch.mesh import ensure_sim_devices
    ensure_sim_devices(512)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--moe-chunks", type=int, default=0)
    ap.add_argument("--kv-quant", action="store_true",
                    help="§Perf: int8 decode kv cache + absmax scales")
    ap.add_argument("--moe-shard-map", action="store_true",
                    help="§Perf: explicit shard_map all-to-all MoE dispatch")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="§Perf: sequence-shard residual activations")
    ap.add_argument("--moe-shard", action="store_true",
                    help="§Perf: expert-shard MoE dispatch buffers")
    ap.add_argument("--cache-mode", default="auto", choices=["auto", "seq"],
                    help="§Perf: decode cache sharding scheme")
    ap.add_argument("--tag", default="",
                    help="suffix for the output JSON (perf experiments)")
    args = ap.parse_args()

    runs = []
    if args.all:
        for arch in ARCH_IDS:
            shapes = list(INPUT_SHAPES)
            if arch == "sater-slm-8b":
                shapes = ["train_4k"]       # paper-representative extra row
            for s in shapes:
                runs.append((arch, s))
    else:
        runs.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in runs:
        tag = f"{arch}__{shape}__{args.mesh}" + \
            (f"__{args.tag}" if args.tag else "")
        path = os.path.join(args.out, f"dryrun_{tag}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            res = run_one(arch, shape, args.mesh,
                          microbatches=args.microbatches,
                          save_hlo=args.save_hlo,
                          seq_shard=args.seq_shard,
                          cache_mode=args.cache_mode,
                          moe_shard=args.moe_shard,
                          moe_chunks_override=args.moe_chunks,
                          kv_quant=args.kv_quant,
                          moe_shard_map=args.moe_shard_map)
            res["ok"] = True
        except Exception as e:  # noqa: BLE001 — record failure, keep sweeping
            res = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"[FAIL] {tag}: {res['error']}", flush=True)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if res.get("ok"):
            print(f"[ok] {tag} compile={res.get('compile_s')}s "
                  f"flops={res.get('hlo_flops', 0):.3e} "
                  f"coll={sum(v for k, v in res['collectives'].items() if not k.startswith('n_')):.3e}B",
                  flush=True)


if __name__ == "__main__":
    main()
