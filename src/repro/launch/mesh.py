"""Production meshes (TPU v5e target) and simulated host meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling them.
:func:`ensure_sim_devices` is the one sanctioned way to request N
simulated host devices (the ``--xla_force_host_platform_device_count``
trick) — call it before anything initializes the jax backend and the
env-var ordering footgun disappears behind one clear error message.

Hardware constants used by the roofline analysis live here too.
"""

from __future__ import annotations

import os

import jax

_SIM_FLAG = "--xla_force_host_platform_device_count"


def _axis_type_kwargs(n) -> dict:
    """Compat shim: jax.sharding.AxisType (explicit-sharding API) exists
    only on newer JAX; older releases take no axis_types kwarg and treat
    every axis as Auto, which is exactly what we want."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Single-device mesh for CPU smoke testing of the pjit code path."""
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_type_kwargs(2))


def ensure_sim_devices(n: int) -> None:
    """Request at least ``n`` simulated host (CPU) devices.

    Extracted from launch/dryrun.py, which proved the trick: setting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
    first backend query makes the CPU client expose N devices, so the
    whole sharded serving path runs (and is CI-gated) without an
    accelerator in sight.  The flag only takes effect if the backend
    has not been initialized yet — the classic footgun is an earlier
    ``jax.devices()`` (or any op) locking the device count at 1.  This
    helper is safe to call any time BEFORE that first touch (merely
    importing jax does not initialize the backend); afterwards it
    raises with an actionable message instead of silently running
    single-device.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    cur = 0
    for tok in flags.split():
        if tok.startswith(_SIM_FLAG + "="):
            cur = int(tok.split("=", 1)[1])
    if cur < n:
        flags = " ".join(t for t in flags.split()
                         if not t.startswith(_SIM_FLAG + "="))
        os.environ["XLA_FLAGS"] = (flags + f" {_SIM_FLAG}={n}").strip()
    if jax.local_device_count() < n:     # initializes the backend (now)
        raise RuntimeError(
            f"need {n} simulated host devices but the jax backend already "
            f"initialized with {jax.local_device_count()}; call "
            "ensure_sim_devices() before the first jax device query "
            "(tests get this from tests/conftest.py)")


def make_sim_mesh(data: int, model: int = 1):
    """``(data, model)`` mesh over the first ``data*model`` host devices.

    The serving loop's sharded mode (serving/scheduler.py) wants a
    deterministic device order — ``jax.devices()[:n]`` — rather than
    whatever ``jax.make_mesh`` picks, so cascade tier placement can
    carve DISJOINT slices out of the same device list (see
    :func:`make_tier_mesh`).  Call :func:`ensure_sim_devices` first
    when running on CPU.
    """
    need = data * model
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"make_sim_mesh({data}, {model}) needs {need} devices but only "
            f"{len(devs)} exist; on CPU call ensure_sim_devices({need}) "
            "before the backend initializes")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devs[:need]).reshape(data, model), ("data", "model"))


def make_tier_mesh(devices):
    """1-wide-model mesh over an explicit device slice — the unit of
    cascade tier placement (core/cascade_multi.py ``placement=``): each
    tier's scheduler decodes under shard_map on exactly these devices,
    so tiers on disjoint slices decode concurrently."""
    import numpy as np
    devices = list(devices)
    if not devices:
        raise ValueError("make_tier_mesh: empty device slice")
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(len(devices), 1), ("data", "model"))


def describe_mesh(mesh) -> str:
    """One-line device banner for launcher startup/summary output:
    axis sizes, device count + platform, and the device ids covered —
    so a serve log always records WHERE it ran (and tier placement
    logs can name their slices).  ``None`` means no mesh: whatever
    single device jax puts arrays on."""
    if mesh is None:
        d = jax.devices()[0]
        return f"single device ({d.platform}:{d.id})"
    axes = " ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
    devs = list(mesh.devices.ravel())
    ids = ",".join(str(d.id) for d in devs)
    return (f"mesh {axes} over {len(devs)} {devs[0].platform} "
            f"device(s) [{ids}]")


def batch_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# TPU v5e per-chip constants (roofline)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
