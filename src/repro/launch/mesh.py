"""Production meshes (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling them.

Hardware constants used by the roofline analysis live here too.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n) -> dict:
    """Compat shim: jax.sharding.AxisType (explicit-sharding API) exists
    only on newer JAX; older releases take no axis_types kwarg and treat
    every axis as Auto, which is exactly what we want."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Single-device mesh for CPU smoke testing of the pjit code path."""
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_type_kwargs(2))


def batch_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# TPU v5e per-chip constants (roofline)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
