"""Asyncio streaming front-end over the split-phase serving loop.

:class:`AsyncServer` wraps a :class:`~repro.serving.scheduler.ServingLoop`
and exposes the interface a token-streaming API server needs:

  * ``submit(uid, tokens, tenant=...)`` registers a request and returns
    an async iterator that yields generated token ids as the loop
    harvests them (via the loop's ``on_tokens`` callback), finishing
    when the request finalizes.  The full
    :class:`~repro.serving.scheduler.Completion` lands in
    ``server.results[uid]``.
  * ``cancel(uid)`` maps a departed client onto ``ServingLoop.release``:
    the lane is freed within one decode round, nothing is delivered,
    and the stream ends immediately.
  * a single driver coroutine owns the loop, alternating decode rounds
    with ``await asyncio.sleep(0)`` so streams and new submissions are
    serviced between rounds — the loop itself is not thread-safe and
    never needs to be, because everything happens on the event loop.

Fair queueing.  Submissions do not go straight to ``ServingLoop.submit``
(whose pending queue is strict FIFO); they wait in a two-class
:class:`FairQueue` and are fed to the loop only as lanes free up, so
admission *order* stays under front-end control.  Requests are classed
as ``ttft`` (interactive: first token latency is the SLO) or
``throughput`` (batch: only aggregate tokens/s matters).  Each admission
cycle grants up to ``ttft_burst`` ttft-class requests, then one
throughput request — a throughput flood cannot starve an interactive
arrival behind its whole backlog, and a ttft flood still leaks
throughput work through.  ``fair=False`` degrades to a single FIFO
queue (the baseline the starvation test measures against).

Preemption composes for free: run the loop with ``auto_preempt=True``
and a cold interactive session's KV pages migrate to host RAM under
pressure instead of pinning the pool (see serving/scheduler.py) —
because resume is bit-exact, the stream's tokens are unaffected.

Device placement is surfaced like ``launch/serve.py`` surfaces it:
``describe()`` returns the startup banner (device mesh + lanes/shard
when the Scheduler serves sharded over a data mesh — log it once
before accepting clients) and ``close()`` returns the final summary
dict carrying that banner alongside rounds driven, requests served,
and the loop's closing stats.
"""

from __future__ import annotations

import asyncio
import collections
from typing import AsyncIterator, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.scheduler import Completion, Request, Scheduler

TTFT = "ttft"
THROUGHPUT = "throughput"

_DONE = object()        # queue sentinel: stream finished (or cancelled)


class FairQueue:
    """Two-class weighted round-robin admission queue.

    ``take(n)`` pops up to ``n`` requests: each cycle grants up to
    ``ttft_burst`` ttft-class requests then one throughput request.
    With ``fair=False`` it is a plain FIFO over arrival order.
    """

    def __init__(self, ttft_burst: int = 2, fair: bool = True):
        if ttft_burst < 1:
            raise ValueError("ttft_burst must be >= 1")
        self.ttft_burst = ttft_burst
        self.fair = fair
        self._seq = 0
        self._q: Dict[str, "collections.deque"] = {
            TTFT: collections.deque(), THROUGHPUT: collections.deque()}

    def __len__(self):
        return len(self._q[TTFT]) + len(self._q[THROUGHPUT])

    def push(self, tenant: str, req: Request) -> None:
        if tenant not in self._q:
            raise ValueError(f"unknown tenant class {tenant!r}")
        self._q[tenant].append((self._seq, req))
        self._seq += 1

    def _pop_fifo(self) -> Request:
        t, th = self._q[TTFT], self._q[THROUGHPUT]
        if t and (not th or t[0][0] < th[0][0]):
            return t.popleft()[1]
        return th.popleft()[1]

    def take(self, n: int) -> List[Request]:
        out: List[Request] = []
        while len(out) < n and len(self):
            if not self.fair:
                out.append(self._pop_fifo())
                continue
            for _ in range(self.ttft_burst):
                if len(out) >= n or not self._q[TTFT]:
                    break
                out.append(self._q[TTFT].popleft()[1])
            if len(out) < n and self._q[THROUGHPUT]:
                out.append(self._q[THROUGHPUT].popleft()[1])
        return out


class _Client:
    __slots__ = ("req", "tenant", "queue", "submit_round", "first_round")

    def __init__(self, req: Request, tenant: str, submit_round: int):
        self.req = req
        self.tenant = tenant
        self.queue: "asyncio.Queue" = asyncio.Queue()
        self.submit_round = submit_round
        self.first_round: Optional[int] = None


class AsyncServer:
    """One event-loop-owned ServingLoop with per-request token streams.

    Usage::

        server = AsyncServer(sched, key)
        await server.start()
        stream = server.submit(uid=0, tokens=prompt_ids, tenant=TTFT)
        async for tok in stream: ...
        comp = server.results[0]
        await server.close()
    """

    def __init__(self, sched: Scheduler, key, stop_policy=None,
                 ttft_burst: int = 2, fair: bool = True):
        self.sched = sched
        self.loop = sched.loop(key, stop_policy=stop_policy)
        self.loop.on_tokens = self._on_tokens
        self.n_lanes = sched.n_lanes
        self.queue = FairQueue(ttft_burst, fair=fair)
        self.results: Dict[int, Completion] = {}
        self.ttft_rounds: Dict[int, int] = {}   # uid -> submit->first-token
        self.rounds = 0
        self._clients: Dict[int, _Client] = {}
        self._cancelled: set = set()
        self._wake = asyncio.Event()
        self._driver: Optional["asyncio.Task"] = None
        self._closing = False

    # -- client API ----------------------------------------------------
    def submit(self, uid: int, tokens: Sequence[int],
               tenant: str = THROUGHPUT, group: Optional[int] = None,
               max_new_tokens: Optional[int] = None,
               ) -> AsyncIterator[int]:
        """Register a request; returns its token stream."""
        if uid in self._clients or uid in self.results:
            raise ValueError(f"uid {uid} already submitted")
        req = Request(uid=uid, tokens=list(tokens), group=group,
                      max_new_tokens=max_new_tokens,
                      meta={"tenant": tenant})
        client = _Client(req, tenant, self.rounds)
        self._clients[uid] = client
        self.queue.push(tenant, req)
        self._wake.set()
        # lazy-start the driver: a stream handed out before start()
        # would otherwise wait forever on a loop nothing drives
        if self._driver is None:
            self._driver = asyncio.ensure_future(self._drive())
        return self._stream(client)

    def cancel(self, uid: int) -> None:
        """Client went away: end its stream now, release its lane at the
        next round boundary.  No completion is recorded."""
        client = self._clients.pop(uid, None)
        if client is None:
            return
        self._cancelled.add(uid)
        client.queue.put_nowait(_DONE)
        self._wake.set()

    def describe(self) -> str:
        """Startup banner line: device mesh plus lane-pool sharding —
        an API server should log this once before accepting clients so
        the serve log records where (and how sharded) it ran."""
        from repro.launch.mesh import describe_mesh
        line = describe_mesh(self.sched.mesh)
        if self.sched.mesh is not None:
            line += (f"; lane pool sharded data={self.sched.n_shards} "
                     f"({self.sched.lanes_per_shard} lanes/shard)")
        return line

    async def close(self) -> dict:
        """Stop the driver after the current round and close the loop
        (callers should drain their streams first).  Returns the final
        summary: the device/mesh banner, rounds driven, requests
        served, and the loop's closing :class:`ServeStats`."""
        self._closing = True
        self._wake.set()
        if self._driver is not None:
            await self._driver
            self._driver = None
        stats = self.loop.close()
        return {"devices": self.describe(), "rounds": self.rounds,
                "served": len(self.results), "stats": stats}

    # -- the driver coroutine ------------------------------------------
    async def start(self) -> None:
        """Start the driver eagerly (optional — the first ``submit``
        lazy-starts it; this just fronts the jit warm-up)."""
        if self._driver is None:
            self._driver = asyncio.ensure_future(self._drive())

    async def _drive(self) -> None:
        loop = self.loop
        while not self._closing:
            if not (loop.has_work or len(self.queue) or self._cancelled):
                self._wake.clear()
                if self._closing:
                    break
                await self._wake.wait()
                continue
            if self._cancelled:
                gone, self._cancelled = self._cancelled, set()
                loop.release(gone)
            # feed the loop only what it can admit this round, so
            # admission order stays with the FairQueue rather than the
            # loop's FIFO pending queue
            free = sum(1 for lane in loop.lanes if lane is None)
            want = max(0, free - len(loop.pending))
            if want:
                batch = [r for r in self.queue.take(want)
                         if r.uid in self._clients]
                if batch:
                    loop.submit(batch)
            if loop.has_work:
                done = loop.step()
                self.rounds += 1
                for comp in done:
                    self._finish(comp)
                loop.release([c.uid for c in done])  # results dict owns them
            # yield so streams drain and new submissions land
            await asyncio.sleep(0)

    # -- loop callbacks ------------------------------------------------
    def _on_tokens(self, uid: int, toks: np.ndarray) -> None:
        client = self._clients.get(uid)
        if client is None:
            return
        if client.first_round is None:
            client.first_round = self.rounds
            self.ttft_rounds[uid] = self.rounds - client.submit_round
        client.queue.put_nowait(np.array(toks, np.int32))

    def _finish(self, comp: Completion) -> None:
        client = self._clients.pop(comp.uid, None)
        if client is None:
            return                       # cancelled while in flight
        self.results[comp.uid] = comp
        client.queue.put_nowait(_DONE)

    async def _stream(self, client: _Client) -> AsyncIterator[int]:
        while True:
            item = await client.queue.get()
            if item is _DONE:
                return
            for tok in item:
                yield int(tok)
