"""Analytic FLOP/byte models + HLO collective accounting with while-loop
trip-count multipliers.

Why analytic: XLA's HLO cost analysis counts a while-loop *body once*
(scan-over-layers => ~1/L of real FLOPs).  We therefore (1) parse the
optimized HLO and multiply collective bytes by the enclosing loops' trip
counts (structural, from the compiled artifact), and (2) compute the
compute/memory roofline terms from an explicit per-component FLOP/byte
model of the lowered step, cross-checked against the raw HLO numbers
(recorded alongside).
"""

from __future__ import annotations

import re
from typing import Dict

from repro.configs.base import ModelConfig, InputShape


# ----------------------------------------------------------------------
# Analytic FLOPs (global, whole step)
# ----------------------------------------------------------------------

def _layer_matmul_flops_per_token(cfg: ModelConfig) -> float:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    f = 0.0
    if cfg.has_attention:
        f += 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh    # qkv proj
        f += 2 * cfg.n_heads * dh * d                            # o proj
    if cfg.has_ssm:
        di = cfg.d_inner
        n, h = cfg.ssm_state, cfg.n_ssm_heads
        proj_out = 2 * di + 2 * n + h
        f += 2 * d * proj_out + 2 * di * d                       # in/out proj
        q = cfg.ssm_chunk
        p = cfg.ssm_head_dim
        # SSD per token: scores 2*q*n, y_intra 2*q*p, states 2*n*p, y_inter 2*n*p
        f += 2 * h * (q * (n + p) + 2 * n * p)
        f += 2 * cfg.ssm_conv_width * (di + 2 * n)               # conv
    if cfg.is_moe:
        mult = 3 if cfg.mlp_gated else 2
        f += cfg.moe_top_k * mult * 2 * d * cfg.moe_d_ff
        f += 2 * d * cfg.n_experts                               # router
        if cfg.moe_shared_expert:
            f += mult * 2 * d * cfg.d_ff
    elif cfg.d_ff:
        mult = 3 if cfg.mlp_gated else 2
        f += mult * 2 * d * cfg.d_ff
    return f


def _attn_context_flops_per_token(cfg: ModelConfig, ctx: float) -> float:
    """qk^T + pv against an average context of ``ctx`` positions."""
    if not cfg.has_attention:
        return 0.0
    return 2 * 2 * cfg.n_heads * cfg.resolved_head_dim * ctx


def _avg_context(cfg: ModelConfig, s: int, decode: bool) -> float:
    windows = cfg.layer_windows()
    ctxs = []
    for w in windows:
        full = float(s) if decode else s / 2.0
        ctxs.append(min(float(w), full) if w > 0 else full)
    return sum(ctxs) / max(len(ctxs), 1)


def analytic_flops(cfg: ModelConfig, shp: InputShape) -> float:
    b, s = shp.global_batch, shp.seq_len
    per_tok_layer = _layer_matmul_flops_per_token(cfg)
    head = 2 * cfg.d_model * cfg.vocab_size
    if shp.kind == "train":
        n_tok = b * s
        ctx = _avg_context(cfg, s, decode=False)
        layer_f = (per_tok_layer + _attn_context_flops_per_token(cfg, ctx)) \
            * n_tok * cfg.n_layers
        # fwd + bwd (2x fwd) + remat fwd
        mult = 4.0 if cfg.remat else 3.0
        return layer_f * mult + head * n_tok * 3.0 * 2  # head fwd+bwd, tied embed grad
    if shp.kind == "prefill":
        n_tok = b * s
        ctx = _avg_context(cfg, s, decode=False)
        layer_f = (per_tok_layer + _attn_context_flops_per_token(cfg, ctx)) \
            * n_tok * cfg.n_layers
        return layer_f + head * b                        # last_only head
    # decode: 1 token/lane against a seq_len cache
    ctx = _avg_context(cfg, s, decode=True)
    layer_f = (per_tok_layer + _attn_context_flops_per_token(cfg, ctx)) \
        * b * cfg.n_layers
    return layer_f + head * b


# ----------------------------------------------------------------------
# Analytic HBM bytes (global, whole step)
# ----------------------------------------------------------------------

def _param_bytes(cfg: ModelConfig, dtype_bytes: int) -> float:
    return cfg.param_count() * dtype_bytes


def analytic_bytes(cfg: ModelConfig, shp: InputShape) -> float:
    """HBM traffic model: weight streams + activations + cache/states.

    Train: weights read fwd+bwd(+remat fwd) in compute dtype, grads
    written+read, f32 master params+moments read+written (AdamW), layer
    activations written+read once (remat saves the rest).
    Decode: weights once, KV cache read+append, activations negligible.
    """
    b, s = shp.global_batch, shp.seq_len
    d = cfg.d_model
    cdt = 2 if cfg.compute_dtype == "bfloat16" else 4
    if shp.kind == "train":
        pb = _param_bytes(cfg, 4)                       # f32 params
        reads = pb * (3 if cfg.remat else 2)            # fwd+bwd(+remat)
        grads = pb * 2                                  # write + read
        adam = pb * 2 * 2 + pb * 2                      # mu/nu rw + param write
        acts = b * s * d * cdt * cfg.n_layers * 2       # saved layer inputs rw
        logits = b * s * cfg.vocab_size * cdt * 2
        return reads + grads + adam + acts + logits
    if shp.kind == "prefill":
        pb = _param_bytes(cfg, cdt)
        acts = b * s * d * cdt * cfg.n_layers * 2
        cache = _cache_bytes(cfg, b, s, cdt)
        return pb + acts + cache
    # decode
    pb = _param_bytes(cfg, cdt)
    cache = _cache_bytes(cfg, b, s, cdt)                # read full cache
    return pb + cache


def _cache_bytes(cfg: ModelConfig, b: int, s: int, cdt: int) -> float:
    total = 0.0
    if cfg.has_attention:
        windows = cfg.layer_windows()
        # int8 kv cache: 1 byte per element + a 4-byte scale per head-slot
        kv_b = (1 + 4.0 / cfg.resolved_head_dim) if cfg.kv_quant else cdt
        for w in windows:
            sc = min(w, s) if w > 0 else s
            total += 2 * b * sc * cfg.n_kv_heads * cfg.resolved_head_dim * kv_b
    if cfg.has_ssm:
        total += cfg.n_layers * b * cfg.n_ssm_heads * cfg.ssm_head_dim * \
            cfg.ssm_state * 4
    return total


# ----------------------------------------------------------------------
# HLO collective accounting with loop multipliers
# ----------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")


def _type_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for dim in dims.split(","):
            if dim:
                n *= int(dim)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if raw.startswith("ENTRY") or line.startswith("ENTRY"):
                    entry = cur
        else:
            if line == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def collective_bytes_structural(hlo: str) -> dict:
    """Per-device collective bytes with while-loop trip multipliers."""
    comps, entry = _split_computations(hlo)

    # while info: body -> (cond, owner unknown); trip from condition consts
    trip_of_body: Dict[str, int] = {}
    children: Dict[str, list] = {c: [] for c in comps}
    for name, lines in comps.items():
        for line in lines:
            bm = _BODY_RE.search(line)
            cm = _COND_RE.search(line)
            if bm:
                body = bm.group(1)
                trip = 1
                if cm and cm.group(1) in comps:
                    consts = [int(x) for ln in comps[cm.group(1)]
                              for x in _CONST_RE.findall(ln)]
                    consts = [c for c in consts if 2 <= c <= 10**7]
                    if consts:
                        trip = max(consts)
                children[name].append((body, trip))
                if cm:
                    children[name].append((cm.group(1), trip))
            for call in _CALL_RE.findall(line):
                if call in comps:
                    children[name].append((call, 1))

    # propagate multipliers from entry
    mult: Dict[str, int] = {}

    def visit(name, m):
        if name not in comps:
            return
        mult[name] = max(mult.get(name, 0), m)
        for child, t in children.get(name, []):
            if child != name:
                visit(child, m * t)

    if entry:
        visit(entry, 1)
    else:
        for c in comps:
            mult.setdefault(c, 1)

    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    op_re = re.compile(
        r"=\s*\(?([a-z0-9\[\]\{\}, ]+)\)?\s+([a-z0-9-]+)\(", re.I)
    for name, lines in comps.items():
        m = mult.get(name, 1)
        for line in lines:
            om = op_re.search(line)
            if not om:
                continue
            op = om.group(2)
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                out[base] += _type_bytes(om.group(1)) * m
                counts[base] += m
    total = sum(out.values())
    return {**out, **{f"n_{k}": v for k, v in counts.items()},
            "total": total}
