"""Distributed training launcher.

On real hardware this runs the pjit'd train step on the production mesh;
in this container it runs the same code path on the host mesh (1 CPU
device) with a reduced config — proving the launcher end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 5 --batch 2 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, smoke_variant
from repro.distributed import sharding as sh
from repro.launch.dryrun import make_train_step
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.training.optimizer import adamw, cosine_warmup_schedule
from repro.models import model as model_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host mesh (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    opt = adamw(cosine_warmup_schedule(args.lr, args.steps))
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.int32(0)}
    step_fn = make_train_step(cfg)

    pspecs = sh.param_specs(cfg, params, mesh)
    state_specs = {"params": pspecs,
                   "opt_state": sh.opt_state_specs(cfg, params, mesh),
                   "step": P()}
    bspec = sh.tokens_spec(mesh, args.batch)
    with mesh:
        jstep = jax.jit(step_fn,
                        in_shardings=(sh.named(mesh, state_specs),
                                      {"tokens": NamedSharding(mesh, bspec),
                                       "loss_mask": NamedSharding(mesh, bspec)}),
                        donate_argnums=(0,))
        rng = np.random.RandomState(0)
        t0 = time.time()
        for i in range(args.steps):
            batch = {
                "tokens": jnp.asarray(rng.randint(
                    0, cfg.vocab_size, (args.batch, args.seq)), jnp.int32),
                "loss_mask": jnp.ones((args.batch, args.seq), jnp.int32),
            }
            state, metrics = jstep(state, batch)
            print(f"step {i} loss={float(metrics['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    print("done")


if __name__ == "__main__":
    main()
