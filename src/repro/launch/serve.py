"""Distributed serving launcher: pjit'd prefill + decode steps on the
production mesh (or host mesh with --smoke), driving batched requests
through the generation engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 4 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as model_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh()

    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    b, s = args.requests, args.prompt_len
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)
    lengths = jnp.full((b,), s, jnp.int32)

    with mesh:
        t0 = time.time()
        last, cache = jax.jit(
            lambda p, t, l: model_lib.prefill(
                p, cfg, tokens=t, lengths=l,
                max_len=s + args.new_tokens, last_only=True)
        )(params, prompts, lengths)
        print(f"prefill {b}x{s} in {time.time()-t0:.2f}s")

        decode = jax.jit(lambda p, t, c: model_lib.decode_step(p, cfg, t, c))
        tok = jnp.argmax(last, -1).astype(jnp.int32)
        t0 = time.time()
        out = [tok]
        for _ in range(args.new_tokens - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"decoded {args.new_tokens} tokens x {b} lanes in {dt:.2f}s "
              f"({1000*dt/args.new_tokens:.1f} ms/tok)")
        print("sample lane 0 tokens:", [int(t[0]) for t in out][:16])


if __name__ == "__main__":
    main()
