"""Distributed serving launcher: the streaming serving loop on the
production mesh (or host mesh with --smoke).

Requests *arrive over time* (Poisson arrivals at --arrival-rate req/s;
0 = the whole backlog at t=0) and are submitted to a
:class:`~repro.serving.scheduler.ServingLoop` mid-flight: the loop
admits them into free/evicted lanes between decode rounds, so a
request that lands while earlier ones are decoding starts on the next
round instead of waiting for a batch boundary.  All jitted steps
(bucketed prefill, round decode, lane insert) lower under the mesh
context, keeping the pjit path exercised.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 8 --lanes 4 --new-tokens 16 --round-tokens 8 \
      --arrival-rate 4

With ``--sim-devices N`` (requires ``--smoke``), the lane pool and the
paged KV pool are split into N per-device shards over a simulated host
mesh (``launch/mesh.ensure_sim_devices``) and decode rounds run under
shard_map — the CPU-only way to drive the multi-device serving path
end to end.  The startup banner and the final summary report the mesh
shape, device ids, lanes per shard, and per-shard pool peaks, so a
serve log always records where (and how sharded) it ran.

The summary reports per-request latency — time-to-first-token and
time-to-decision (submit -> finalize) mean/p50/p95 — alongside the
aggregate throughput numbers, because under streaming arrivals the
aggregate wall-clock alone says nothing about what any one request
experienced.

With ``--paged --share-prefix``, each request becomes a K-lane vote
group (K = --group-size): the group's prompt is prefilled once, its
blocks are refcount-shared across all K block tables with
copy-on-write on the last partial block, and the serve summary reports
the pool/refcount counters (shared lanes, CoW clones, prefix-cache
hits, end-of-run pool state).

With ``--chunk-size`` (optionally ``--prefill-budget``), prompts are
chunk-prefilled interleaved with decode rounds instead of whole per
admission — a long prompt landing mid-stream no longer stalls every
live lane for its full prefill, which is exactly the ttft-tail effect
the ``--arrival-rate`` summary makes visible.

With ``--paged --preempt`` (optionally ``--pool-blocks`` to force
pressure), the loop preempts the coldest lane to host RAM instead of
blocking admission when the device pool runs dry: the lane's KV blocks
are offloaded block-granular, the lane is handed to the waiting
request, and the parked request resumes bit-identically once blocks
free up.  The summary reports the offload churn (lanes parked/resumed,
host-pool peak, bytes copied) so pool-pressure behaviour is visible
from the launcher.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.mesh import (describe_mesh, ensure_sim_devices,
                               make_host_mesh, make_production_mesh,
                               make_sim_mesh)
from repro.models import model as model_lib
from repro.serving.batch import GenConfig
from repro.serving.scheduler import Request, RequestGroup, Scheduler


def _pct(xs, q):
    return float(np.percentile(xs, q)) if len(xs) else 0.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--round-tokens", type=int, default=8)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson request arrivals per second; 0 submits "
                         "the whole backlog at t=0 (replay mode)")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the block-paged KV cache")
    ap.add_argument("--block-size", type=int, default=32,
                    help="cache slots per block with --paged")
    ap.add_argument("--share-prefix", action="store_true",
                    help="with --paged: group requests into K-lane vote "
                         "groups, prefill each group once and share its "
                         "prompt blocks (refcount + copy-on-write)")
    ap.add_argument("--group-size", type=int, default=4,
                    help="lanes per vote group with --share-prefix")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="chunked prefill: append prompts onto the cache "
                         "this many tokens at a time, interleaved with "
                         "decode rounds (admission never stalls the loop)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="with --chunk-size: chunk-capacity tokens each "
                         "round may spend on prompt processing "
                         "(default: finish every queued prompt per round)")
    ap.add_argument("--state-slots", type=int, default=None,
                    help="with --paged on an SSM/hybrid arch: cap the "
                         "recurrent-state slot pool (default: one slot "
                         "per lane); a smaller cap forces admission "
                         "backpressure on the state axis")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="with --paged: cap the device block pool (default "
                         "sizes it so every lane can run to budget; a "
                         "smaller cap forces admission pressure)")
    ap.add_argument("--preempt", action="store_true",
                    help="with --paged: under pool pressure, offload the "
                         "coldest lane's KV blocks to host RAM and hand "
                         "its lane to the waiting request; the parked "
                         "request resumes bit-identically when blocks free")
    ap.add_argument("--sim-devices", type=int, default=None,
                    help="with --smoke: serve sharded over this many "
                         "simulated host devices — lanes and KV pools "
                         "split per-shard, decode rounds under shard_map "
                         "(must divide --lanes, >= 2 lanes per shard)")
    args = ap.parse_args()
    if args.share_prefix and not args.paged:
        ap.error("--share-prefix requires --paged")
    if args.prefill_budget is not None and args.chunk_size is None:
        ap.error("--prefill-budget requires --chunk-size")
    if (args.preempt or args.pool_blocks is not None) and not args.paged:
        ap.error("--preempt/--pool-blocks require --paged")
    if args.state_slots is not None and not args.paged:
        ap.error("--state-slots requires --paged")
    if args.sim_devices is not None and not args.smoke:
        ap.error("--sim-devices requires --smoke (the production mesh "
                 "shards the model axis, not the lane pool)")
    if args.sim_devices is not None:
        # must land before anything touches the jax backend
        ensure_sim_devices(args.sim_devices)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        mesh = (make_sim_mesh(args.sim_devices)
                if args.sim_devices is not None else make_host_mesh())
    else:
        mesh = make_production_mesh()

    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    rng = np.random.RandomState(0)
    # pre-tokenized random prompts with ragged lengths to exercise the
    # prompt-length buckets (no tokenizer needed at this layer)
    reqs = [Request(uid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (
                        rng.randint(args.prompt_len // 2,
                                    args.prompt_len + 1),)).tolist())
            for i in range(args.requests)]
    if args.share_prefix:
        # K-vote sampling shape: every group is one prompt fanned out to
        # --group-size lanes — the scheduler prefills it once and maps
        # its prompt blocks read-only into every lane
        reqs = [RequestGroup([
            Request(uid=g.uid * args.group_size + j, tokens=g.tokens,
                    group=g.uid) for j in range(args.group_size)])
            for g in reqs]
    # Poisson process: exponential inter-arrival gaps at the given rate
    if args.arrival_rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                             len(reqs)))
    else:
        arrivals = np.zeros(len(reqs))
    gcfg = GenConfig(max_new_tokens=args.new_tokens, temperature=0.0,
                     eos_id=-1)     # greedy, run every request to budget
    sched = Scheduler(params, cfg, tokenizer=None, gcfg=gcfg,
                      n_lanes=args.lanes, round_tokens=args.round_tokens,
                      max_prompt_len=args.prompt_len, paged=args.paged,
                      block_size=args.block_size,
                      share_prefix=args.share_prefix,
                      chunk_size=args.chunk_size,
                      prefill_budget=args.prefill_budget,
                      pool_blocks=args.pool_blocks,
                      state_slots=args.state_slots,
                      auto_preempt=args.preempt,
                      mesh=mesh if args.sim_devices is not None else None)

    print(f"devices: {describe_mesh(mesh)}")
    if sched.mesh is not None:
        print(f"  lane pool sharded data={sched.n_shards}: "
              f"{sched.lanes_per_shard} lanes/shard"
              + (f", {sched.pool_blocks} pool blocks/shard"
                 if args.paged else ""))

    comps = []
    with mesh:
        loop = sched.loop(key)
        t0 = time.time()
        nxt = 0
        while nxt < len(reqs) or loop.has_work:
            now = time.time() - t0
            while nxt < len(reqs) and arrivals[nxt] <= now:
                loop.submit([reqs[nxt]])     # mid-flight admission
                nxt += 1
            if loop.has_work:
                done = loop.step()
                comps.extend(done)
                # bounded streaming: the loop drops delivered records,
                # so session memory tracks the lane pool, not the total
                # requests served
                loop.release(c.uid for c in done)
            elif nxt < len(reqs):
                # idle until the next arrival is due
                time.sleep(min(arrivals[nxt] - now, 0.05))
        dt = time.time() - t0
        stats = loop.close()

    tok_total = sum(c.gen_len for c in comps)
    ttft = [c.ttft_s for c in comps if c.ttft_s is not None]
    ttd = [c.ttd_s for c in comps if c.ttd_s is not None]
    print(f"served {len(comps)} requests over {args.lanes} lanes in {dt:.2f}s"
          + (f" (Poisson {args.arrival_rate:.1f} req/s, last arrival "
             f"{arrivals[-1]:.2f}s)" if args.arrival_rate > 0 and len(reqs)
             else ""))
    print(f"  rounds={stats.rounds} prefills={stats.prefills} "
          f"(prompts={stats.prefill_prompts}, "
          f"tokens={stats.prefill_tokens}) "
          f"generated={stats.generated_tokens} tokens"
          + (f", prefill chunks={stats.prefill_chunks}"
             if args.chunk_size else ""))
    print(f"  {tok_total} tokens total, "
          f"{1000 * dt / max(tok_total, 1):.1f} ms/tok, "
          f"lane occupancy {stats.lane_rounds / max(stats.rounds * args.lanes, 1):.0%}")
    print(f"  per-request latency: "
          f"ttft mean {np.mean(ttft) * 1e3 if ttft else 0:.0f}ms "
          f"p50 {_pct(ttft, 50) * 1e3:.0f}ms p95 {_pct(ttft, 95) * 1e3:.0f}ms"
          f" | time-to-decision mean {np.mean(ttd) * 1e3 if ttd else 0:.0f}ms"
          f" p50 {_pct(ttd, 50) * 1e3:.0f}ms p95 {_pct(ttd, 95) * 1e3:.0f}ms")
    if sched.mesh is not None:
        print(f"  {describe_mesh(mesh)}: {sched.n_shards} lane-pool "
              f"shard(s) x {sched.lanes_per_shard} lanes")
    if args.paged:
        # a pure-SSM arch pages recurrent state, not KV blocks: it has
        # state-slot pools but no BlockPool, so the KV lines are skipped
        pools = [p for p in (sched.pools or [sched.pool]) if p is not None]
        if pools:
            print(f"  paged cache: peak {stats.peak_blocks_in_use}/"
                  f"{stats.pool_blocks} blocks "
                  f"({stats.peak_cache_bytes / 2**20:.2f} MiB vs dense "
                  f"{stats.dense_cache_bytes / 2**20:.2f} MiB), "
                  f"admission blocked {stats.admission_blocked}x, "
                  f"peak reserved {max(p.peak_reserved for p in pools)}")
        else:
            print(f"  admission blocked {stats.admission_blocked}x "
                  f"(state-slot backpressure)")
        if len(pools) > 1:
            print("  per-shard peaks: " + ", ".join(
                f"s{i}={p.peak_in_use}/{sched.pool_blocks}"
                for i, p in enumerate(pools)))
        if stats.state_slots:
            print(f"  state slots: peak {stats.peak_state_slots}/"
                  f"{stats.state_slots} "
                  f"({stats.peak_state_bytes / 2**20:.2f} MiB at "
                  f"{stats.state_slot_bytes / 2**20:.2f} MiB/slot)")
        # loop.close() runs BlockPool.leak_report(): any block still
        # held or reserved after the last lane drained is a serving bug
        print("  pool leak check: "
              + (stats.leak_report if stats.leak_report
                 else "clean (every block returned)"))
    if args.preempt:
        print(f"  preemption: {stats.preempts} lanes parked, "
              f"{stats.resumes} resumed, host pool peak "
              f"{stats.host_blocks_peak} blocks, "
              f"{stats.offload_bytes / 2**20:.2f} MiB KV offloaded")
    if args.share_prefix:
        pools = sched.pools or [sched.pool]
        print(f"  prefix sharing: {stats.shared_lanes} lanes rode a "
              f"shared prefill, {stats.cow_copies} CoW block clones, "
              f"prefix cache {stats.prefix_hits} hits "
              f"({stats.prefix_hit_blocks} blocks reused); "
              f"pool holds registered {sum(p.shared_holds for p in pools)}, "
              f"end state in_use={sum(p.in_use for p in pools)} "
              f"reserved={sum(p.reserved for p in pools)}")
    if comps:
        first = min(comps, key=lambda c: c.uid)
        print(f"sample request {first.uid} tokens:",
              first.tokens[:16].tolist())


if __name__ == "__main__":
    main()
