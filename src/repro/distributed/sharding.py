"""PartitionSpec rules for every architecture family.

Conventions (DESIGN.md §5):
  * weights: attention heads / FFN hidden / vocab on ``model``;
    MoE expert dim on ``model``; huge MoE stacks (llama4-scout, ~109B
    total) additionally FSDP-shard the expert d_model dim over ``data``.
  * batch over ("pod","data"); long_500k (batch=1) shards the KV-cache
    sequence axis over ``data`` instead (context-parallel decode).
  * optimizer moments: ZeRO-style — the first replicated, divisible dim
    of each moment leaf is sharded over ``data``.

jit input shardings must divide exactly, so every rule checks
divisibility against the mesh axis size and falls back to the next
candidate dim (e.g. mamba2's 50280 vocab is not 16-divisible -> the
embedding shards d_model instead).
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes, data_axis_size, model_axis_size

FSDP_PARAM_THRESHOLD = 3e10     # params above this get expert-dim FSDP


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", str(last))


def _in_module(path, *names) -> bool:
    keys = {getattr(p, "key", None) for p in path}
    return any(n in keys for n in names)


def _spec_with(nd: int, assignments: dict) -> P:
    parts = [None] * nd
    for dim, axis in assignments.items():
        parts[dim] = axis
    return P(*parts)


def param_spec(cfg: ModelConfig, path, leaf, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (leading dim may be layers)."""
    name = _leaf_name(path)
    fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD
    nd = leaf.ndim
    shape = leaf.shape
    msz = mesh.shape["model"]
    dsz = mesh.shape["data"]

    def div(dim, sz=msz):
        return shape[dim] % sz == 0

    if name == "embedding":                      # (V, D)
        if div(0):
            return P("model", None)
        if div(1):
            return P(None, "model")
        return P(None, None)
    if name == "lm_head":                        # (D, V)
        if div(1):
            return P(None, "model")
        if div(0):
            return P("model", None)
        return P(None, None)
    if _in_module(path, "moe") and name != "router":
        if _in_module(path, "shared"):           # (L, D, F) / (L, F, D)
            if name in ("wi_gate", "wi_up", "wi"):
                return _spec_with(nd, {nd - 1: "model"} if div(nd - 1) else {})
            return _spec_with(nd, {nd - 2: "model"} if div(nd - 2) else {})
        if nd == 4:                              # (L, E, D, F) / (L, E, F, D)
            a = {}
            if div(1):
                a[1] = "model"
            if fsdp and shape[2] % dsz == 0:
                a[2] = "data"
            # multipod: FSDP-scale expert weights also shard the last
            # dim over 'pod' (llama4 multipod: 25.2 -> fits; §Perf C8)
            if fsdp and "pod" in mesh.shape and                     shape[3] % mesh.shape["pod"] == 0:
                a[3] = "pod"
            return _spec_with(nd, a)
        return P(*([None] * nd))
    if name in ("wq", "wk", "wv", "wi_gate", "wi_up", "wi", "in_proj"):
        if div(nd - 1):
            return _spec_with(nd, {nd - 1: "model"})   # shard output dim
        return P(*([None] * nd))
    if name in ("wo", "out_proj"):
        if div(nd - 2):
            return _spec_with(nd, {nd - 2: "model"})   # shard input dim
        return P(*([None] * nd))
    if name == "conv_w" and div(nd - 1):         # (L, W, 1, Cc)
        return _spec_with(nd, {nd - 1: "model"})
    if name in ("conv_b", "norm_scale") and _in_module(path, "ssm") \
            and div(nd - 1):
        return _spec_with(nd, {nd - 1: "model"})
    return P(*([None] * nd))                     # norms, router, A_log, ...


def param_specs(cfg: ModelConfig, params_tree, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(cfg, path, leaf, mesh), params_tree)


def zero_spec(spec: P, shape, data_size: int) -> P:
    """ZeRO the first replicated dim that the data axis divides (no-op if
    the spec already consumes the data axis, e.g. FSDP expert weights)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for p in parts:
        if p is None:
            continue
        used.update((p,) if isinstance(p, str) else p)
    if "data" in used:
        return P(*parts)
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % data_size == 0 and s >= data_size:
            parts[i] = "data"
            return P(*parts)
    return P(*parts)


def opt_state_specs(cfg: ModelConfig, params_tree, mesh: Mesh):
    """Moments: param spec + ZeRO over data; step: replicated."""
    dsz = mesh.shape["data"]
    mom = jax.tree_util.tree_map_with_path(
        lambda path, leaf: zero_spec(param_spec(cfg, path, leaf, mesh),
                                     leaf.shape, dsz),
        params_tree)
    return {"mu": mom, "nu": mom, "step": P()}


# ----------------------------------------------------------------------
# Activations / inputs / cache
# ----------------------------------------------------------------------

def tokens_spec(mesh: Mesh, batch: int) -> P:
    ax = batch_axes(mesh)
    if batch % data_axis_size(mesh) == 0:
        return P(ax, None)
    return P(None, None)


def _kv_axes(cfg: ModelConfig, mesh: Mesh):
    """(kv_head_axis, head_dim_axis) for cache sharding (divisible only)."""
    m = model_axis_size(mesh)
    if cfg.n_kv_heads and cfg.n_kv_heads % m == 0:
        return "model", None
    if cfg.resolved_head_dim % m == 0:
        return None, "model"
    return None, None


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq_len: int = 0,
                mode: str = "auto"):
    """Spec tree matching model.init_decode_state's structure.

    mode="auto": shard kv-heads (or head_dim) over 'model' — plain TP.
    mode="seq" (§Perf lever): shard the cache SEQUENCE axis over 'model'
    (flash-decode style): per-shard partial attention + tiny stat
    collectives instead of all-gathering scores/cache.
    """
    shard_batch = batch % data_axis_size(mesh) == 0
    bax = batch_axes(mesh) if shard_batch else None
    # context-parallel at batch=1: shard the cache sequence axis instead
    seq_ax = None if shard_batch else "data"
    spec = {"pos": P(bax if shard_batch else None)}
    if cfg.has_attention:
        if mode == "seq":
            kv_ax, dh_ax = None, None
            seq_ax = "model" if shard_batch else ("data", "model")
        else:
            kv_ax, dh_ax = _kv_axes(cfg, mesh)
        spec["k"] = P(None, bax, seq_ax, kv_ax, dh_ax)
        spec["v"] = P(None, bax, seq_ax, kv_ax, dh_ax)
        if cfg.kv_quant:
            spec["k_scale"] = P(None, bax, seq_ax, kv_ax)
            spec["v_scale"] = P(None, bax, seq_ax, kv_ax)
        spec["cache_pos"] = P(bax, seq_ax)
    if cfg.has_ssm:
        msz = model_axis_size(mesh)
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        conv_ax = "model" if conv_ch % msz == 0 else None
        h_ax = "model" if cfg.n_ssm_heads % msz == 0 else None
        spec["conv"] = P(None, bax, None, conv_ax)
        spec["ssm"] = P(None, bax, h_ax, None, None)
    return spec


def serving_cache_specs(cache) -> dict:
    """PartitionSpec dict for a SERVING-loop decode cache (the dicts
    built by model.init_decode_state / init_paged_decode_state), lane-
    (data-)parallel for the sharded serving path (serving/scheduler.py).

    Dense caches shard the lane axis: ``pos``/``cache_pos`` lead with
    it, every layer-stacked leaf (k, v, scales, conv, ssm) carries it
    second (axis 0 is layers).  Paged caches shard the BLOCK axis of
    k/v instead — the pool is built as S equal per-shard slabs (see
    Scheduler ``mesh=``), so splitting axis 1 over ``data`` hands each
    shard exactly its own slab — while ``block_tables`` shards over
    lanes and ``kpos`` (the shared position ruler) stays replicated.
    Under shard_map these specs make the decode hot path collective-
    free: every lane reads only its own shard's blocks.
    """
    spec = {}
    for name in cache:
        if name == "pos":
            spec[name] = P("data")
        elif name == "kpos":
            spec[name] = P()
        elif name in ("cache_pos", "block_tables"):
            spec[name] = P("data", None)
        else:                   # layer-stacked: k/v/k_scale/v_scale/conv/ssm
            spec[name] = P(None, "data")
    return spec


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
