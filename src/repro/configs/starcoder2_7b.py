"""starcoder2-7b — dense GQA, RoPE [arXiv:2402.19173].

StarCoder2 uses a non-gated GELU MLP and LayerNorm.
"""

from repro.configs.base import ModelConfig, register

STARCODER2_7B = register(ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    rope_theta=100000.0,
    mlp_gated=False,
    activation="gelu",
    norm="layernorm",
    compute_dtype="bfloat16",
    source="arXiv:2402.19173 (StarCoder 2 and The Stack v2)",
))
