"""pixtral-12b — VLM: pixtral-ViT frontend + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409].

Per the brief, only the transformer BACKBONE is modeled; the vision
encoder + projector are a stub — ``input_specs`` supplies precomputed
patch embeddings interleaved with text-token embeddings
(``embedding_inputs=True``).  Mistral-Nemo decoder: head_dim 128
(d_model 5120 with 32 heads -> q-proj 5120->4096).
"""

from repro.configs.base import ModelConfig, register

PIXTRAL_12B = register(ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    mlp_gated=True,
    activation="silu",
    embedding_inputs=True,
    compute_dtype="bfloat16",
    source="hf:mistralai/Pixtral-12B-2409",
))
