"""sater-slm-8b — the paper's own experimental scale.

SATER fine-tunes Llama-3.1-8B-Instruct / Qwen2.5-7B / Qwen2.5-3B with
LoRA r=8.  This config is the paper-representative entry used for the
DPO train-step dry-run (policy = base (+) LoRA, reference = base), shape
train_4k.  Architecturally identical to llama3-8b.
"""

from repro.configs.base import ModelConfig, register

SATER_SLM_8B = register(ModelConfig(
    name="sater-slm-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    mlp_gated=True,
    activation="silu",
    compute_dtype="bfloat16",
    source="SATER (EMNLP 2025) experimental setup; arch = Llama-3.1-8B",
))
