"""hymba-1.5b — hybrid: parallel attention + mamba heads in each block
[arXiv:2411.13676].

Hymba fuses attention heads and SSM heads inside one layer (outputs are
mean-fused after per-path normalization).  Most layers use sliding-window
attention; every global_every-th layer is global (Hymba uses 3 global
layers; we approximate with the same local:global machinery as gemma3).
"""

from repro.configs.base import ModelConfig, register

HYMBA_1_5B = register(ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=10000.0,
    sliding_window=1024,
    global_every=11,          # ~3 global layers out of 32
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    mlp_gated=True,
    activation="silu",
    compute_dtype="bfloat16",
    source="arXiv:2411.13676 (Hymba: A Hybrid-head Architecture for SLMs)",
))
