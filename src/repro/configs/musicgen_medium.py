"""musicgen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].

The EnCodec conv codec is a stub per the brief: ``input_specs`` supplies
precomputed frame embeddings (sum of the delayed codebook embeddings), so
``embedding_inputs=True``; the output head predicts the 2048-entry
codebook vocabulary.  MusicGen's decoder is MHA (kv == heads).
"""

from repro.configs.base import ModelConfig, register

MUSICGEN_MEDIUM = register(ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    rope_theta=10000.0,
    mlp_gated=False,
    activation="gelu",
    norm="layernorm",
    embedding_inputs=True,
    compute_dtype="bfloat16",
    source="arXiv:2306.05284 (Simple and Controllable Music Generation)",
))
