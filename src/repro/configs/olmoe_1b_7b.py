"""olmoe-1b-7b — MoE, 64 experts top-8 [arXiv:2409.02060]."""

from repro.configs.base import ModelConfig, register

OLMOE_1B_7B = register(ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,                 # every FFN is MoE
    vocab_size=50304,
    rope_theta=10000.0,
    n_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
    mlp_gated=True,
    activation="silu",
    compute_dtype="bfloat16",
    source="arXiv:2409.02060 (OLMoE: Open Mixture-of-Experts Language Models)",
))
