"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

17B *active* / ~109B total.  Every layer routes to 1 of 16 experts and
additionally applies a shared expert.  Early-fusion multimodal inputs are
modeled as embedding streams (``embedding_inputs=True``) per the brief's
frontend-stub carve-out.
"""

from repro.configs.base import ModelConfig, register

LLAMA4_SCOUT = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,                # shared-expert hidden dim
    vocab_size=202048,
    rope_theta=500000.0,
    n_experts=16,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_shared_expert=True,
    mlp_gated=True,
    activation="silu",
    embedding_inputs=True,
    compute_dtype="bfloat16",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
