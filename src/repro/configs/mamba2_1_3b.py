"""mamba2-1.3b — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060].

48 layers of pure Mamba2 blocks: in_proj -> causal conv1d -> SSD scan ->
gated RMSNorm -> out_proj.  d_inner = 2*d_model = 4096, head_dim 64 =>
64 SSM heads, state N=128.
"""

from repro.configs.base import ModelConfig, register

MAMBA2_1_3B = register(ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,               # attention-free
    n_kv_heads=0,
    d_ff=0,                  # no separate MLP; the block IS the mixer
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
    compute_dtype="bfloat16",
    source="arXiv:2405.21060 (Transformers are SSMs / Mamba-2)",
))
