"""Config system: architecture configs, input shapes, and the registry.

Every assigned architecture gets a ``ModelConfig`` built here and a module
``src/repro/configs/<arch_id>.py`` that cites its source.  Reduced "smoke"
variants (<=2 layers, d_model<=512, <=4 experts) are derived automatically
for CPU tests via :func:`smoke_variant`.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified decoder-LM configuration covering all assigned arch families.

    arch_type is one of: dense | moe | ssm | hybrid | vlm | audio.
    vlm/audio use the same decoder substrate; their modality frontend is a
    stub (precomputed embeddings supplied through ``input_specs``).
    """

    name: str
    arch_type: str
    n_layers: int
    d_model: int
    vocab_size: int

    # --- attention ---
    n_heads: int = 0                      # 0 => attention-free (pure SSM)
    n_kv_heads: int = 0
    head_dim: int = 0                     # 0 => d_model // n_heads
    rope_theta: float = 10000.0
    sliding_window: int = 0               # 0 => full attention
    global_every: int = 0                 # e.g. gemma3: 6 => layers 5,11,.. global
    attn_logit_softcap: float = 0.0

    # --- mlp ---
    d_ff: int = 0                         # 0 => no dense MLP (pure SSM block)
    mlp_gated: bool = True                # llama-style gated vs plain 2-layer
    activation: str = "silu"              # silu | gelu | relu2
    norm: str = "rmsnorm"                 # rmsnorm | layernorm

    # --- moe ---
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                     # per-expert hidden dim
    moe_shared_expert: bool = False       # llama4: shared expert alongside routed
    moe_capacity_factor: float = 1.25
    moe_aux_loss_coef: float = 0.01
    moe_router_z_coef: float = 1e-3

    # --- ssm (mamba2 / hymba) ---
    ssm_state: int = 0                    # N (state dim); 0 => no SSM path
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # --- embeddings / head ---
    tie_embeddings: bool = False
    scale_embeddings: bool = False        # gemma: * sqrt(d_model)
    embedding_inputs: bool = False        # vlm/audio: frontend stub supplies embeds

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    rms_eps: float = 1e-6

    # --- training-step knobs (used by the distributed step builders) ---
    remat: bool = True
    microbatches: int = 1
    # §Perf lever: Megatron-SP-style sequence sharding of the residual
    # stream over the 'model' axis (turns per-layer activation
    # all-reduces into reduce-scatter/all-gather pairs). Only meaningful
    # under a mesh with a 'model' axis; off by default.
    seq_shard_activations: bool = False
    # §Perf lever: constrain the MoE dispatch/combine buffers to be
    # expert-sharded over 'model' so the token scatter lowers as
    # reduce-scatter/all-to-all instead of a full-buffer all-reduce.
    shard_moe_dispatch: bool = False
    # Constrain (B,S,V) logits to be vocab-sharded over 'model' (needed
    # to FIT the 128k-262k-vocab train steps; requires a mesh context).
    shard_logits_vocab: bool = False
    # Process MoE dispatch in token chunks (lax.scan) to bound the
    # (E, C, D) buffers at long-sequence prefill/train; 1 = unchunked.
    moe_dispatch_chunks: int = 1
    # Store decode k/v caches in int8 with per-(slot, head) absmax
    # scales (beyond-paper §Perf lever: halves the decode memory term).
    kv_quant: bool = False
    # Use the explicit shard_map all-to-all expert-parallel dispatch
    # instead of GSPMD's scatter lowering (§Perf B; requires a mesh set
    # via models.moe_shard_map.set_mesh and n_experts % model == 0).
    moe_shard_map: bool = False

    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if not self.ssm_state:
            return 0
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window (0 = full/global) honoring global_every.

        gemma3 pattern: 5 local layers then 1 global, repeating.
        """
        if not self.has_attention:
            return tuple(0 for _ in range(self.n_layers))
        if not self.sliding_window:
            return tuple(0 for _ in range(self.n_layers))
        if not self.global_every:
            return tuple(self.sliding_window for _ in range(self.n_layers))
        out = []
        for i in range(self.n_layers):
            is_global = (i % self.global_every) == (self.global_every - 1)
            out.append(0 if is_global else self.sliding_window)
        return tuple(out)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, dh = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        if self.has_attention:
            per_layer += d * self.n_heads * dh            # q
            per_layer += 2 * d * self.n_kv_heads * dh     # k, v
            per_layer += self.n_heads * dh * d            # o
        if self.has_ssm:
            di = self.d_inner
            g = 1
            per_layer += d * (2 * di + 2 * g * self.ssm_state + self.n_ssm_heads)
            per_layer += self.ssm_conv_width * (di + 2 * g * self.ssm_state)
            per_layer += di * d                            # out proj
            per_layer += 2 * self.n_ssm_heads              # A_log, D
            per_layer += di                                # gated norm
        if self.is_moe:
            mult = 3 if self.mlp_gated else 2
            per_layer += self.n_experts * mult * d * self.moe_d_ff
            per_layer += d * self.n_experts                # router
            if self.moe_shared_expert:
                per_layer += mult * d * self.d_ff
        elif self.d_ff:
            mult = 3 if self.mlp_gated else 2
            per_layer += mult * d * self.d_ff
        per_layer += 2 * d                                 # two norms
        n += self.n_layers * per_layer + d                 # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts instead of all)."""
        if not self.is_moe:
            return self.param_count()
        mult = 3 if self.mlp_gated else 2
        inactive = (self.n_experts - self.moe_top_k) * mult * self.d_model * self.moe_d_ff
        return self.param_count() - self.n_layers * inactive


# ----------------------------------------------------------------------
# Input shapes assigned to this paper.
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

ARCH_IDS = (
    "llama3-8b",
    "starcoder2-7b",
    "pixtral-12b",
    "olmoe-1b-7b",
    "hymba-1.5b",
    "gemma3-1b",
    "musicgen-medium",
    "llama4-scout-17b-a16e",
    "nemotron-4-15b",
    "mamba2-1.3b",
    # the paper's own experimental scale (SATER trains 3-8B SLMs); this is
    # the paper-representative config used for the DPO train-step dry-run.
    "sater-slm-8b",
)

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def all_arch_ids():
    return ARCH_IDS


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    n_kv = min(cfg.n_kv_heads, max(1, n_heads // 2)) if cfg.n_heads else 0
    if n_heads and cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads  # keep MHA archs MHA
    head_dim = d // n_heads if n_heads else 0
    repl = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        global_every=min(cfg.global_every, 2) if cfg.global_every else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=min(cfg.ssm_head_dim, 32) if cfg.ssm_state else 64,
        ssm_chunk=16 if cfg.ssm_state else 128,
        microbatches=1,
        remat=False,
    )
    return dataclasses.replace(cfg, **repl)
