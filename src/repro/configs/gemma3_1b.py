"""gemma3-1b — dense GQA(kv=1), 5:1 local:global, 262k vocab
[hf:google/gemma-3-1b-pt]."""

from repro.configs.base import ModelConfig, register

GEMMA3_1B = register(ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    rope_theta=1000000.0,
    sliding_window=512,
    global_every=6,           # 5 local : 1 global
    mlp_gated=True,
    activation="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
    compute_dtype="bfloat16",
    source="hf:google/gemma-3-1b-pt",
))
