"""nemotron-4-15b — dense GQA, squared-ReLU non-gated MLP, LayerNorm
[arXiv:2402.16819]."""

from repro.configs.base import ModelConfig, register

NEMOTRON_4_15B = register(ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    rope_theta=10000.0,
    mlp_gated=False,
    activation="relu2",
    norm="layernorm",
    compute_dtype="bfloat16",
    source="arXiv:2402.16819 (Nemotron-4 15B Technical Report)",
))
