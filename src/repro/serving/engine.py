"""One-shot batched generation: prefill + round-chunked decode over the
full token budget in a single round.

This is now a thin wrapper over the primitives in serving/batch.py —
the same jitted prefill and ``decode_round`` the continuous-batching
scheduler (serving/scheduler.py) uses, so a scheduler run with the same
lane pool, padding and master key reproduces this engine bit-for-bit
(tests/test_scheduler.py proves it).  Host-side callers that need lane
admission/eviction and vote-aware early stopping mid-flight should go
through the scheduler instead.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.batch import (GenConfig, decode_round, first_eos_lengths,
                                 prefill_jit)

__all__ = ["GenConfig", "generate", "decode_texts"]


def generate(params, cfg: ModelConfig, prompts: np.ndarray,
             lengths: np.ndarray, key, gcfg: GenConfig) -> Tuple[np.ndarray, np.ndarray]:
    """prompts: (B, S) right-padded int32; lengths: (B,).

    Returns (generated (B, max_new_tokens) int32 incl. EOS, gen_len (B,)).
    """
    prompts = jnp.asarray(prompts)
    lengths = jnp.asarray(lengths)
    b, s = prompts.shape
    last, cache = prefill_jit(params, cfg, prompts, lengths,
                              int(s) + gcfg.max_new_tokens)
    done0 = jnp.zeros((b,), bool)
    _, _, _, toks = decode_round(params, cfg, gcfg, cache, last, done0,
                                 key, jnp.int32(0), gcfg.max_new_tokens)
    toks = np.asarray(toks)
    # token count up to and including EOS (the paper's latency proxy)
    return toks, first_eos_lengths(toks, gcfg.eos_id)


def decode_texts(tokenizer, toks: np.ndarray):
    return [tokenizer.decode(row) for row in toks]
