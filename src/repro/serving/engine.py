"""One-shot batched generation: one prefill, then one ``decode_round``
spanning the whole token budget.

This is a thin wrapper over the primitives in serving/batch.py — the
same jitted prefill and ``decode_round`` the continuous-batching
scheduler (serving/scheduler.py) uses, so a scheduler run with the same
lane pool, padding and master key reproduces this engine bit-for-bit
(tests/test_scheduler.py proves it, for both the dense and the paged
scheduler cache).  The engine itself always decodes into a dense
``(B, prompt + budget)`` cache: with a single fixed batch and no
mid-flight admission there is nothing for a block pool to recycle.
Host-side callers that need lane admission/eviction, vote-aware early
stopping, or the paged KV cache should go through the scheduler.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.batch import (GenConfig, decode_round, first_eos_lengths,
                                 prefill_jit)

__all__ = ["GenConfig", "generate", "decode_texts"]


def generate(params, cfg: ModelConfig, prompts: np.ndarray,
             lengths: np.ndarray, key, gcfg: GenConfig,
             salts=None, s_max=None) -> Tuple[np.ndarray, np.ndarray]:
    """prompts: (B, S) right-padded int32; lengths: (B,).

    Every lane decodes the full ``gcfg.max_new_tokens`` budget in one
    jitted round (lanes past their EOS keep stepping and emit pad);
    truncation at EOS happens on the host afterwards.  Returns
    (generated (B, max_new_tokens) int32 incl. EOS, gen_len (B,)).

    ``salts`` (B,) seeds each row's per-request sample stream (default
    ``arange(B)`` — row i behaves like request uid i); ``s_max``
    overrides the decode-cache width (default ``S + max_new_tokens``).
    A scheduler lane serving request ``uid`` with the same master key,
    prompt bucket, and cache width reproduces row ``salts == uid`` of
    this engine bit-for-bit, whatever the serving trace around it was —
    which is how tests/test_serving_trace.py uses this function as the
    per-request oracle.
    """
    prompts = jnp.asarray(prompts)
    lengths = jnp.asarray(lengths)
    b, s = prompts.shape
    if salts is None:
        salts = np.arange(b, dtype=np.int32)
    if s_max is None:
        s_max = int(s) + gcfg.max_new_tokens
    last, cache = prefill_jit(params, cfg, prompts, lengths, int(s_max))
    if cfg.kv_quant:
        # same per-slot absmax quantization the scheduler applies at
        # lane insertion (serving/batch._quantize_prefill), so a quant
        # scheduler lane still reproduces this engine bit-for-bit
        from repro.models.attention import quantize_kv
        cache = dict(cache)
        cache["k"], cache["k_scale"] = quantize_kv(cache["k"])
        cache["v"], cache["v_scale"] = quantize_kv(cache["v"])
    done0 = jnp.zeros((b,), bool)
    steps0 = jnp.zeros((b,), jnp.int32)
    _, _, _, toks = decode_round(params, cfg, gcfg, cache, last, done0,
                                 key, jnp.asarray(salts, dtype=jnp.int32),
                                 steps0, gcfg.max_new_tokens)
    toks = np.asarray(toks)
    # token count up to and including EOS (the paper's latency proxy)
    return toks, first_eos_lengths(toks, gcfg.eos_id)


def decode_texts(tokenizer, toks: np.ndarray):
    return [tokenizer.decode(row) for row in toks]
