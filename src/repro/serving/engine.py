"""Batched generation engine: prefill + fixed-shape decode loop with
per-lane EOS masking (the TPU-native analogue of vLLM's continuous
batching at the granularity this paper needs — whole-request batches
sampled K ways for cascade voting).

The decode loop is a single jitted ``lax.scan`` over max_new_tokens;
finished lanes keep stepping but emit pad and stop extending their
KV validity, so the compiled shape is static.  Host-side, the cascade
driver (core/routing.py) implements SATER's *early stopping*: it decodes
in rounds and drops the whole batch as soon as the vote is decided —
that is where the paper's >80% AROL cut comes from.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving.sampler import sample_tokens


@dataclasses.dataclass(frozen=True)
class GenConfig:
    max_new_tokens: int = 128
    temperature: float = 0.7
    top_p: float = 1.0
    eos_id: int = 2
    pad_id: int = 0


@functools.partial(jax.jit, static_argnames=("cfg", "gcfg", "prompt_len"))
def _generate_jit(params, cfg: ModelConfig, prompts, lengths, key,
                  gcfg: GenConfig, prompt_len: int):
    b = prompts.shape[0]
    max_len = prompt_len + gcfg.max_new_tokens
    last, cache = model_lib.prefill(params, cfg, tokens=prompts,
                                    lengths=lengths, max_len=max_len,
                                    last_only=True)

    def step(carry, key_t):
        cache, cur_logits, done = carry
        tok = sample_tokens(key_t, cur_logits, gcfg.temperature, gcfg.top_p)
        tok = jnp.where(done, gcfg.pad_id, tok)
        new_done = done | (tok == gcfg.eos_id)
        next_logits, cache = model_lib.decode_step(params, cfg, tok, cache)
        return (cache, next_logits, new_done), tok

    keys = jax.random.split(key, gcfg.max_new_tokens)
    done0 = jnp.zeros((b,), bool)
    (_, _, done), toks = jax.lax.scan(step, (cache, last, done0), keys)
    return jnp.swapaxes(toks, 0, 1), done                      # (B, T_new)


def generate(params, cfg: ModelConfig, prompts: np.ndarray,
             lengths: np.ndarray, key, gcfg: GenConfig) -> Tuple[np.ndarray, np.ndarray]:
    """prompts: (B, S) right-padded int32; lengths: (B,).

    Returns (generated (B, max_new_tokens) int32 incl. EOS, gen_len (B,)).
    """
    toks, _ = _generate_jit(params, cfg, jnp.asarray(prompts),
                            jnp.asarray(lengths), key, gcfg,
                            int(prompts.shape[1]))
    toks = np.asarray(toks)
    # token count up to and including EOS (the paper's latency proxy)
    gen_len = np.zeros((toks.shape[0],), np.int32)
    for i, row in enumerate(toks):
        eos = np.nonzero(row == gcfg.eos_id)[0]
        gen_len[i] = int(eos[0]) + 1 if len(eos) else toks.shape[1]
    return toks, gen_len


def decode_texts(tokenizer, toks: np.ndarray):
    return [tokenizer.decode(row) for row in toks]
