"""Token sampling: temperature + top-p (nucleus), greedy fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def top_p_mask(logits, top_p: float):
    """Mask logits outside the top-p nucleus to -inf.

    The nucleus is the smallest prefix of the probability-sorted vocab
    whose cumulative probability reaches ``top_p``; surviving logits
    are those >= the smallest kept sorted logit.

    Tie boundary (documented contract, tested in tests/test_sampler.py):
    when several logits are exactly equal at the nucleus edge, the
    ``>= cutoff`` comparison keeps ALL of them, even the ones whose
    cumulative-probability rank falls outside ``top_p``.  Equal logits
    are equally deserving — a sort-order-dependent subset would make
    the kept set depend on how the backend's sort breaks ties — so the
    effective nucleus mass may exceed ``top_p`` by up to
    (n_tied - 1) * p_tied.  This matches common serving-engine
    behaviour and keeps the mask permutation-invariant.
    """
    sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
    sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    # keep smallest prefix with cumulative prob >= top_p
    keep = cum - sorted_probs < top_p
    cutoff = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits >= cutoff, logits, -jnp.inf)


def sample_tokens(key, logits, temperature: float = 0.7, top_p: float = 1.0):
    """logits: (B, V) -> (B,) int32 samples, one shared noise tensor.

    temperature <= 0 is greedy argmax (top_p ignored); otherwise
    temperature-scaled nucleus sampling via :func:`top_p_mask` (see its
    docstring for the tie-at-the-boundary contract).

    The whole batch draws from a single categorical over (B, V), so a
    row's sample depends on its row index and the batch width.  Serving
    paths that need trace-independent streams use
    :func:`sample_tokens_salted` instead."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_p < 1.0:
        logits = top_p_mask(logits, top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_tokens_salted(key, salts, steps, logits,
                         temperature: float = 0.7, top_p: float = 1.0):
    """Per-row sampling streams: row i draws from
    ``fold_in(fold_in(key, salts[i]), steps[i])``.

    salts/steps: (B,) int32.  With ``salts`` a per-request id and
    ``steps`` the request's own generated-token count, a request's
    sample stream depends ONLY on (master key, request id, token
    index) — not on the lane it landed in, the lane-pool width, when it
    was admitted, or how its prompt was prefilled.  This is what lets a
    one-shot per-request oracle reproduce any serving trace bit-for-bit
    (tests/test_serving_trace.py).

    temperature <= 0 is greedy argmax (keys unused), identical to
    :func:`sample_tokens`."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_p < 1.0:
        logits = top_p_mask(logits, top_p)

    def draw(salt, step, row):
        k = jax.random.fold_in(jax.random.fold_in(key, salt), step)
        return jax.random.categorical(k, row)

    return jax.vmap(draw)(salts, steps, logits).astype(jnp.int32)
