"""Token sampling: temperature + top-p (nucleus), greedy fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(key, logits, temperature: float = 0.7, top_p: float = 1.0):
    """logits: (B, V) -> (B,) int32 samples."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(sorted_probs, axis=-1)
        # keep smallest prefix with cumulative prob >= top_p
        keep = cum - sorted_probs < top_p
        cutoff = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits >= cutoff, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
