"""Host-side allocators for the paged serving caches: ``BlockPool``
for attention KV blocks, ``StateSlotPool`` for recurrent (SSM) state
slots.

The paged serving path (``Scheduler(paged=True)``) stores K/V in a flat
pool of fixed-size *blocks* — ``(n_layers, n_blocks + 1, block_size,
n_kv_heads, head_dim)`` device arrays — instead of one dense
``(n_lanes, s_max)`` slab per lane.  ``BlockPool`` is the host-side
book-keeper: a free-list of physical block ids plus a reservation
counter that makes admission backpressure deadlock-free.

Block id 0 is the *trash block*: it is never handed out, and every
write that must go nowhere (evicted lanes still stepping in the jitted
round, positions past a lane's budget) is routed to it.  Allocatable
ids are ``1 .. n_blocks``.

Two counters, two invariants:

  * ``in_use``    — physical blocks currently held by lanes;
  * ``reserved``  — blocks *promised* to admitted lanes but not yet
    allocated (a lane admitted with prompt length P and decode budget
    G reserves ``ceil((P + G) / block_size)`` blocks up front and draws
    them lazily as it decodes).

  Invariant 1: ``in_use + n_free == n_blocks`` (no leaks).
  Invariant 2: ``reserved <= n_free`` (every promised block exists), so
  a live lane can never fail to grow — admission is the only place
  that can block.  This trades a little admission concurrency for a
  preemption-free scheduler.

Freed blocks return to the pool the moment a lane finishes — including
lanes killed mid-flight by a ``StopPolicy`` such as ``VoteEarlyStop``,
which is what turns SATER's confidence-based rejection into reclaimed
HBM, not just skipped compute.

Sharing: refcounts and copy-on-write
------------------------------------
Blocks are *reference counted* so one physical block can back the same
logical prompt positions in many lanes at once — the substrate for
SATER's K-vote groups (K lanes, one prompt) and for cross-request
instruction-prefix sharing (serving/scheduler.py):

  * ``alloc`` hands out blocks with refcount 1;
  * ``share(ids)`` registers one more holder per block (a lane whose
    block table maps the block read-only, or a prefix-cache entry
    keeping it warm);
  * ``free(ids)`` releases one hold per listed block — a block returns
    to the free list only when its *last* holder releases it, so a
    ``VoteEarlyStop`` kill that frees a vote lane's table decrements
    the shared prompt blocks and physically frees only the lane's
    private tail (no double-free by construction);
  * ``cow(id)`` is the copy-on-write primitive: called by a lane about
    to *append into* the last, partially-filled prompt block.  With
    refcount 1 the caller already owns the block exclusively and keeps
    it (no copy); otherwise the caller's hold is dropped and a private
    block is drawn from its reservation — the caller must then copy
    the block's device contents before writing (batch.copy_blocks).

Shared holds cost reservation only once: the group that allocates the
prompt blocks reserves them; extra holders reserve only their private
tail (growth blocks + at most one CoW copy).

Host offload: preemption's memory side
--------------------------------------
``offload(ids)`` moves a lane's holds to *host* blocks (ids from a
disjoint, never-recycled namespace) and ``restore(handle)`` moves them
back, drawing fresh device blocks from the caller's reservation.  Host
blocks are refcounted exactly like device blocks, and a dual-residence
map tracks content that is live on both sides at once (a shared prompt
block with one lane preempted and one still decoding): the first
offloader copies bytes, later co-holders attach for free, and a
restore that finds a live device twin re-shares it with zero bytes
moved.  The pool only does the book-keeping — the scheduler owns the
actual byte movement, directed by the ``copies`` / ``scatters`` lists
the two calls return.

Worked example (the block-size / n_lanes / HBM trade-off)
---------------------------------------------------------
Take an 8B-class config: 32 layers, 8 KV heads, head_dim 128, bf16.
One cache *slot* (one token position, K+V, all layers) costs

    32 layers * 8 heads * 128 dim * 2 bytes * 2 (K and V) = 128 KiB.

Dense serving at ``n_lanes = 96`` and ``s_max = 4096`` pins

    96 * 4096 * 128 KiB = 48 GiB

of HBM whether lanes use it or not — the cache, not the FLOPs, caps
``n_lanes``.  Paged with ``block_size = 32`` (4 MiB per block) holds
only what lanes have actually written, rounded up to the block:
SATER's shortest-response training plus vote early stop mean a typical
lane dies after a few hundred tokens, so steady-state usage is

    96 lanes * ~256 tokens ≈ 96 * 8 blocks * 4 MiB ≈ 3 GiB,

a ~16x cut — or, holding HBM constant, ~16x more lanes.  Smaller
blocks waste less in the final partial block per lane (expected waste
is ``block_size / 2`` slots per lane) but mean longer block tables and
more scatter/gather index traffic; 16-64 slots is the sweet spot
(TPU tiling also wants the block's token axis >= 8 for f32 / 16 for
bf16 — see ``kernels/paged_attention``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass
class HostBlocks:
    """Handle to a lane's KV pages parked in host RAM.

    ``ids`` are *host* block ids in block-table order — a namespace
    disjoint from device ids, never recycled, so a stale handle can
    never alias a later offload.  The handle owns one host hold per
    entry; redeem it with :meth:`BlockPool.restore` or drop it with
    :meth:`BlockPool.discard`.
    """

    ids: List[int]


class BlockPool:
    """Free-list allocator over ``n_blocks`` equal-size cache blocks.

    All methods are O(blocks touched); nothing here touches the device
    — the scheduler owns the device arrays and only consumes the ids.
    """

    TRASH = 0    # reserved block id: writes-to-nowhere land here

    def __init__(self, n_blocks: int, block_size: int, id_base: int = 0):
        if n_blocks < 1:
            raise ValueError("pool needs at least one allocatable block")
        if id_base < 0:
            raise ValueError(f"id_base must be >= 0, got {id_base}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # Sharded serving gives each data shard its own pool over a
        # disjoint slab of the device block axis: shard s's pool hands
        # out ids ``id_base+1 .. id_base+n_blocks`` (id_base =
        # s * (n_blocks + 1), row id_base being that shard's local
        # trash block).  Ids are then globally unique, so the
        # scheduler's block tables and the GSPMD insert/gather/scatter
        # call sites never need to know which shard owns a block.
        self.id_base = id_base
        # LIFO free-list: recently freed (still-warm) blocks are reused
        # first.  Ids base+1..base+n_blocks; 0 is the trash block (and
        # every per-shard base row), never listed.
        # The set mirrors the list so free() can reject double-frees —
        # the one misuse that would corrupt the cache silently (one
        # physical block alloc'd to two live lanes) instead of erroring.
        self._free: List[int] = list(range(id_base + n_blocks, id_base, -1))
        self._free_set = set(self._free)
        # holder count per live block; absent / 0 <=> block is free
        self._refs: Dict[int, int] = {}
        self.reserved = 0
        self.peak_in_use = 0
        self.peak_reserved = 0       # reservation high-water (admission churn)
        self.cow_copies = 0          # cow() calls that materialized a copy
        self.shared_holds = 0        # holders registered via share()
        # --- host offload side (preemption) ---------------------------
        # Host block ids are monotonic and never reused; each carries a
        # refcount so a prompt block shared by K lanes that all get
        # preempted is copied to host ONCE and restored shared.
        self._host_refs: Dict[int, int] = {}
        self._host_next = 1
        # Dual-residence maps while a block's bytes live on BOTH sides
        # (some holders still on device, some parked): device id <->
        # host id.  Offloaded content is immutable by construction
        # (shared prompt blocks are read-only; partial tails are always
        # private post-CoW), so the twin never goes stale.
        self._host_of: Dict[int, int] = {}   # device bid -> host id
        self._dev_of: Dict[int, int] = {}    # host id -> device bid
        self.host_blocks_peak = 0    # host-pool high-water (distinct blocks)
        self.offloaded_blocks = 0    # device->host block copies performed
        self.restored_blocks = 0     # host->device block materializations

    # -- queries -------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def available(self) -> int:
        """Blocks neither allocated nor promised to an admitted lane —
        what a *new* admission may reserve."""
        return len(self._free) - self.reserved

    def refcount(self, bid: int) -> int:
        """Current holder count of a block (0 <=> free)."""
        return self._refs.get(bid, 0)

    @property
    def host_in_use(self) -> int:
        """Distinct blocks currently parked in host RAM."""
        return len(self._host_refs)

    def host_refcount(self, hid: int) -> int:
        """Holder count of a host block (0 <=> not parked)."""
        return self._host_refs.get(hid, 0)

    # -- reservation (admission-time) ----------------------------------
    def reserve(self, n: int) -> bool:
        """Promise ``n`` blocks to a lane being admitted.  Returns False
        (and reserves nothing) when the pool cannot guarantee them —
        the scheduler then leaves the request queued: backpressure."""
        if n > self.available:
            return False
        self.reserved += n
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        return True

    def unreserve(self, n: int) -> None:
        """Return an unused part of a reservation (lane finished or was
        killed before drawing all its promised blocks)."""
        if n > self.reserved:
            raise ValueError(f"unreserve({n}) exceeds reserved={self.reserved}")
        self.reserved -= n

    # -- allocation ----------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Draw ``n`` physical blocks from the lane's reservation.

        Invariant 2 guarantees this never fails for a properly reserved
        lane; a failure here is a scheduler accounting bug, not a
        recoverable condition, hence the hard error.
        """
        if n > self.reserved:
            raise RuntimeError(f"alloc({n}) exceeds reserved={self.reserved}: "
                               "lane drew more blocks than it reserved")
        if n > len(self._free):
            raise RuntimeError(f"alloc({n}) with only {len(self._free)} free: "
                               "reservation invariant violated")
        ids = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(ids)
        for i in ids:
            self._refs[i] = 1
        self.reserved -= n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    # -- sharing -------------------------------------------------------
    def share(self, ids: List[int], n: int = 1) -> None:
        """Register ``n`` more holders for each listed block (a lane's
        block table mapping it read-only, or a prefix-cache entry).
        Every hold must eventually be released by one :meth:`free`."""
        if n < 0:
            raise ValueError(f"share: negative holder count {n}")
        for i in ids:
            if self._refs.get(i, 0) < 1:
                raise ValueError(f"share: block {i} is not allocated")
        for i in ids:
            self._refs[i] += n
        self.shared_holds += n * len(ids)

    def cow(self, bid: int) -> Tuple[int, bool]:
        """Copy-on-write: make ``bid`` privately writable for the caller.

        Returns ``(block_id, copied)``.  With a single holder the caller
        keeps ``bid`` (``copied`` False, nothing changes).  Otherwise the
        caller's hold on ``bid`` is released and a fresh private block is
        drawn from the caller's *reservation*; ``copied`` True tells the
        caller to clone the device contents (batch.copy_blocks) before
        its first write.
        """
        if self._refs.get(bid, 0) < 1:
            raise ValueError(f"cow: block {bid} is not allocated")
        if self._refs[bid] == 1:
            return bid, False
        self._refs[bid] -= 1
        self.cow_copies += 1
        return self.alloc(1)[0], True

    def free(self, ids: List[int]) -> None:
        """Release one hold per listed block (eviction, EOS, a
        ``StopPolicy`` kill, or a prefix-cache eviction).  A block
        returns to the free list — reusable immediately — only when its
        last holder releases it.  Over-releasing raises: a block freed
        more times than it is held would later back two live lanes."""
        counts: Dict[int, int] = {}
        lo, hi = self.id_base + 1, self.id_base + self.n_blocks
        for i in ids:
            if not lo <= i <= hi:
                raise ValueError(f"free: {i} is not an allocatable block id "
                                 f"of this pool (ids {lo}..{hi})")
            counts[i] = counts.get(i, 0) + 1
        for i, c in counts.items():
            if c > self._refs.get(i, 0):
                raise ValueError(
                    f"free: block {i} released {c} time(s) but held "
                    f"{self._refs.get(i, 0)} (double-free)")
        for i, c in counts.items():
            self._refs[i] -= c
            if self._refs[i] == 0:
                del self._refs[i]
                # a fully-freed device block may be recycled at any time:
                # sever its host twin so restore() re-materializes from
                # the host copy instead of aliasing the recycled block
                h = self._host_of.pop(i, None)
                if h is not None:
                    del self._dev_of[h]
                self._free_set.add(i)
                self._free.append(i)

    # -- host offload (preemption) -------------------------------------
    def offload(self, ids: List[int]) -> Tuple[HostBlocks,
                                               List[Tuple[int, int]]]:
        """Move the caller's holds on ``ids`` to host blocks.

        Returns ``(handle, copies)``.  ``copies`` lists
        ``(device_bid, host_bid)`` pairs whose device bytes the caller
        must snapshot into host storage — only the FIRST offloader of a
        given block copies; later co-holders (other preempted lanes of a
        vote group, or re-offload while a prefix-cache entry keeps the
        device twin warm) attach to the existing host block for free.
        The caller must capture the device array value before issuing
        any later cache write (functional updates make the captured
        value immutable, so this is a consistency — not a race — rule).

        The device holds are released exactly as by :meth:`free`, so a
        block whose last holder offloads it returns to the free list
        immediately; over-offload raises before mutating.
        """
        counts: Dict[int, int] = {}
        for i in ids:
            counts[i] = counts.get(i, 0) + 1
        for i, c in counts.items():
            if c > self._refs.get(i, 0):
                raise ValueError(
                    f"offload: block {i} listed {c} time(s) but held "
                    f"{self._refs.get(i, 0)}")
        out: List[int] = []
        copies: List[Tuple[int, int]] = []
        for b in ids:
            h = self._host_of.get(b)
            if h is None:
                h = self._host_next
                self._host_next += 1
                self._host_refs[h] = 1
                copies.append((b, h))
                self.offloaded_blocks += 1
                self.free([b])
                if self._refs.get(b, 0) > 0:
                    # co-holders keep the device twin alive: record the
                    # dual residence so their later offloads are free
                    self._host_of[b] = h
                    self._dev_of[h] = b
            else:
                self._host_refs[h] += 1
                self.free([b])
            out.append(h)
        self.host_blocks_peak = max(self.host_blocks_peak, self.host_in_use)
        return HostBlocks(out), copies

    def restore_cost(self, hb: HostBlocks) -> int:
        """Device blocks a :meth:`restore` of ``hb`` would draw from the
        caller's reservation right now (host blocks without a live
        device twin; twinned blocks re-share in place for free)."""
        return len({h for h in hb.ids if h not in self._dev_of})

    def restore(self, hb: HostBlocks) -> Tuple[
            List[int], List[Tuple[int, int]], List[int]]:
        """Redeem a host handle back into device blocks.

        Returns ``(blocks, scatters, dropped)``: ``blocks`` are device
        ids in the handle's order; ``scatters`` lists
        ``(host_id, device_bid)`` pairs whose host bytes the caller must
        write into the device cache (blocks with a live device twin are
        re-shared with zero bytes moved); ``dropped`` lists host ids
        whose last hold was just redeemed — the caller frees their host
        bytes AFTER performing the scatters.

        Fresh materializations draw from the caller's *reservation*;
        the call validates refcounts and reservation up front and raises
        before mutating anything (over-restore is an accounting bug).
        """
        counts: Dict[int, int] = {}
        for h in hb.ids:
            counts[h] = counts.get(h, 0) + 1
        for h, c in counts.items():
            if c > self._host_refs.get(h, 0):
                raise ValueError(
                    f"restore: host block {h} redeemed {c} time(s) but "
                    f"held {self._host_refs.get(h, 0)}")
        fresh = self.restore_cost(hb)
        if fresh > self.reserved:
            raise RuntimeError(
                f"restore needs {fresh} fresh block(s) but only "
                f"{self.reserved} reserved: caller must reserve the "
                "restore_cost before redeeming")
        blocks: List[int] = []
        scatters: List[Tuple[int, int]] = []
        dropped: List[int] = []
        for h in hb.ids:
            d = self._dev_of.get(h)
            if d is None:
                d = self.alloc(1)[0]
                scatters.append((h, d))
                self.restored_blocks += 1
                self._dev_of[h] = d
                self._host_of[d] = h
            else:
                self.share([d])
            blocks.append(d)
            self._host_refs[h] -= 1
            if self._host_refs[h] == 0:
                del self._host_refs[h]
                dropped.append(h)
                d2 = self._dev_of.pop(h, None)
                if d2 is not None:
                    del self._host_of[d2]
        return blocks, scatters, dropped

    def discard(self, hb: HostBlocks) -> List[int]:
        """Release a host handle without restoring it (a parked request
        was cancelled or its vote group decided).  Returns the host ids
        whose last hold was dropped — the caller frees their bytes.
        Over-discard raises before mutating."""
        counts: Dict[int, int] = {}
        for h in hb.ids:
            counts[h] = counts.get(h, 0) + 1
        for h, c in counts.items():
            if c > self._host_refs.get(h, 0):
                raise ValueError(
                    f"discard: host block {h} dropped {c} time(s) but "
                    f"held {self._host_refs.get(h, 0)}")
        dropped: List[int] = []
        for h in hb.ids:
            self._host_refs[h] -= 1
            if self._host_refs[h] == 0:
                del self._host_refs[h]
                dropped.append(h)
                d = self._dev_of.pop(h, None)
                if d is not None:
                    del self._host_of[d]
        return dropped

    def leak_report(self) -> "str | None":
        """None when the pool has fully drained (every block free, no
        outstanding reservation) — the invariant a streaming serving
        loop must restore after arbitrary mid-flight admission/eviction
        churn.  Otherwise a human-readable description of what is still
        held, for test assertions and shutdown diagnostics."""
        if self.in_use == 0 and self.reserved == 0 and not self._host_refs:
            return None
        held = {i: c for i, c in self._refs.items()}
        msg = (f"pool not drained: in_use={self.in_use} "
               f"reserved={self.reserved} held_refs={held}")
        if self._host_refs:
            msg += (f" host_in_use={self.host_in_use} "
                    f"host_refs={dict(self._host_refs)}")
        return msg

    def __repr__(self):
        return (f"BlockPool(blocks={self.n_blocks}, bs={self.block_size}, "
                f"in_use={self.in_use}, reserved={self.reserved}, "
                f"peak={self.peak_in_use}, cow={self.cow_copies})")


class StateSlotPool:
    """Allocator for per-lane recurrent *state slots* (conv tail + SSD
    state) — the state-slot leg of the per-architecture cache protocol
    (models/cache_protocol.py).

    An SSM lane's state is O(1) in sequence length — one
    ``(W, conv_ch)`` conv tail plus one ``(H, P, N)`` SSD state per
    layer — and it lives in *lane-indexed* dense arrays, so there is no
    block indirection to manage.  What "paging" it means is the rest of
    what :class:`BlockPool` gives KV lanes:

      * **admission backpressure** — a pool sized below ``n_lanes``
        makes SSM admission block on ``reserve()`` exactly like a KV
        lane blocks on block reservation (useful when the state slab,
        not the lane count, is the HBM cap: mamba2-2.7b's slot is
        ~7 MiB/lane where a gemma3 KV *slot* is KiB but grows per
        token);
      * **preempt/offload accounting** — ``offload()`` moves a slot's
        hold to a monotonic host id (the scheduler owns the actual
        byte snapshot, as it does for KV blocks) and ``restore()``
        draws a fresh slot from the caller's reservation;
      * **leak audit** — ``leak_report()`` must return None after a
        drained serving run, mirroring the KV invariant.

    Reservation and allocation are deliberately the same two-phase
    protocol as :class:`BlockPool` (reserve at admission, draw lazily,
    hard-error on overdraw) so the scheduler treats both pools
    uniformly; a hybrid lane holds one slot here AND a block-table
    there.  No refcounts: recurrent state is never shared between
    lanes (each vote lane's state diverges from token 0 of decode, and
    ``insert_lanes_shared`` replicates — not aliases — conv/ssm rows).

    ``slot_bytes`` is the per-slot HBM cost (all layers, conv + SSD),
    used only for reporting: ``peak_state_bytes`` is what the hetero
    bench gate pins against ``lanes * slot size``.
    """

    def __init__(self, n_slots: int, slot_bytes: int = 0, id_base: int = 0):
        if n_slots < 1:
            raise ValueError("pool needs at least one state slot")
        if id_base < 0:
            raise ValueError(f"id_base must be >= 0, got {id_base}")
        self.n_slots = n_slots
        self.slot_bytes = slot_bytes
        self.id_base = id_base
        # LIFO free list, ids base+1 .. base+n_slots (0 kept unused for
        # symmetry with BlockPool's trash row / per-shard id spacing)
        self._free: List[int] = list(range(id_base + n_slots, id_base, -1))
        self._held = set()
        self.reserved = 0
        self.peak_in_use = 0
        self.peak_reserved = 0
        # --- host offload side (preemption) ---------------------------
        self._host = set()           # outstanding host ids
        self._host_next = 1
        self.host_slots_peak = 0
        self.offloaded_slots = 0
        self.restored_slots = 0

    # -- queries -------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def available(self) -> int:
        """Slots neither allocated nor promised — what a new admission
        may reserve."""
        return len(self._free) - self.reserved

    @property
    def host_in_use(self) -> int:
        return len(self._host)

    @property
    def peak_state_bytes(self) -> int:
        """High-water HBM pinned by live slots (reporting only)."""
        return self.peak_in_use * self.slot_bytes

    # -- reservation / allocation --------------------------------------
    def reserve(self, n: int = 1) -> bool:
        """Promise ``n`` slots to lanes being admitted; False (reserving
        nothing) when the pool cannot guarantee them — backpressure."""
        if n > self.available:
            return False
        self.reserved += n
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        return True

    def unreserve(self, n: int = 1) -> None:
        if n > self.reserved:
            raise ValueError(f"unreserve({n}) exceeds reserved={self.reserved}")
        self.reserved -= n

    def alloc(self) -> int:
        """Draw one slot from the caller's reservation.  Failure here is
        a scheduler accounting bug (see BlockPool.alloc)."""
        if self.reserved < 1:
            raise RuntimeError("alloc() with no reservation: lane drew a "
                               "slot it never reserved")
        if not self._free:
            raise RuntimeError("alloc() with no free slot: reservation "
                               "invariant violated")
        sid = self._free.pop()
        self._held.add(sid)
        self.reserved -= 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return sid

    def free(self, sid: int) -> None:
        """Release a slot (EOS, budget, StopPolicy kill).  Double-free
        raises — a slot freed twice would back two live lanes."""
        if sid not in self._held:
            raise ValueError(f"free: slot {sid} is not allocated "
                             f"(double-free or foreign id)")
        self._held.discard(sid)
        self._free.append(sid)

    # -- host offload (preemption) -------------------------------------
    def offload(self, sid: int) -> int:
        """Move a slot's hold to a host id (monotonic, never recycled).
        The caller snapshots the lane's conv/ssm rows itself — the pool
        only does the accounting.  The device slot frees immediately."""
        self.free(sid)
        hid = self._host_next
        self._host_next += 1
        self._host.add(hid)
        self.offloaded_slots += 1
        self.host_slots_peak = max(self.host_slots_peak, len(self._host))
        return hid

    def restore(self, hid: int) -> int:
        """Redeem a host id back into a device slot, drawn from the
        caller's reservation (reserve 1 before redeeming)."""
        if hid not in self._host:
            raise ValueError(f"restore: host slot {hid} is not parked")
        sid = self.alloc()
        self._host.discard(hid)
        self.restored_slots += 1
        return sid

    def discard(self, hid: int) -> None:
        """Drop a host id without restoring (parked request cancelled or
        its vote group decided)."""
        if hid not in self._host:
            raise ValueError(f"discard: host slot {hid} is not parked")
        self._host.discard(hid)

    def leak_report(self) -> "str | None":
        """None when fully drained — every slot free, no reservation, no
        parked host state; else a description for test assertions."""
        if self.in_use == 0 and self.reserved == 0 and not self._host:
            return None
        msg = (f"state-slot pool not drained: in_use={self.in_use} "
               f"reserved={self.reserved} held={sorted(self._held)}")
        if self._host:
            msg += f" host_in_use={len(self._host)}"
        return msg

    def __repr__(self):
        return (f"StateSlotPool(slots={self.n_slots}, "
                f"slot_bytes={self.slot_bytes}, in_use={self.in_use}, "
                f"reserved={self.reserved}, peak={self.peak_in_use})")
