"""Shape-bucketed batching + round-chunked decode: the jitted primitives
under both the one-shot engine (engine.py) and the continuous-batching
scheduler (scheduler.py).

Two ideas bound recompilation while keeping every compiled shape static:

  * prompt-length *buckets* — prompts are right-padded to the smallest
    bucket that fits, so prefill compiles once per (admit size, bucket)
    pair instead of once per prompt length;
  * *round-chunked* decode — instead of one ``lax.scan`` over the whole
    token budget, decoding runs in rounds of R tokens with per-lane
    liveness (``done``) carried across rounds.  Between rounds the host
    can admit new requests into freed lanes, evict finished ones, and
    ask a StopPolicy whether whole vote groups are already decided —
    which is what turns SATER's early stopping from token *accounting*
    into actually-skipped compute.

PRNG contract: the token a request samples at its own step t uses
``fold_in(fold_in(key, salt), t)`` with ``salt`` the request's id and
``t`` the request's generated-token count (``sampler.
sample_tokens_salted``).  A request's sample stream therefore depends
only on the master key, its id, and its token index — NOT on the lane
it was placed in, the lane-pool width, the round it was admitted, or
how its prompt was prefilled (whole or chunked).  That trace
independence is what the randomized differential harness
(tests/test_serving_trace.py) checks against a one-shot per-request
oracle, bit for bit.

The primitives are cache-layout agnostic where they can be:
``decode_round`` steps whatever cache pytree ``model.decode_step``
understands (any of the per-architecture protocols in
models/cache_protocol.py — dense or block-paged attention KV,
per-lane SSM state slots, or a hybrid of both), while lane insertion
is layout-specific — ``insert_lanes`` scatters dense cache rows
(including conv/ssm state rows), ``insert_lanes_paged`` scatters
prompt K/V into allocator-assigned pool pages (see
serving/block_pool.py and serving/scheduler.py).

Prefix sharing adds a third insert path: ``prefill_shared`` prefills
one row per *vote group* (not per lane) and ``insert_lanes_shared``
scatters that single row's prompt K/V into the pool once, then stitches
the group's K lanes onto it — each lane's block table maps the same
physical prompt blocks read-only, and only the last partial block is
cloned per lane (``copy_blocks``) so decode appends never collide.

Chunked prefill replaces the insert paths entirely when the scheduler
runs with ``chunk_size``: ``prefill_chunk_jit`` appends one C-token
chunk of each row's prompt directly onto the live cache
(``model.prefill_chunk`` — dense rows or pool pages), interleaved with
decode rounds, and ``fanout_lanes`` replicates a completed shared
row's decode-entry state to its K vote lanes.  Chunk attention runs at
the prompt-bucket width, so a chunked prompt is bit-identical to a
whole-prefilled one (tests/test_serving_trace.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving.sampler import sample_tokens_salted

try:                                    # newer JAX exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                     # older releases: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map


@dataclasses.dataclass(frozen=True)
class GenConfig:
    max_new_tokens: int = 128
    temperature: float = 0.7
    top_p: float = 1.0
    eos_id: int = 2
    pad_id: int = 0


# ----------------------------------------------------------------------
# Buckets
# ----------------------------------------------------------------------

def make_buckets(max_len: int, min_bucket: int = 32) -> Tuple[int, ...]:
    """Power-of-two ladder from min_bucket up, always ending at max_len."""
    out: List[int] = []
    b = min_bucket
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n; the largest bucket if none fits (callers
    truncate to it)."""
    for b in buckets:
        if n <= b:
            return b
    return max(buckets)


def pad_token_rows(rows: Sequence[Sequence[int]], pad_id: int,
                   width: int, n_rows: int) -> Tuple[np.ndarray, np.ndarray]:
    """Right-pad token id rows to (n_rows, width).  Rows beyond
    len(rows) are dummies of length 1 (prefill indexes lengths-1)."""
    toks = np.full((n_rows, width), pad_id, np.int32)
    lens = np.ones((n_rows,), np.int32)
    for i, ids in enumerate(rows):
        ids = list(ids)[:width]
        toks[i, : len(ids)] = ids
        lens[i] = max(len(ids), 1)
    return toks, lens


# ----------------------------------------------------------------------
# Jitted primitives
# ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "max_len"))
def prefill_jit(params, cfg: ModelConfig, prompts, lengths, max_len: int):
    """Bucket-shaped prefill: (last-token logits (B,V), cache sized for
    max_len total positions)."""
    return model_lib.prefill(params, cfg, tokens=prompts, lengths=lengths,
                             max_len=max_len, last_only=True)


@functools.partial(jax.jit, static_argnames=("cfg", "max_len"))
def prefill_shared(params, cfg: ModelConfig, prompts, lengths, max_len: int):
    """Prefill for shared-prefix group admission: one row per *group*
    (the K vote lanes of a question share it), instead of one row per
    lane as in :func:`prefill_jit`.  Numerically identical to
    ``prefill_jit`` — it is a separate jitted entry point so the
    scheduler's shared path is observable (tests count its invocations
    to prove one-prefill-per-question) and so its compile cache keys
    don't mix with the per-lane path's."""
    return model_lib.prefill(params, cfg, tokens=prompts, lengths=lengths,
                             max_len=max_len, last_only=True)


@functools.partial(jax.jit, static_argnames=("cfg", "sb"))
def prefill_chunk_jit(params, cfg: ModelConfig, cache, cur_logits, tokens,
                      start, lengths, lanes, read_rows, write_rows, sb: int):
    """One chunked-prefill step for a batch of rows (model.prefill_chunk
    plus the per-lane serving state it leaves behind).

    tokens (Nb, C) is each row's next prompt chunk, ``start`` its
    offset, ``lanes`` the target lane per row — real lanes for
    dense/paged single-lane rows, the ``>= n_lanes`` sentinel for
    shared-prefix group rows, whose per-lane state is fanned out by
    :func:`fanout_lanes` only once their final chunk lands.  For lanes
    addressed here:

      * ``pos`` advances to ``min(start + C, length)`` — after the final
        chunk, exactly the prompt length whole-prefill admission sets;
      * dense caches get the row's ``cache_pos`` validity rewritten
        wholesale to ``[0, pos)`` — later chunks thereby also erase the
        scribbles an idle (done-masked) lane's decode writes left while
        it waited for prefill (see the scheduler's mixed-mode round);
      * ``cur_logits`` takes the chunk's last-token logits — garbage
        until the final chunk, at which point it is bit-identical to
        whole prefill's ``last_only`` output and feeds decode step 0.

    Returns (cache, cur_logits, chunk_logits (Nb, V)).
    """
    logits, cache = model_lib.prefill_chunk(
        params, cfg, tokens, cache, start=start, lengths=lengths,
        lanes=lanes, read_rows=read_rows, write_rows=write_rows, sb=sb)
    pos_after = jnp.minimum(start + tokens.shape[1], lengths)
    cache = dict(cache)
    cache["pos"] = cache["pos"].at[lanes].set(pos_after, mode="drop")
    if "cache_pos" in cache:
        sc = cache["cache_pos"].shape[1]
        p = jnp.arange(sc, dtype=jnp.int32)
        rows = jnp.where(p[None, :] < pos_after[:, None], p[None, :], -1)
        cache["cache_pos"] = cache["cache_pos"].at[lanes].set(rows,
                                                              mode="drop")
    cur_logits = cur_logits.at[lanes].set(logits.astype(cur_logits.dtype),
                                          mode="drop")
    return cache, cur_logits, logits


@jax.jit
def fanout_lanes(cache, cur_logits, new_logits, lane_rows, lengths):
    """Fan a completed shared-prefix chunk row's decode-entry state out
    to its K vote lanes: replicate the prompt-last-token logits into
    ``cur_logits`` and set each lane's ``pos`` to the prompt length.

    The prompt K/V itself is NOT copied — the lanes' block tables
    already map the shared prompt blocks (plus their CoW tails, cloned
    separately via :func:`copy_blocks`).  ``lane_rows`` (Nb, Kmax)
    carries the target lanes, ``>= n_lanes`` sentinel beyond a row's
    real lane count or for rows whose prefill is still in flight.
    """
    nb, kmax = lane_rows.shape
    lanes = lane_rows.reshape(-1)
    rows = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), kmax)
    cache = dict(cache)
    cache["pos"] = cache["pos"].at[lanes].set(lengths[rows], mode="drop")
    cur_logits = cur_logits.at[lanes].set(
        new_logits[rows].astype(cur_logits.dtype), mode="drop")
    return cache, cur_logits


@functools.partial(jax.jit, static_argnames=("cfg", "gcfg", "rounds"))
def decode_round(params, cfg: ModelConfig, gcfg: GenConfig, cache,
                 cur_logits, done, key, salts, steps, rounds: int):
    """Decode `rounds` tokens for every lane; done lanes emit pad.

    salts: (B,) per-lane request salt; steps: (B,) per-lane count of
    tokens the lane's request has already generated (both traced, so
    consecutive rounds share one executable).  The token lane i samples
    at scan step t uses ``fold_in(fold_in(key, salts[i]), steps[i]+t)``
    — see the module docstring's PRNG contract.

    Lanes that enter the round done (dead, or parked while their prompt
    is still being chunk-prefilled) keep stepping inside the scan but
    get their ``pos`` (and dense ``cache_pos`` validity) restored
    afterwards: their writes stay confined to the same few
    never-validated slots round after round instead of marching through
    the cache, which is what lets a chunk-prefilling lane ride the
    round harmlessly until its prompt is complete.  Recurrent state
    (``conv``/``ssm`` lane rows) is restored the same way — it is
    CUMULATIVE, so unlike KV slots the phantom steps would corrupt it
    in place, not just scribble on never-read positions.

    Returns (cache, next_logits, done, tokens (B, rounds)).
    """
    done_in = done
    pos_in = cache["pos"]
    cpos_in = cache.get("cache_pos")
    conv_in = cache.get("conv")
    ssm_in = cache.get("ssm")

    def step(carry, t):
        cache, logits, done = carry
        tok = sample_tokens_salted(key, salts, steps + t, logits,
                                   gcfg.temperature, gcfg.top_p)
        tok = jnp.where(done, gcfg.pad_id, tok)
        new_done = done | (tok == gcfg.eos_id)
        next_logits, cache = model_lib.decode_step(params, cfg, tok, cache)
        # keep the carry dtype stable: the scheduler's logits buffer may
        # be wider than the model's compute dtype (sampling upcasts to
        # f32 anyway, so this never changes the drawn token)
        return (cache, next_logits.astype(logits.dtype), new_done), tok

    (cache, logits, done), toks = jax.lax.scan(
        step, (cache, cur_logits, done), jnp.arange(rounds, dtype=jnp.int32))
    cache = dict(cache)
    cache["pos"] = jnp.where(done_in, pos_in, cache["pos"])
    if cpos_in is not None:
        cache["cache_pos"] = jnp.where(done_in[:, None], cpos_in,
                                       cache["cache_pos"])
    if conv_in is not None:
        cache["conv"] = jnp.where(done_in[None, :, None, None], conv_in,
                                  cache["conv"])
        cache["ssm"] = jnp.where(done_in[None, :, None, None, None], ssm_in,
                                 cache["ssm"])
    return cache, logits, done, jnp.swapaxes(toks, 0, 1)


@functools.partial(jax.jit, static_argnames=("cfg", "gcfg", "rounds"))
def decode_round_spec(params, cfg: ModelConfig, gcfg: GenConfig, cache,
                      cur_logits, done, key, salts, steps, draft_toks,
                      draft_len, rounds: int):
    """Speculative decode round: verify up to Kd draft tokens per lane
    in one fused pass (``model.verify_step``), commit the longest
    sequentially-agreeing prefix, then run a normal ``rounds``-token
    decode scan from the post-accept state.

    draft_toks: (B, Kd) draft token ids (pad past ``draft_len``);
    draft_len: (B,) real drafts per lane (0 = the lane rides the round
    undrafted).  Acceptance is exact-match against the *target* stream:
    target i is sampled from the logits after draft i-1 at the lane's
    PRNG index ``steps + i`` — bitwise the token sequential decode
    would emit there, because ``verify_step``'s logits are bitwise
    sequential decode's (its contract) and each target is drawn at
    ``decode_round``'s exact (B, V) sampling geometry.  Greedy
    (temperature <= 0) degenerates to argmax agreement; sampled mode
    stays trace-independent because the per-request salted streams are.
    A committed token therefore IS the token a normal round would have
    emitted — speculation can change wall-clock and round counts but
    never the stream (tests/test_serving_trace.py extends its oracle
    bit-match over drafted traces on exactly this argument).

    Commit/rollback: ``pos`` advances by ``accept``; rejected dense
    draft slots are re-marked empty (``cache_pos`` rewind) while
    rejected paged slots are already unreachable (causally masked until
    the block table grows over them, and the next writes at those
    positions overwrite them first — the standard trash-slot argument).
    The *bonus* token after the accepted prefix is deliberately NOT
    committed: the trailing scan's first sample re-draws it from the
    post-accept logits at the same PRNG index, bit-identically, which
    keeps the accounting one-token-per-scan-step everywhere.

    Lanes done at entry (dead or parked mid-chunk-prefill) ride the
    round exactly as in :func:`decode_round`: draft_len 0, accept 0,
    pos/cache_pos restored at the end.  Recurrent (conv/ssm) caches
    never reach this round: draft rejection would need to rewind
    cumulative state, which has no trash-slot analogue, so the
    scheduler's spec guard keeps SSM-bearing configs on the plain
    rounds (see Scheduler.__init__).

    Returns (cache, next_logits, done, spec_toks (B, Kd), accept (B,),
    toks (B, rounds)) — committed draft-phase tokens are pad-masked
    past ``accept``; the host harvests ``spec_toks[:accept]`` then
    ``toks`` per lane.
    """
    done_in = done
    pos_in = cache["pos"]
    cpos_in = cache.get("cache_pos")
    kd = draft_toks.shape[1]

    ver_logits, cache = model_lib.verify_step(params, cfg, draft_toks, cache,
                                              draft_len=draft_len)
    # Target stream: what sequential decode would emit at each draft
    # slot.  Sampled one slot at a time at decode_round's exact (B, V)
    # geometry — the backend's sampling lowering is only trusted to be
    # bitwise stable at the shape the normal path uses.  Target i is
    # conditioned on logits after draft i-1, valid wherever drafts
    # 0..i-1 matched — the only region acceptance consults.
    tgts = []
    logits_i = cur_logits
    for i in range(kd):
        tgts.append(sample_tokens_salted(key, salts, steps + i, logits_i,
                                         gcfg.temperature, gcfg.top_p))
        if i + 1 < kd:
            logits_i = ver_logits[:, i].astype(cur_logits.dtype)
    tgt = jnp.stack(tgts, axis=1)                                   # (B,Kd)

    idx = jnp.arange(kd, dtype=jnp.int32)[None, :]
    match = ((draft_toks == tgt) & (idx < draft_len[:, None])
             & (~done_in[:, None]))
    accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    committed = idx < accept[:, None]
    spec_toks = jnp.where(committed, tgt, gcfg.pad_id)
    # an EOS inside the accepted prefix finishes the lane; tokens the
    # draft happened to agree on past it are truncated by the host
    # harvest exactly like a normal round's post-EOS pad tail
    done = done_in | jnp.any(committed & (tgt == gcfg.eos_id), axis=1)

    pos_v = pos_in + accept
    cache = dict(cache)
    cache["pos"] = pos_v
    if cpos_in is not None:
        cp = cache["cache_pos"]
        cache["cache_pos"] = jnp.where(cp >= pos_v[:, None], -1, cp)

    # logits after the last accepted draft seed the trailing scan; with
    # accept == 0 that is cur_logits untouched, so an all-rejected (or
    # undrafted) lane's round is bitwise a normal decode_round
    gather = jnp.clip(accept - 1, 0, kd - 1)
    after = jnp.take_along_axis(ver_logits, gather[:, None, None],
                                axis=1)[:, 0]
    logits_v = jnp.where((accept > 0)[:, None],
                         after.astype(cur_logits.dtype), cur_logits)

    def step(carry, t):
        cache, logits, done = carry
        tok = sample_tokens_salted(key, salts, steps + accept + t, logits,
                                   gcfg.temperature, gcfg.top_p)
        tok = jnp.where(done, gcfg.pad_id, tok)
        new_done = done | (tok == gcfg.eos_id)
        next_logits, cache = model_lib.decode_step(params, cfg, tok, cache)
        return (cache, next_logits.astype(logits.dtype), new_done), tok

    (cache, logits, done), toks = jax.lax.scan(
        step, (cache, logits_v, done), jnp.arange(rounds, dtype=jnp.int32))
    cache = dict(cache)
    cache["pos"] = jnp.where(done_in, pos_in, cache["pos"])
    if cpos_in is not None:
        cache["cache_pos"] = jnp.where(done_in[:, None], cpos_in,
                                       cache["cache_pos"])
    return (cache, logits, done, spec_toks, accept.astype(jnp.int32),
            jnp.swapaxes(toks, 0, 1))


# ----------------------------------------------------------------------
# Sharded (multi-device) decode rounds
# ----------------------------------------------------------------------
#
# The scheduler's sharded mode (Scheduler(mesh=...)) runs the SAME round
# bodies under shard_map over the mesh's 1-wide-model "data" axis: each
# shard steps its own lanes_per_shard slice of the lane batch against
# its own slab of the KV pool (distributed/sharding.py
# serving_cache_specs), with params replicated via the param-spec rules.
# The body is data-parallel and collective-free, and per-request PRNG
# salting makes each lane's sample stream depend only on (master key,
# request id, token index) — so the sharded round is BIT-IDENTICAL to
# the single-device one as long as the per-shard batch keeps the >=2-row
# geometry the oracle uses (size-1 batch dims lower reductions
# differently).  Tensor parallelism over a model>1 axis is deliberately
# NOT routed through here: the round body has no collectives, so a
# model-sharded shard_map would silently compute garbage.  Model-axis TP
# composes at the GSPMD level instead — device_put the params to
# param_specs(...) shardings and call the plain jitted rounds
# (tests/test_sharded_serving.py pins that path down to token equality
# and logits-allclose; see docs/architecture.md for why allclose).

# replication checking was renamed check_rep -> check_vma across JAX
# releases; disable it under whichever name this JAX understands.
import inspect as _inspect
_SHARD_MAP_CHECK = {
    ("check_vma" if "check_vma" in _inspect.signature(_shard_map).parameters
     else "check_rep"): False}

_SHARDED_FNS: dict = {}
_SHARDED_PARAMS: dict = {}


def _params_on_mesh(mesh, cfg: ModelConfig, params):
    """device_put the weights to their param-spec shardings ONCE per
    (mesh, params) pair.  Without this every sharded round would
    re-broadcast the weights from their home device at call time, and
    two cascade tiers placed on disjoint slices would serialize through
    that one device's transfer path instead of decoding concurrently.
    The memo holds a reference to the original params so the id() key
    can never be recycled by a new object."""
    key = (mesh, id(params))
    hit = _SHARDED_PARAMS.get(key)
    if hit is not None:
        return hit[1]
    from repro.distributed import sharding as dist_sharding
    pspec = dist_sharding.param_specs(cfg, params, mesh)
    placed = jax.device_put(params, dist_sharding.named(mesh, pspec))
    _SHARDED_PARAMS[key] = (params, placed)
    return placed


def _sharded_round_fn(mesh, cfg: ModelConfig, gcfg: GenConfig, rounds: int,
                      cache_keys: tuple, spec: bool, params):
    """Build (and memoize) the jitted shard_map wrapper for one
    (mesh, model, gen-config, round length, cache layout) combination —
    the sharded analogue of the jit cache the plain rounds get from
    their static argnames."""
    key = (mesh, cfg, gcfg, rounds, cache_keys, spec)
    fn = _SHARDED_FNS.get(key)
    if fn is not None:
        return fn
    if mesh.shape.get("model", 1) != 1:
        raise ValueError(
            "sharded decode rounds are data-parallel only (model axis "
            "must be 1): the round body has no collectives, so a "
            "model-sharded shard_map would compute garbage.  Shard the "
            "params with distributed.sharding.param_specs and call the "
            "plain rounds for tensor parallelism.")
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as dist_sharding
    pspec = dist_sharding.param_specs(cfg, params, mesh)
    cache_spec = dist_sharding.serving_cache_specs(dict.fromkeys(cache_keys))
    if spec:
        def body(params, cache, cur_logits, done, key, salts, steps,
                 draft_toks, draft_len):
            return decode_round_spec.__wrapped__(
                params, cfg, gcfg, cache, cur_logits, done, key, salts,
                steps, draft_toks, draft_len, rounds)
        in_specs = (pspec, cache_spec, P("data"), P("data"), P(),
                    P("data"), P("data"), P("data"), P("data"))
        out_specs = (cache_spec, P("data"), P("data"), P("data"),
                     P("data"), P("data"))
    else:
        def body(params, cache, cur_logits, done, key, salts, steps):
            return decode_round.__wrapped__(
                params, cfg, gcfg, cache, cur_logits, done, key, salts,
                steps, rounds)
        in_specs = (pspec, cache_spec, P("data"), P("data"), P(),
                    P("data"), P("data"))
        out_specs = (cache_spec, P("data"), P("data"), P("data"))
    fn = jax.jit(_shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, **_SHARD_MAP_CHECK))
    _SHARDED_FNS[key] = fn
    return fn


def sharded_decode_round(mesh, params, cfg: ModelConfig, gcfg: GenConfig,
                         cache, cur_logits, done, key, salts, steps,
                         rounds: int):
    """:func:`decode_round` under shard_map over ``mesh``'s data axis.
    Same signature plus the leading mesh; bit-identical outputs."""
    fn = _sharded_round_fn(mesh, cfg, gcfg, rounds, tuple(sorted(cache)),
                           False, params)
    params = _params_on_mesh(mesh, cfg, params)
    return fn(params, cache, cur_logits, done, key, salts, steps)


def sharded_decode_round_spec(mesh, params, cfg: ModelConfig,
                              gcfg: GenConfig, cache, cur_logits, done, key,
                              salts, steps, draft_toks, draft_len,
                              rounds: int):
    """:func:`decode_round_spec` under shard_map over ``mesh``'s data
    axis.  Same signature plus the leading mesh; bit-identical outputs
    (the verify pass reads the cache's LOCAL block tables, which the
    sharded scheduler maintains — see scheduler ``_local_tables``)."""
    fn = _sharded_round_fn(mesh, cfg, gcfg, rounds, tuple(sorted(cache)),
                           True, params)
    params = _params_on_mesh(mesh, cfg, params)
    return fn(params, cache, cur_logits, done, key, salts, steps,
              draft_toks, draft_len)


# cache entries stacked per layer carry the lane axis at position 1
_LAYER_STACKED = ("k", "v", "k_scale", "v_scale", "conv", "ssm")


def _quantize_prefill(cache, new_cache):
    """Bridge a *floating-point* prefilled sub-batch onto a *quantized*
    lane pool: quantize the prompt K/V per (slot, kv-head) and emit the
    matching scale entries.

    Prefill always runs in the compute dtype (quantizing inside the
    prompt pass would make each prompt position attend over the int8
    round-trip of earlier ones, i.e. whole-prefill would stop matching
    itself across buckets); the int8 representation is decided HERE, at
    lane insertion, once per slot — which is also what keeps every
    insert path (dense, paged, shared) writing bit-identical int8
    blocks for the same prompt.
    """
    if "k_scale" not in cache or "k_scale" in new_cache:
        return new_cache
    from repro.models.attention import quantize_kv
    new_cache = dict(new_cache)
    new_cache["k"], new_cache["k_scale"] = quantize_kv(new_cache["k"])
    new_cache["v"], new_cache["v_scale"] = quantize_kv(new_cache["v"])
    return new_cache


@jax.jit
def insert_lanes(cache, cur_logits, new_cache, new_logits, lanes):
    """Scatter a freshly prefilled sub-batch into the global lane pool.

    lanes: (Nb,) int32 target lane per new row; rows padded up to the
    admit bucket carry an out-of-range sentinel (>= n_lanes) and are
    dropped by the scatter.  Quantized pools (``k_scale`` in the cache)
    take fp-prefilled rows: the prompt K/V is quantized at insertion
    (:func:`_quantize_prefill`).
    """
    new_cache = _quantize_prefill(cache, new_cache)
    out = {}
    for name, val in cache.items():
        new = new_cache[name]
        if name in _LAYER_STACKED:
            out[name] = val.at[:, lanes].set(new.astype(val.dtype),
                                             mode="drop")
        else:
            out[name] = val.at[lanes].set(new.astype(val.dtype), mode="drop")
    cur_logits = cur_logits.at[lanes].set(
        new_logits.astype(cur_logits.dtype), mode="drop")
    return out, cur_logits


@jax.jit
def insert_lanes_paged(cache, cur_logits, new_cache, new_logits, lanes,
                       block_rows):
    """Scatter a freshly prefilled sub-batch into the paged lane pool.

    The wave was prefilled *dense* at its prompt bucket (``new_cache``
    K/V are (L, Nb, bucket, KV, Dh)); this writes each row's prompt
    positions into the pool pages its lane was allocated:

        position p of row j  ->  flat slot block_rows[j, p // bs] * bs
                                             + p % bs

    block_rows: (Nb, max_blocks) int32 page ids, trash (0) beyond the
    row's allocation — positions past a row's real blocks (right-pad of
    the bucket, dummy rows padding the admit wave) therefore land in
    the trash block, so no masking is needed;
    lanes: (Nb,) target lane per row, >= n_lanes sentinel on dummy rows
    (dropped by the lane-axis scatters, exactly as in insert_lanes).

    The device block tables are NOT written here: the host owns them
    (serving/block_pool.py) and pushes the full table before the next
    decode round.  Quantized pools take fp-prefilled rows; the prompt
    K/V is quantized at insertion and the scale pages ride the same
    flat-slot scatter (:func:`_quantize_prefill`).
    """
    new_cache = _quantize_prefill(cache, new_cache)
    out = dict(cache)
    if "k" in cache:     # pure-SSM pools have no KV pages to scatter
        L, _, bucket = new_cache["k"].shape[:3]
        pb, bs = cache["k"].shape[1], cache["k"].shape[2]
        p = jnp.arange(bucket, dtype=jnp.int32)
        tgt = (block_rows[:, p // bs] * bs + p[None, :] % bs).reshape(-1)
        for name in ("k", "v", "k_scale", "v_scale"):
            if name not in cache:
                continue
            flat = cache[name].reshape(L, pb * bs, *cache[name].shape[3:])
            new = new_cache[name].reshape(L, -1, *new_cache[name].shape[3:])
            out[name] = flat.at[:, tgt].set(new.astype(flat.dtype)).reshape(
                cache[name].shape)
    for name in ("conv", "ssm"):
        if name in cache:
            out[name] = cache[name].at[:, lanes].set(
                new_cache[name].astype(cache[name].dtype), mode="drop")
    out["pos"] = cache["pos"].at[lanes].set(new_cache["pos"], mode="drop")
    cur_logits = cur_logits.at[lanes].set(
        new_logits.astype(cur_logits.dtype), mode="drop")
    return out, cur_logits


@jax.jit
def insert_lanes_shared(cache, cur_logits, new_cache, new_logits, lane_rows,
                        block_rows):
    """Scatter one prefilled *group* row into the pool once, then fan its
    state out to the group's K lanes.

    ``new_cache`` rows are per group (``prefill_shared``), not per lane:
    row j's prompt K/V is written into the pool exactly once through
    ``block_rows[j]`` (same flat-slot mapping as ``insert_lanes_paged``;
    trash-block (0) entries absorb bucket right-padding, dummy rows, and
    positions whose blocks were satisfied by the scheduler's prefix
    cache — those slots already hold the identical K/V and are left
    untouched so earlier holders keep bit-identical reads).  The
    per-lane state — last-token logits, ``pos``, and any conv/ssm state
    — is *replicated* to every lane of the row:

    lane_rows: (Nb, Kmax) int32 target lanes per row, ``>= n_lanes``
    sentinel beyond a row's real lane count (dropped by the scatters);
    block_rows: (Nb, max_blocks) int32 write-side page ids.

    Host-owned block tables are not written here; each lane's *read*
    table (shared prompt blocks + its private CoW tail) is pushed by the
    scheduler before the next decode round.  Quantized pools take
    fp-prefilled group rows; quantization happens once per shared slot
    at insertion (:func:`_quantize_prefill`), so every lane of the
    group — and every later prefix-cache hit — reads bit-identical
    int8+scale pairs.
    """
    new_cache = _quantize_prefill(cache, new_cache)
    L, _, bucket = new_cache["k"].shape[:3]
    pb, bs = cache["k"].shape[1], cache["k"].shape[2]
    p = jnp.arange(bucket, dtype=jnp.int32)
    tgt = (block_rows[:, p // bs] * bs + p[None, :] % bs).reshape(-1)

    out = dict(cache)
    for name in ("k", "v", "k_scale", "v_scale"):
        if name not in cache:
            continue
        flat = cache[name].reshape(L, pb * bs, *cache[name].shape[3:])
        new = new_cache[name].reshape(L, -1, *new_cache[name].shape[3:])
        out[name] = flat.at[:, tgt].set(new.astype(flat.dtype)).reshape(
            cache[name].shape)

    nb, kmax = lane_rows.shape
    lanes = lane_rows.reshape(-1)                          # (Nb*Kmax,)
    rows = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), kmax)
    for name in ("conv", "ssm"):
        if name in cache:
            out[name] = cache[name].at[:, lanes].set(
                new_cache[name][:, rows].astype(cache[name].dtype),
                mode="drop")
    out["pos"] = cache["pos"].at[lanes].set(new_cache["pos"][rows],
                                            mode="drop")
    cur_logits = cur_logits.at[lanes].set(
        new_logits[rows].astype(cur_logits.dtype), mode="drop")
    return out, cur_logits


@jax.jit
def copy_blocks(cache, src, dst):
    """Clone whole pool blocks: ``k/v[:, dst[i]] <- k/v[:, src[i]]``.

    The device half of copy-on-write (block_pool.BlockPool.cow): when a
    vote lane needs a private copy of the group's last partial prompt
    block, the allocator picks the ids and this kernel moves the bytes.
    Pairs are padded to a bucket with (0, 0) — trash overwriting trash —
    so the compile count stays O(#pair buckets).  Quantized pools clone
    the scale pages alongside their int8 blocks, verbatim — CoW never
    requantizes, so a cloned tail stays bit-identical to its source.
    """
    out = dict(cache)
    for name in ("k", "v", "k_scale", "v_scale"):
        if name in cache:
            out[name] = cache[name].at[:, dst].set(cache[name][:, src])
    return out


# pool entries moved whole-block by offload/restore, in a fixed order so
# the host tuples line up across gather and scatter
_BLOCK_POOL_KEYS = ("k", "v", "k_scale", "v_scale")


@jax.jit
def gather_blocks(cache, ids):
    """Read whole pool blocks out of the paged cache: returns a tuple of
    ``cache[name][:, ids]`` for each pool entry present (``(k, v)`` fp,
    ``(k, v, k_scale, v_scale)`` quantized), each ``(L, n, bs, ...)``.

    The device half of ``BlockPool.offload``: the allocator decides
    which blocks need a host copy, this op pulls their bytes in one
    gather (the caller then ``np.asarray``s the result into host RAM).
    ``ids`` is padded to a bucket with 0 — gathering the trash block —
    so the compile count stays O(#id buckets); the caller slices the
    real prefix off host-side.  Quantized blocks offload as raw
    int8+scale pairs — no dequantization round-trip, so a
    restored block is bit-identical to what was parked.
    """
    return tuple(cache[name][:, ids] for name in _BLOCK_POOL_KEYS
                 if name in cache)


@jax.jit
def scatter_blocks(cache, ids, arrays):
    """Write whole pool blocks back into the paged cache:
    ``cache[name][:, ids[i]] <- arrays[j][i]`` with ``arrays`` ordered
    as :func:`gather_blocks` returns — the device half of
    ``BlockPool.restore`` for blocks without a live device twin.
    Padded with id 0 + junk rows (writes land in the trash block)."""
    out = dict(cache)
    names = [name for name in _BLOCK_POOL_KEYS if name in cache]
    for name, arr in zip(names, arrays):
        out[name] = cache[name].at[:, ids].set(arr.astype(cache[name].dtype))
    return out


def harvest_lengths(toks: np.ndarray, limits: np.ndarray,
                    eos_id: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row harvest length for one decode round: tokens up to and
    including the first EOS that falls inside the row's ``limits[i]``
    budget window, or ``limits[i]`` when none does.

    Returns ``(lengths, eos_found)`` — the vectorized form of the
    scheduler's per-lane truncate-at-EOS-or-budget harvest (one numpy
    pass over the whole round batch instead of a Python loop per lane).

    Edge contract (regression-tested in tests/test_scheduler.py): an
    EOS at position 0 harvests exactly 1 token (the EOS itself); a row
    with zero remaining budget harvests 0 tokens and reports no EOS
    even when its round emitted one (tokens past the budget were never
    owed); limits are clamped to ``[0, round_width]`` so a stale
    negative budget can never produce a negative slice; an empty batch
    (no live rows, or a zero-width round) returns empty/zero arrays
    instead of tripping ``argmax`` on an empty axis.
    """
    b, r = toks.shape
    limits = np.clip(limits, 0, r)
    if r == 0:
        return np.zeros((b,), np.int32), np.zeros((b,), bool)
    pos = np.arange(r, dtype=np.int32)
    eos = (toks == eos_id) & (pos[None, :] < limits[:, None])
    found = eos.any(axis=1)
    lengths = np.where(found, eos.argmax(axis=1) + 1, limits)
    return lengths.astype(np.int32), found


def first_eos_lengths(toks: np.ndarray, eos_id: int) -> np.ndarray:
    """Per-row token count up to and including the first EOS (row width
    if none) — :func:`harvest_lengths` with the limit at full width."""
    limits = np.full((toks.shape[0],), toks.shape[1], np.int32)
    return harvest_lengths(toks, limits, eos_id)[0]
