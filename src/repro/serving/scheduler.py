"""Round-based continuous-batching scheduler.

A fixed pool of ``n_lanes`` decode lanes shares one device cache pytree
(leading lane axis) and advances in lockstep rounds of ``round_tokens``
tokens (``batch.decode_round``).  Between rounds the host:

  1. *admits* pending requests into free lanes — prompts are padded to
     a length bucket and the admission wave to a power-of-two size, so
     prefill compiles O(#buckets x #wave sizes) times total, then the
     prefilled rows are scattered into the pool (``batch.insert_lanes``);
  2. *harvests* the round's tokens per live lane, truncating at EOS or
     the per-request budget and finalizing finished lanes (which frees
     them for the next admission — continuous batching);
  3. consults the ``StopPolicy``: every newly finished request is shown
     to the policy in (gen_len, uid) order, and any vote *group* the
     policy declares decided is killed mid-flight — its still-running
     lanes are evicted with whatever they generated so far and its
     never-admitted requests are dropped.  This is SATER's early stop
     as real freed compute, not token accounting.

Request lifecycle:  pending -> admitted (prefill + lane insert)
  -> decoding (one round at a time) -> finished (EOS | budget)
                                    -> cancelled (group decided)

Determinism: step-t sampling uses fold_in(master_key, t) with t the
*global* round-step counter, shared by all lanes.  A request's tokens
therefore depend on its admission step and the lane-pool width, exactly
like batch composition affects real serving engines.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving.batch import (GenConfig, decode_round, insert_lanes,
                                 make_buckets, pad_token_rows, pick_bucket,
                                 prefill_jit)


@dataclasses.dataclass
class Request:
    """One generation request.  ``group`` ties the K vote lanes of a
    question together for the StopPolicy; ``meta`` rides along to the
    completion (e.g. the confidence level the prompt asked for)."""
    uid: int
    prompt: Optional[str] = None
    tokens: Optional[Sequence[int]] = None   # pre-tokenized alternative
    group: Optional[int] = None
    max_new_tokens: Optional[int] = None     # default: gcfg.max_new_tokens
    meta: Optional[dict] = None


@dataclasses.dataclass
class Completion:
    uid: int
    group: Optional[int]
    tokens: np.ndarray           # generated ids up to & incl. EOS
    gen_len: int                 # == len(tokens)
    text: str
    cancelled: bool              # killed by StopPolicy before finishing
    meta: Optional[dict] = None


class StopPolicy:
    """Hook consulted after every finished request.

    ``observe`` returns the group ids that are now *decided*: the
    scheduler evicts their running lanes and drops their pending
    requests.  The base policy never stops anything.
    """

    def observe(self, completion: Completion) -> Iterable[int]:
        return ()


@dataclasses.dataclass
class SchedStats:
    rounds: int = 0              # decode_round invocations
    lane_rounds: int = 0         # sum over rounds of live lanes
    generated_tokens: int = 0    # tokens actually produced by live lanes
    prefills: int = 0            # prefill executions (admission waves)
    prefill_prompts: int = 0     # real prompts prefetched across waves
    cancelled: int = 0           # requests killed by the StopPolicy
    wall_s: float = 0.0


@dataclasses.dataclass
class _Lane:
    req: Request
    budget: int
    parts: List[np.ndarray] = dataclasses.field(default_factory=list)
    generated: int = 0


class Scheduler:
    def __init__(self, params, cfg: ModelConfig, tokenizer, gcfg: GenConfig,
                 n_lanes: int = 32, round_tokens: int = 16,
                 max_prompt_len: int = 256,
                 buckets: Optional[Sequence[int]] = None,
                 admit_buckets: Optional[Sequence[int]] = None):
        self.params, self.cfg, self.tokenizer, self.gcfg = \
            params, cfg, tokenizer, gcfg
        self.n_lanes = n_lanes
        self.round_tokens = round_tokens
        self.buckets = tuple(sorted(buckets or make_buckets(max_prompt_len)))
        self.admit_buckets = tuple(sorted(admit_buckets or
                                          make_buckets(n_lanes, 1)))
        # cache sized so any prompt bucket + any budget fits one lane
        self.s_max = max(self.buckets) + gcfg.max_new_tokens

    # ------------------------------------------------------------------
    def _encode(self, req: Request) -> List[int]:
        if req.tokens is not None:
            return list(req.tokens)[: max(self.buckets)]
        return self.tokenizer.encode(req.prompt, bos=True)[: max(self.buckets)]

    def _budget(self, req: Request) -> int:
        b = req.max_new_tokens or self.gcfg.max_new_tokens
        return min(b, self.gcfg.max_new_tokens)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], key,
            stop_policy: Optional[StopPolicy] = None
            ) -> Tuple[List[Completion], SchedStats]:
        """Drive every request to completion; returns completions in
        request order plus scheduling statistics."""
        t0 = time.time()
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        stats = SchedStats()
        pending = collections.deque(requests)
        lanes: List[Optional[_Lane]] = [None] * self.n_lanes
        host_done = np.ones((self.n_lanes,), bool)
        cache = model_lib.init_decode_state(self.cfg, self.n_lanes, self.s_max)
        cur_logits = jnp.zeros((self.n_lanes, self.cfg.vocab_size),
                               jnp.float32)
        completions: Dict[int, Completion] = {}
        decided: set = set()
        global_step = 0

        def finalize(i: int, cancelled: bool):
            lane = lanes[i]
            toks = (np.concatenate(lane.parts) if lane.parts
                    else np.zeros((0,), np.int32))
            text = self.tokenizer.decode(toks) if self.tokenizer else ""
            comp = Completion(lane.req.uid, lane.req.group, toks, len(toks),
                              text, cancelled, lane.req.meta)
            completions[lane.req.uid] = comp
            lanes[i] = None
            host_done[i] = True
            if cancelled:
                stats.cancelled += 1
            return comp

        while pending or any(l is not None for l in lanes):
            # ---- admission: fill free lanes from the pending queue ----
            free = [i for i in range(self.n_lanes) if lanes[i] is None]
            wave: List[Request] = []
            while pending and len(wave) < len(free):
                req = pending.popleft()
                if req.group in decided:
                    completions[req.uid] = Completion(
                        req.uid, req.group, np.zeros((0,), np.int32), 0, "",
                        True, req.meta)
                    stats.cancelled += 1
                    continue
                wave.append(req)
            if wave:
                by_bucket: Dict[int, List[Request]] = collections.defaultdict(list)
                enc = {r.uid: self._encode(r) for r in wave}
                for r in wave:
                    by_bucket[pick_bucket(len(enc[r.uid]), self.buckets)
                              ].append(r)
                for bucket in sorted(by_bucket):
                    grp = by_bucket[bucket]
                    admit_n = pick_bucket(len(grp), self.admit_buckets)
                    toks, lens = pad_token_rows([enc[r.uid] for r in grp],
                                                self.gcfg.pad_id, bucket,
                                                admit_n)
                    lane_ids = np.full((admit_n,), self.n_lanes, np.int32)
                    for j, r in enumerate(grp):
                        i = free.pop(0)
                        lane_ids[j] = i
                        lanes[i] = _Lane(r, self._budget(r))
                        host_done[i] = False
                    last, new_cache = prefill_jit(
                        self.params, self.cfg, jnp.asarray(toks),
                        jnp.asarray(lens), self.s_max)
                    cache, cur_logits = insert_lanes(
                        cache, cur_logits, new_cache, last,
                        jnp.asarray(lane_ids))
                    stats.prefills += 1
                    stats.prefill_prompts += len(grp)

            live = [i for i in range(self.n_lanes) if lanes[i] is not None]
            if not live:
                continue           # only decided-group requests were queued

            # ---- one decode round over the whole pool ----
            r = self.round_tokens
            cache, cur_logits, _, toks = decode_round(
                self.params, self.cfg, self.gcfg, cache, cur_logits,
                jnp.asarray(host_done), key, jnp.int32(global_step), r)
            global_step += r
            stats.rounds += 1
            stats.lane_rounds += len(live)
            toks_np = np.asarray(toks)

            # ---- harvest: EOS / budget per live lane ----
            newly: List[int] = []
            for i in live:
                lane = lanes[i]
                take = toks_np[i, : min(r, lane.budget - lane.generated)]
                eos = np.nonzero(take == self.gcfg.eos_id)[0]
                finished = False
                if len(eos):
                    take = take[: int(eos[0]) + 1]
                    finished = True
                lane.parts.append(take)
                lane.generated += len(take)
                stats.generated_tokens += len(take)
                if finished or lane.generated >= lane.budget:
                    newly.append(i)

            # ---- finalize + vote-aware early stop ----
            newly.sort(key=lambda i: (lanes[i].generated, lanes[i].req.uid))
            for i in newly:
                comp = finalize(i, cancelled=False)
                if stop_policy is not None:
                    decided.update(stop_policy.observe(comp))
            if decided:
                for i in range(self.n_lanes):
                    if lanes[i] is not None and lanes[i].req.group in decided:
                        finalize(i, cancelled=True)

        stats.wall_s = time.time() - t0
        return [completions[r.uid] for r in requests], stats
