"""Round-based continuous-batching scheduler, with an optional
block-paged KV cache.

A fixed pool of ``n_lanes`` decode lanes shares one device cache pytree
(leading lane axis) and advances in lockstep rounds of ``round_tokens``
tokens (``batch.decode_round``).  Between rounds the host:

  1. *admits* pending requests into free lanes — prompts are padded to
     a length bucket and the admission wave to a power-of-two size, so
     prefill compiles O(#buckets x #wave sizes) times total, then the
     prefilled rows are scattered into the pool (``batch.insert_lanes``
     or, paged, ``batch.insert_lanes_paged``);
  2. *harvests* the round's tokens per live lane, truncating at EOS or
     the per-request budget and finalizing finished lanes (which frees
     them — and, paged, their cache blocks — for the next admission);
  3. consults the ``StopPolicy``: every newly finished request is shown
     to the policy in (gen_len, uid) order, and any vote *group* the
     policy declares decided is killed mid-flight — its still-running
     lanes are evicted with whatever they generated so far and its
     never-admitted requests are dropped.  This is SATER's early stop
     as real freed compute — and, paged, real freed HBM.

Dense vs paged cache
--------------------
Dense (default): every lane owns ``s_max`` cache slots for its whole
lifetime, so HBM cost is ``n_lanes * s_max`` slots regardless of how
short responses actually are — with SATER's shortest-response training
and vote early stop, most of that is never written.  Paged
(``paged=True``): K/V live in a pool of ``block_size``-slot blocks
(model.init_paged_decode_state) managed by a host-side free-list
allocator (serving/block_pool.py).  A lane admitted with prompt length
P and budget G *reserves* ``ceil((P+G)/bs)`` blocks (so it can always
grow — no preemption needed), *allocates* ``ceil(P/bs)`` for the
prompt, and draws the rest lazily, one round ahead of its decode
position.  Admission blocks while the pool cannot cover a reservation
(``SchedStats.admission_blocked`` counts those waits), and every
finalize — EOS, budget, or a ``StopPolicy`` kill — returns the lane's
blocks to the pool immediately.  Evicted lanes keep stepping inside
the jitted round until their lane is re-admitted; their block-table
rows are re-pointed at the allocator's trash block first, so those
writes land nowhere.

Request lifecycle:  pending -> admitted (prefill + lane insert)
  -> decoding (one round at a time) -> finished (EOS | budget)
                                    -> cancelled (group decided)

Determinism: step-t sampling uses fold_in(master_key, t) with t the
*global* round-step counter, shared by all lanes.  A request's tokens
therefore depend on its admission step and the lane-pool width, exactly
like batch composition affects real serving engines.  The paged cache
reproduces the dense cache's logical slot layout exactly (positions are
contiguous within a lane's block table), so for greedy decoding the
paged scheduler bit-matches the dense one and the one-shot engine
(tests/test_scheduler.py proves both) — on the jnp attention path used
off-TPU; the TPU Pallas paged-attention kernel is allclose to it, not
bit-equal.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving.batch import (GenConfig, decode_round, insert_lanes,
                                 insert_lanes_paged, make_buckets,
                                 pad_token_rows, pick_bucket, prefill_jit)
from repro.serving.block_pool import BlockPool


@dataclasses.dataclass
class Request:
    """One generation request.  ``group`` ties the K vote lanes of a
    question together for the StopPolicy; ``meta`` rides along to the
    completion (e.g. the confidence level the prompt asked for)."""
    uid: int
    prompt: Optional[str] = None
    tokens: Optional[Sequence[int]] = None   # pre-tokenized alternative
    group: Optional[int] = None
    max_new_tokens: Optional[int] = None     # default: gcfg.max_new_tokens
    meta: Optional[dict] = None


@dataclasses.dataclass
class Completion:
    """A finished (or cancelled) request as returned by
    :meth:`Scheduler.run`."""
    uid: int
    group: Optional[int]
    tokens: np.ndarray           # generated ids up to & incl. EOS
    gen_len: int                 # == len(tokens)
    text: str
    cancelled: bool              # killed by StopPolicy before finishing
    meta: Optional[dict] = None


class StopPolicy:
    """Hook consulted after every finished request.

    ``observe`` returns the group ids that are now *decided*: the
    scheduler evicts their running lanes and drops their pending
    requests.  The base policy never stops anything.
    """

    def observe(self, completion: Completion) -> Iterable[int]:
        return ()


@dataclasses.dataclass
class SchedStats:
    """Counters for one :meth:`Scheduler.run` call.

    The cache fields quantify the paged win: ``peak_cache_bytes`` is
    the high-water K/V footprint (for dense, the full static cache; for
    paged, peak blocks in use x block bytes), and ``dense_cache_bytes``
    is what a dense cache at the same lane count pins — their ratio is
    the HBM cut the block pool delivers.
    """
    rounds: int = 0              # decode_round invocations
    lane_rounds: int = 0         # sum over rounds of live lanes
    generated_tokens: int = 0    # tokens actually produced by live lanes
    prefills: int = 0            # prefill executions (admission waves)
    prefill_prompts: int = 0     # real prompts prefetched across waves
    cancelled: int = 0           # requests killed by the StopPolicy
    wall_s: float = 0.0
    admission_blocked: int = 0   # admissions deferred on pool pressure
    pool_blocks: int = 0         # allocatable blocks (paged only)
    peak_blocks_in_use: int = 0  # allocator high-water mark (paged only)
    peak_cache_bytes: int = 0    # peak K/V footprint actually held
    dense_cache_bytes: int = 0   # dense-equivalent K/V footprint


@dataclasses.dataclass
class _Lane:
    req: Request
    budget: int
    parts: List[np.ndarray] = dataclasses.field(default_factory=list)
    generated: int = 0
    # paged bookkeeping
    prompt_len: int = 0
    blocks: List[int] = dataclasses.field(default_factory=list)
    reserved: int = 0            # promised-but-undrawn pool blocks


class Scheduler:
    """Continuous-batching engine over a fixed lane pool.

    Parameters
    ----------
    params, cfg, tokenizer, gcfg:
        Model weights/config, tokenizer (None for pre-tokenized
        requests) and generation settings.
    n_lanes, round_tokens:
        Lane-pool width and decode-round length (the early-stop grain:
        a StopPolicy can kill a group at most ``round_tokens`` tokens
        after the deciding lane finished).
    max_prompt_len, buckets, admit_buckets:
        Prompt-length bucket ladder and admission-wave size ladder;
        compiled shapes are bounded by their product.
    paged, block_size, pool_blocks:
        ``paged=True`` swaps the dense per-lane cache for the
        block-paged pool: ``block_size`` slots per block,
        ``pool_blocks`` allocatable blocks (default: enough for every
        lane at full ``s_max`` — set it lower to trade admission
        concurrency for HBM, the allocator backpressures admission
        instead of overflowing).  Must cover at least one worst-case
        lane (``ceil(s_max / block_size)`` blocks).
    """

    def __init__(self, params, cfg: ModelConfig, tokenizer, gcfg: GenConfig,
                 n_lanes: int = 32, round_tokens: int = 16,
                 max_prompt_len: int = 256,
                 buckets: Optional[Sequence[int]] = None,
                 admit_buckets: Optional[Sequence[int]] = None,
                 paged: bool = False, block_size: int = 32,
                 pool_blocks: Optional[int] = None):
        self.params, self.cfg, self.tokenizer, self.gcfg = \
            params, cfg, tokenizer, gcfg
        self.n_lanes = n_lanes
        self.round_tokens = round_tokens
        self.buckets = tuple(sorted(buckets or make_buckets(max_prompt_len)))
        self.admit_buckets = tuple(sorted(admit_buckets or
                                          make_buckets(n_lanes, 1)))
        # cache sized so any prompt bucket + any budget fits one lane
        self.s_max = max(self.buckets) + gcfg.max_new_tokens
        self.paged = paged
        self.block_size = block_size
        self.pool: Optional[BlockPool] = None    # most recent run's pool
        if paged:
            self.max_blocks = -(-self.s_max // block_size)
            self.pool_blocks = (n_lanes * self.max_blocks
                                if pool_blocks is None else pool_blocks)
            if self.pool_blocks < self.max_blocks:
                raise ValueError(
                    f"pool_blocks={self.pool_blocks} cannot hold one "
                    f"worst-case lane ({self.max_blocks} blocks): admission "
                    "could never make progress")
            # fail fast on configs the paged cache cannot serve
            model_lib.init_paged_decode_state(cfg, 1, self.s_max,
                                              block_size, 1)

    # ------------------------------------------------------------------
    def _encode(self, req: Request) -> List[int]:
        if req.tokens is not None:
            return list(req.tokens)[: max(self.buckets)]
        return self.tokenizer.encode(req.prompt, bos=True)[: max(self.buckets)]

    def _budget(self, req: Request) -> int:
        b = req.max_new_tokens or self.gcfg.max_new_tokens
        return min(b, self.gcfg.max_new_tokens)

    def _reservation(self, prompt_len: int, budget: int) -> int:
        """Blocks a lane may touch over its lifetime: prompt + budget,
        rounded up to whole blocks."""
        return -(-(prompt_len + budget) // self.block_size)

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], key,
            stop_policy: Optional[StopPolicy] = None
            ) -> Tuple[List[Completion], SchedStats]:
        """Drive every request to completion; returns completions in
        request order plus scheduling statistics."""
        t0 = time.time()
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        stats = SchedStats()
        pending = collections.deque(requests)
        lanes: List[Optional[_Lane]] = [None] * self.n_lanes
        host_done = np.ones((self.n_lanes,), bool)
        if self.paged:
            pool = BlockPool(self.pool_blocks, self.block_size)
            self.pool = pool
            cache = model_lib.init_paged_decode_state(
                self.cfg, self.n_lanes, self.s_max, self.block_size,
                self.pool_blocks)
            host_table = np.zeros((self.n_lanes, self.max_blocks), np.int32)
            table_dirty = False
        else:
            pool = None
            cache = model_lib.init_decode_state(self.cfg, self.n_lanes,
                                                self.s_max)
        cur_logits = jnp.zeros((self.n_lanes, self.cfg.vocab_size),
                               jnp.float32)
        completions: Dict[int, Completion] = {}
        decided: set = set()
        # tokenization memo: a pool-blocked head-of-queue request is
        # re-examined every round; encode it once, not once per round
        enc: Dict[int, List[int]] = {}
        global_step = 0

        def finalize(i: int, cancelled: bool):
            nonlocal table_dirty
            lane = lanes[i]
            toks = (np.concatenate(lane.parts) if lane.parts
                    else np.zeros((0,), np.int32))
            text = self.tokenizer.decode(toks) if self.tokenizer else ""
            comp = Completion(lane.req.uid, lane.req.group, toks, len(toks),
                              text, cancelled, lane.req.meta)
            completions[lane.req.uid] = comp
            if self.paged:
                # reclaim immediately: blocks (and the unused tail of the
                # reservation) go back to the pool mid-flight, and the
                # lane's table row points at the trash block so its
                # remaining in-round steps write nowhere
                pool.free(lane.blocks)
                pool.unreserve(lane.reserved)
                lane.blocks, lane.reserved = [], 0
                host_table[i] = 0
                table_dirty = True
            lanes[i] = None
            host_done[i] = True
            if cancelled:
                stats.cancelled += 1
            return comp

        while pending or any(l is not None for l in lanes):
            # ---- admission: fill free lanes from the pending queue ----
            free = [i for i in range(self.n_lanes) if lanes[i] is None]
            wave: List[Request] = []
            while pending and len(wave) < len(free):
                req = pending[0]
                if req.group in decided:
                    pending.popleft()
                    completions[req.uid] = Completion(
                        req.uid, req.group, np.zeros((0,), np.int32), 0, "",
                        True, req.meta)
                    stats.cancelled += 1
                    continue
                if req.uid not in enc:
                    enc[req.uid] = self._encode(req)
                if self.paged:
                    need = self._reservation(max(len(enc[req.uid]), 1),
                                             self._budget(req))
                    if not pool.reserve(need):
                        # pool pressure: leave the queue intact (FIFO)
                        # and retry after the next round frees blocks
                        stats.admission_blocked += 1
                        break
                pending.popleft()
                wave.append(req)
            if wave:
                by_bucket: Dict[int, List[Request]] = collections.defaultdict(list)
                for r in wave:
                    by_bucket[pick_bucket(len(enc[r.uid]), self.buckets)
                              ].append(r)
                for bucket in sorted(by_bucket):
                    grp = by_bucket[bucket]
                    admit_n = pick_bucket(len(grp), self.admit_buckets)
                    toks, lens = pad_token_rows([enc[r.uid] for r in grp],
                                                self.gcfg.pad_id, bucket,
                                                admit_n)
                    lane_ids = np.full((admit_n,), self.n_lanes, np.int32)
                    block_rows = (np.zeros((admit_n, self.max_blocks),
                                           np.int32) if self.paged else None)
                    for j, r in enumerate(grp):
                        i = free.pop(0)
                        lane_ids[j] = i
                        lane = _Lane(r, self._budget(r))
                        if self.paged:
                            lane.prompt_len = max(len(enc[r.uid]), 1)
                            n_pb = -(-lane.prompt_len // self.block_size)
                            lane.blocks = pool.alloc(n_pb)
                            lane.reserved = self._reservation(
                                lane.prompt_len, lane.budget) - n_pb
                            block_rows[j, :n_pb] = lane.blocks
                            host_table[i] = block_rows[j]
                            table_dirty = True
                        lanes[i] = lane
                        host_done[i] = False
                    if self.paged:
                        # prefill dense at the prompt bucket only, then
                        # scatter the rows into their allocated pages
                        last, new_cache = prefill_jit(
                            self.params, self.cfg, jnp.asarray(toks),
                            jnp.asarray(lens), bucket)
                        cache, cur_logits = insert_lanes_paged(
                            cache, cur_logits, new_cache, last,
                            jnp.asarray(lane_ids), jnp.asarray(block_rows))
                    else:
                        last, new_cache = prefill_jit(
                            self.params, self.cfg, jnp.asarray(toks),
                            jnp.asarray(lens), self.s_max)
                        cache, cur_logits = insert_lanes(
                            cache, cur_logits, new_cache, last,
                            jnp.asarray(lane_ids))
                    stats.prefills += 1
                    stats.prefill_prompts += len(grp)

            live = [i for i in range(self.n_lanes) if lanes[i] is not None]
            if not live:
                continue           # only decided-group requests were queued

            # ---- one decode round over the whole pool ----
            r = self.round_tokens
            if self.paged:
                # grow each live lane's block table one round ahead of
                # its decode position (drawn from its reservation, so
                # this can never fail); writes past the budget spill
                # into the trash block by construction
                for i in live:
                    lane = lanes[i]
                    upto = min(lane.prompt_len + lane.generated + r,
                               lane.prompt_len + lane.budget)
                    grow = -(-upto // self.block_size) - len(lane.blocks)
                    if grow > 0:
                        new_ids = pool.alloc(grow)
                        host_table[i, len(lane.blocks):
                                   len(lane.blocks) + grow] = new_ids
                        lane.blocks.extend(new_ids)
                        lane.reserved -= grow
                        table_dirty = True
                if table_dirty:
                    cache["block_tables"] = jnp.asarray(host_table)
                    table_dirty = False
            cache, cur_logits, _, toks = decode_round(
                self.params, self.cfg, self.gcfg, cache, cur_logits,
                jnp.asarray(host_done), key, jnp.int32(global_step), r)
            global_step += r
            stats.rounds += 1
            stats.lane_rounds += len(live)
            toks_np = np.asarray(toks)

            # ---- harvest: EOS / budget per live lane ----
            newly: List[int] = []
            for i in live:
                lane = lanes[i]
                take = toks_np[i, : min(r, lane.budget - lane.generated)]
                eos = np.nonzero(take == self.gcfg.eos_id)[0]
                finished = False
                if len(eos):
                    take = take[: int(eos[0]) + 1]
                    finished = True
                lane.parts.append(take)
                lane.generated += len(take)
                stats.generated_tokens += len(take)
                if finished or lane.generated >= lane.budget:
                    newly.append(i)

            # ---- finalize + vote-aware early stop ----
            newly.sort(key=lambda i: (lanes[i].generated, lanes[i].req.uid))
            for i in newly:
                comp = finalize(i, cancelled=False)
                if stop_policy is not None:
                    decided.update(stop_policy.observe(comp))
            if decided:
                for i in range(self.n_lanes):
                    if lanes[i] is not None and lanes[i].req.group in decided:
                        finalize(i, cancelled=True)

        stats.wall_s = time.time() - t0
        self._cache_stats(stats, cache, pool)
        return [completions[r.uid] for r in requests], stats

    # ------------------------------------------------------------------
    def _cache_stats(self, stats: SchedStats, cache, pool: Optional[BlockPool]):
        """Fill the K/V-footprint fields (see SchedStats)."""
        if not self.cfg.has_attention:
            return
        kv_bytes = cache["k"].nbytes + cache["v"].nbytes
        for s in ("k_scale", "v_scale"):
            if s in cache:
                kv_bytes += cache[s].nbytes
        if self.paged:
            per_block = kv_bytes // (self.pool_blocks + 1)   # incl. trash
            per_slot = per_block // self.block_size
            sc = model_lib.cache_length(self.cfg, self.s_max)
            stats.pool_blocks = self.pool_blocks
            stats.peak_blocks_in_use = pool.peak_in_use
            stats.peak_cache_bytes = per_block * pool.peak_in_use
            stats.dense_cache_bytes = per_slot * sc * self.n_lanes
        else:
            stats.peak_cache_bytes = kv_bytes
            stats.dense_cache_bytes = kv_bytes
