"""Round-based continuous-batching scheduler, with an optional
block-paged KV cache and a streaming serving loop.

Two entry points share all machinery:

  * :meth:`Scheduler.run` — batch-at-once: drive a fixed request list
    to completion (what benchmarks replaying a dataset use);
  * :meth:`Scheduler.loop` -> :class:`ServingLoop` — streaming:
    ``submit()`` admits new requests *between decode rounds* (including
    while earlier requests are mid-flight), ``step()`` advances one
    round and returns that round's completions, ``drain()`` runs the
    backlog dry.  ``run()`` is a thin submit-everything-then-drain
    wrapper over the loop, bit-identical to the batch path.

A fixed pool of ``n_lanes`` decode lanes shares one device cache pytree
(leading lane axis) and advances in lockstep rounds of ``round_tokens``
tokens (``batch.decode_round``).  Between rounds the host:

  1. *admits* pending requests into free lanes — prompts are padded to
     a length bucket and the admission wave to a power-of-two size, so
     prefill compiles O(#buckets x #wave sizes) times total, then the
     prefilled rows are scattered into the pool (``batch.insert_lanes``
     or, paged, ``batch.insert_lanes_paged``);
  2. *harvests* the round's tokens per live lane, truncating at EOS or
     the per-request budget and finalizing finished lanes (which frees
     them — and, paged, their cache blocks — for the next admission);
  3. consults the ``StopPolicy``: every newly finished request is shown
     to the policy in (gen_len, uid) order, and any vote *group* the
     policy declares decided is killed mid-flight — its still-running
     lanes are evicted with whatever they generated so far and its
     never-admitted requests are dropped.  This is SATER's early stop
     as real freed compute — and, paged, real freed HBM.

Dense vs paged cache
--------------------
Dense (default): every lane owns ``s_max`` cache slots for its whole
lifetime, so HBM cost is ``n_lanes * s_max`` slots regardless of how
short responses actually are — with SATER's shortest-response training
and vote early stop, most of that is never written.  Paged
(``paged=True``): K/V live in a pool of ``block_size``-slot blocks
(model.init_paged_decode_state) managed by a host-side free-list
allocator (serving/block_pool.py).  A lane admitted with prompt length
P and budget G *reserves* ``ceil((P+G)/bs)`` blocks (so it can always
grow — no preemption needed), *allocates* ``ceil(P/bs)`` for the
prompt, and draws the rest lazily, one round ahead of its decode
position.  Admission blocks while the pool cannot cover a reservation
(``SchedStats.admission_blocked`` counts those waits), and every
finalize — EOS, budget, or a ``StopPolicy`` kill — returns the lane's
blocks to the pool immediately.  Evicted lanes keep stepping inside
the jitted round until their lane is re-admitted; their block-table
rows are re-pointed at the allocator's trash block first, so those
writes land nowhere.

Shared-prefix vote groups (``share_prefix=True``, paged only)
-------------------------------------------------------------
SATER's K-vote sampling submits the *same* prompt K times per question;
without sharing the scheduler prefills it K times and stores K copies
of its KV.  With ``share_prefix=True``, :class:`RequestGroup` units are
admitted *atomically* (all K lanes or none), prefilled **once** per
group (``batch.prefill_shared``), and the prompt's pool blocks are
mapped read-only into all K block tables — the allocator refcounts
each block (block_pool.BlockPool.share), so a block is freed only when
its last holder dies and a ``VoteEarlyStop`` kill can never double-free
a shared block.  Decode appends collide only in the last, partially
filled prompt block; each lane copy-on-writes it (``BlockPool.cow`` +
``batch.copy_blocks``) before its first decode write, so K lanes cost
one prompt prefill + one shared KV copy + K private tails.  Groups
whose prompts are not token-identical (e.g. RCV's per-lane confidence
headers, which differ from the first token) fall back to per-lane
admission transparently.

On top of group fan-out, a hash-keyed *prefix cache* shares full
prompt blocks across requests: every admitted prompt registers its
block-aligned prefixes, and later admissions whose prompts start with
a registered prefix (same instruction/system header) map the cached
blocks instead of allocating fresh ones — an HBM dedup (the prefill
still computes the prefix, but its writes are routed to the trash
block so earlier holders keep bit-identical reads).  Cache entries
hold refcounts; under pool pressure admission evicts them LRU before
backpressuring.

Chunked prefill (``chunk_size``)
--------------------------------
By default an admission wave prefills each prompt whole, as one jitted
call — a long prompt admitted mid-flight therefore stalls every live
decode lane for its full prefill, exactly where streaming ttft is won
or lost.  With ``chunk_size=C`` set, admission only *assigns* the lane
(and, paged, allocates its prompt blocks); the prompt then streams
through a queue of chunk jobs, ``C`` tokens per step
(``model.prefill_chunk`` appends each chunk's K/V onto the live
cache), interleaved with decode rounds under a per-round
``prefill_budget``.  Jobs advance round-robin (short prompts never
wait for a long one to drain), a parked lane rides the decode round
done-masked until its final chunk lands, and a ``StopPolicy`` kill
mid-prefill frees the lane's blocks like any other eviction.  Shared
groups chunk once per row and fan out (CoW + prefix-cache
registration) only when the row completes.  Chunk attention runs at
the prompt-bucket width, so chunked serving is bit-identical to
whole-prompt serving — for dense, paged, and shared caches, greedy
and sampled (tests/test_serving_trace.py).

Preemption & host offload (``ServingLoop.preempt``/``resume``)
--------------------------------------------------------------
A live lane can be *parked*: its KV pages move to host RAM
(``BlockPool.offload`` + ``batch.gather_blocks``; dense: a row
snapshot), its lane and reservation free immediately, and ``resume``
later restores it into ANY free lane bit-identically — the PRNG
contract keys sampling by (uid, token index), so nothing about lane
index or block ids matters.  With ``Scheduler(auto_preempt=True)``,
admission under pool pressure preempts the coldest preemptible lane
(LRU by last-harvest round; never mid-prefill, never mid-verify, never
the last live member of a vote group) instead of backpressuring, and
parked requests re-admit automatically as blocks free.  Releasing an
unfinished uid cancels it outright (see :meth:`ServingLoop.release`).
See docs/architecture.md "Preemption & host offload".

Request lifecycle:  pending -> admitted (prefill + lane insert;
  chunked: lane parked, prompt streams through chunk jobs)
  -> decoding (one round at a time) -> finished (EOS | budget)
                                    -> cancelled (group decided)
  decoding <-> parked (preempt: KV offloaded to host; resume: restored
  into any free lane, bit-identically)

Determinism: request ``uid``'s step-t sample uses
``fold_in(fold_in(master_key, uid), t)`` (the batch.py PRNG contract),
so a request's tokens depend only on the master key, its uid, its
prompt, and its budget — not on when it was admitted, which lane it
landed in, how wide the pool is, or whether its prompt was prefilled
whole or in chunks.  The paged cache reproduces the dense cache's
logical slot layout exactly (positions are contiguous within a lane's
block table), so the paged scheduler bit-matches the dense one and the
one-shot engine for greedy AND sampled decoding under arbitrary
admission traces (tests/test_scheduler.py and
tests/test_serving_trace.py prove it) — on the jnp attention path used
off-TPU; the TPU Pallas paged-attention kernel is allclose to it, not
bit-equal.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving.batch import (GenConfig, copy_blocks, decode_round,
                                 decode_round_spec, fanout_lanes,
                                 gather_blocks, harvest_lengths, insert_lanes,
                                 insert_lanes_paged, insert_lanes_shared,
                                 make_buckets, pad_token_rows, pick_bucket,
                                 prefill_chunk_jit, prefill_jit,
                                 prefill_shared, scatter_blocks,
                                 sharded_decode_round,
                                 sharded_decode_round_spec)
from repro.models.cache_protocol import cache_protocol
from repro.serving.block_pool import BlockPool, HostBlocks, StateSlotPool


@dataclasses.dataclass
class Request:
    """One generation request.  ``group`` ties the K vote lanes of a
    question together for the StopPolicy; ``meta`` rides along to the
    completion (e.g. the confidence level the prompt asked for)."""
    uid: int
    prompt: Optional[str] = None
    tokens: Optional[Sequence[int]] = None   # pre-tokenized alternative
    group: Optional[int] = None
    max_new_tokens: Optional[int] = None     # default: gcfg.max_new_tokens
    meta: Optional[dict] = None


@dataclasses.dataclass
class RequestGroup:
    """K requests forming one vote group, submitted as a unit.

    With ``share_prefix=True`` the scheduler admits the group
    atomically (all lanes or none) and, when the members' prompts are
    token-identical, prefills the prompt once and maps its KV blocks
    read-only into every member's block table.  Members with differing
    prompts (or a dense / non-sharing scheduler) are admitted as
    independent requests — same results, no sharing.
    """
    requests: List[Request]


@dataclasses.dataclass
class Completion:
    """A finished (or cancelled) request as returned by
    :meth:`Scheduler.run` / :meth:`ServingLoop.step`."""
    uid: int
    group: Optional[int]
    tokens: np.ndarray           # generated ids up to & incl. EOS
    gen_len: int                 # == len(tokens)
    text: str
    cancelled: bool              # killed by StopPolicy before finishing
    meta: Optional[dict] = None
    ttft_s: Optional[float] = None   # submit -> first harvested token
    ttd_s: Optional[float] = None    # submit -> finalize (time-to-decision)


class StopPolicy:
    """Hook consulted after every finished request.

    ``observe`` returns the group ids that are now *decided*: the
    scheduler evicts their running lanes and drops their pending
    requests.  The base policy never stops anything.
    """

    def observe(self, completion: Completion) -> Iterable[int]:
        return ()


@dataclasses.dataclass
class SchedStats:
    """Counters for one :meth:`Scheduler.run` call.

    The cache fields quantify the paged win: ``peak_cache_bytes`` is
    the high-water K/V footprint (for dense, the full static cache; for
    paged, peak blocks in use x block bytes), and ``dense_cache_bytes``
    is what a dense cache at the same lane count pins — their ratio is
    the HBM cut the block pool delivers.
    """
    rounds: int = 0              # decode_round invocations
    lane_rounds: int = 0         # sum over rounds of live lanes
    generated_tokens: int = 0    # tokens actually produced by live lanes
    prefills: int = 0            # prefill executions (admission waves)
    prefill_prompts: int = 0     # real prompt rows prefilled across waves
    prefill_tokens: int = 0      # real prompt tokens prefilled (a shared
    #                              group's prompt counts once, not K times)
    cancelled: int = 0           # requests killed by the StopPolicy
    wall_s: float = 0.0
    admission_blocked: int = 0   # admissions deferred on pool pressure
    pool_blocks: int = 0         # allocatable blocks (paged only)
    peak_blocks_in_use: int = 0  # allocator high-water mark (paged only)
    peak_cache_bytes: int = 0    # peak K/V footprint actually held
    dense_cache_bytes: int = 0   # dense-equivalent K/V footprint
    shared_lanes: int = 0        # lanes fed by another lane's prefill
    cow_copies: int = 0          # partial prompt blocks cloned for CoW
    prefix_hits: int = 0         # prompt rows that reused cached prefix blocks
    prefix_hit_blocks: int = 0   # pool blocks not allocated thanks to the cache
    prefill_chunks: int = 0      # row-chunks processed (chunked prefill only)
    # speculative decoding (spec_k set)
    spec_rounds: int = 0         # rounds that ran the verify path
    drafted_tokens: int = 0      # draft tokens fed to verify rounds
    accepted_draft_tokens: int = 0   # drafts committed by verification
    # preemption + host offload
    preempts: int = 0            # lanes parked (explicit or pool pressure)
    resumes: int = 0             # parked requests restored into a lane
    offload_bytes: int = 0       # K/V + state bytes copied device -> host
    host_blocks_peak: int = 0    # host-pool high-water (paged only)
    # recurrent state slots (paged SSM / hybrid only; cache_protocol)
    state_slots: int = 0         # allocatable per-lane state slots
    peak_state_slots: int = 0    # slot-pool high-water mark
    state_slot_bytes: int = 0    # HBM per slot (conv + SSD, all layers)
    peak_state_bytes: int = 0    # peak_state_slots x state_slot_bytes
    # per-round host/device time breakdown (all entry points)
    sched_s: float = 0.0         # host scheduling: admission, chunk queue,
    #                              table growth, draft staging
    dispatch_s: float = 0.0      # launching jitted rounds (async dispatch)
    harvest_s: float = 0.0       # blocking on round results + finalization
    leak_report: Optional[str] = None   # BlockPool.leak_report() at close()
    #                                     (None: pool drained / dense)


class _PrefixCache:
    """Hash-keyed map from block-aligned prompt-token prefixes to the
    live pool blocks already holding their K/V.

    Every admitted prompt registers all its *full* (block-aligned)
    prompt blocks under every aligned prefix length, so a later prompt
    sharing only the instruction/system header still hits.  Entries
    hold one allocator refcount per block (released on eviction), so a
    cached block survives its last lane — that is the cache's warmth —
    but admission evicts entries LRU whenever the pool cannot cover a
    new reservation, so cached blocks never deadlock admission.  Keys
    are the token tuples themselves: no hash-collision can alias two
    different prefixes onto one block list.
    """

    def __init__(self, pool: BlockPool, block_size: int, max_entries: int):
        self.pool, self.bs, self.cap = pool, block_size, max_entries
        self._entries: "collections.OrderedDict[tuple, List[int]]" = \
            collections.OrderedDict()

    def __len__(self):
        return len(self._entries)

    def lookup(self, toks: Sequence[int]) -> List[int]:
        """Blocks backing the longest registered aligned prefix of
        ``toks`` ([] on miss).  The caller must ``share`` them before
        anything may evict the entry."""
        for m in range(len(toks) // self.bs, 0, -1):
            key = tuple(toks[: m * self.bs])
            blocks = self._entries.get(key)
            if blocks is not None:
                self._entries.move_to_end(key)
                return list(blocks)
        return []

    def register(self, toks: Sequence[int], blocks: List[int]) -> None:
        """Register every aligned prefix of ``toks`` covered by
        ``blocks`` (the prompt's full blocks only — the caller must
        exclude any partially filled tail block, which lanes write)."""
        n_full = min(len(toks) // self.bs, len(blocks))
        for m in range(1, n_full + 1):
            key = tuple(toks[: m * self.bs])
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            self.pool.share(blocks[:m])
            self._entries[key] = list(blocks[:m])
            while len(self._entries) > self.cap:
                self.evict_lru()

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry, releasing its block
        holds.  False when the cache is already empty."""
        if not self._entries:
            return False
        _, blocks = self._entries.popitem(last=False)
        self.pool.free(blocks)
        return True

    def clear(self) -> None:
        while self.evict_lru():
            pass


@dataclasses.dataclass
class _PlanRow:
    """One prefill row planned during shared admission: the prompt, the
    lanes it feeds, and its prompt-block geometry."""
    toks: List[int]
    members: List[Request]
    hit: List[int]               # cached prefix blocks (not yet held)
    n_pb: int                    # ceil(P / block_size) prompt blocks
    n_full: int                  # P // block_size read-only full blocks
    partial: bool                # last prompt block is partially filled
    # placement, set at admission: the data shard whose pool backs the
    # row's blocks, and the lanes assigned to its members (in order)
    shard: int = 0
    lanes: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Lane:
    req: Request
    budget: int
    parts: List[np.ndarray] = dataclasses.field(default_factory=list)
    generated: int = 0
    first_tok_s: Optional[float] = None   # host time of first harvested token
    # paged bookkeeping
    prompt_len: int = 0
    blocks: List[int] = dataclasses.field(default_factory=list)
    reserved: int = 0            # promised-but-undrawn pool blocks
    # recurrent-state slot id (state-paged schedulers; 0 = none).  The
    # bytes live in the lane-indexed conv/ssm arrays — the slot is the
    # accounting handle (admission backpressure, offload, leak audit)
    state_slot: int = 0
    # chunked prefill: False while the lane's prompt is still being
    # chunk-prefilled — the lane rides decode rounds done-masked and
    # joins the decode batch the round its final chunk lands
    ready: bool = True
    # loop round when the lane last harvested a token (or was admitted /
    # resumed) — the pressure policy's LRU coldness key
    last_tok_round: int = 0


@dataclasses.dataclass
class _Parked:
    """A preempted request: everything :meth:`ServingLoop.resume` needs
    to continue it bit-identically in any free lane.

    Because sampling is keyed ``fold_in(fold_in(key, uid), token_index)``
    and the cache state is a pure function of the committed tokens, the
    whole resume payload is the generated-so-far tokens, the decode-entry
    logits row, and the KV pages — nothing about the original lane index
    or block ids needs to survive."""
    req: Request
    budget: int
    parts: List[np.ndarray]
    generated: int
    first_tok_s: Optional[float]
    prompt_len: int
    pos: int                     # decode position (cache["pos"][lane])
    logits_row: np.ndarray       # (vocab,) decode-entry logits
    # hold=True: parked until an explicit resume(); False: the loop
    # auto-resumes it as soon as a lane slot + pool capacity free up
    hold: bool = False
    parked_round: int = 0
    # paged: host handle + block count (bytes live in ServingLoop._host_kv)
    host: Optional[HostBlocks] = None
    n_blocks: int = 0
    # data shard the request was parked from — sharded serving restores
    # it into the same shard (its blocks belong to that shard's slab)
    shard: int = 0
    # dense: the lane's full cache row per layer-stacked entry, plus its
    # cache_pos validity row (copied verbatim — ring-layout safe).
    # State-paged lanes park their conv/ssm rows here too (the KV side,
    # if any, rides the block offload above)
    dense_row: Optional[Dict[str, np.ndarray]] = None
    # state-slot host handle (StateSlotPool.offload; None = no slot)
    state_host: Optional[int] = None


@dataclasses.dataclass
class _PrefillJob:
    """One queued chunk-prefill stream: a prompt being appended onto
    the cache ``chunk_size`` tokens per step (serving loop
    ``_run_prefill_chunks``).  Non-shared jobs feed one lane; a
    shared-prefix group's token-identical members share one job whose
    completed state is fanned out to all K lanes at once."""
    toks: List[int]
    bucket: int                  # prompt bucket == the chunk's attention width
    lanes: List[int]
    lane_objs: List["_Lane"]
    members: List[Request]
    off: int = 0                 # prompt positions already processed
    done: bool = False
    # paged geometry: gather/scatter block rows for the chunk op
    read_row: Optional[np.ndarray] = None
    write_row: Optional[np.ndarray] = None
    # shared-group fan-out state
    shared: bool = False
    prompt_blocks: List[int] = dataclasses.field(default_factory=list)
    n_pb: int = 0
    n_full: int = 0
    partial: bool = False
    cow_reserved: int = 0        # reservation earmarked for CoW tail clones


class Scheduler:
    """Continuous-batching engine over a fixed lane pool.

    Parameters
    ----------
    params, cfg, tokenizer, gcfg:
        Model weights/config, tokenizer (None for pre-tokenized
        requests) and generation settings.
    n_lanes, round_tokens:
        Lane-pool width and decode-round length (the early-stop grain:
        a StopPolicy can kill a group at most ``round_tokens`` tokens
        after the deciding lane finished).
    max_prompt_len, buckets, admit_buckets:
        Prompt-length bucket ladder and admission-wave size ladder;
        compiled shapes are bounded by their product.
    paged, block_size, pool_blocks:
        ``paged=True`` swaps the dense per-lane cache for the pooled
        one, per the model's cache protocol
        (models/cache_protocol.py): attention KV moves into the
        block-paged pool (``block_size`` slots per block,
        ``pool_blocks`` allocatable blocks — default: enough for every
        lane at full ``s_max``; set it lower to trade admission
        concurrency for HBM, the allocator backpressures admission
        instead of overflowing; must cover at least one worst-case
        lane, ``ceil(s_max / block_size)`` blocks), and recurrent
        (SSM) state comes under ``StateSlotPool`` accounting (see
        ``state_slots``).  A pure-SSM model has no KV to page, so its
        ``paged=True`` is slot accounting only; a hybrid gets both.
    state_slots:
        Allocatable recurrent-state slots per shard (paged,
        SSM-bearing models only; default ``n_lanes`` per shard).  A
        lane's conv+SSD state is O(1) in sequence length, so unlike
        KV blocks a slot never grows — sizing ``state_slots`` below
        the lane count makes the state slab (not the lane pool) the
        admission bottleneck, with the same backpressure /
        auto-preempt behavior paged KV lanes get.
    share_prefix, prefix_cache_entries:
        ``share_prefix=True`` (paged only) enables shared-prefix
        serving: RequestGroups are admitted atomically and prefilled
        once, their prompt blocks refcount-shared across the K lanes
        (copy-on-write on the last partial block), plus a
        ``prefix_cache_entries``-entry LRU cache sharing full prompt
        blocks across requests with a common token prefix.
    chunk_size, prefill_budget:
        ``chunk_size`` (a multiple of ``block_size`` when KV is paged,
        and of ``cfg.ssm_chunk`` for SSM-bearing models, so chunk
        starts align with SSD scan boundaries) switches admission to
        *chunked prefill*: prompts are appended onto the cache
        ``chunk_size`` tokens at a time (``model.prefill_chunk``),
        interleaved with decode rounds, so admitting a long prompt
        never stalls live decode lanes for its whole prefill.  ``prefill_budget`` caps
        the *real prompt tokens* each round spends on chunk work (a
        wave of short prompts is priced by its tokens, not by padded
        chunk capacity); ``None`` completes every queued prompt within
        its admission round (whole-prefill latency shape, chunked
        math).
        Chunked and whole-prompt prefill produce bit-identical
        completions (tests/test_serving_trace.py) — chunking changes
        *when* prefill work happens, never what gets generated.
    spec_k:
        Enables speculative verify rounds: requests submitted with
        draft token queues (``ServingLoop.submit(draft_tokens=...)`` /
        ``add_drafts``) verify up to ``spec_k`` queued tokens per round
        in one fused pass (``batch.decode_round_spec``), committing the
        longest prefix agreeing with the request's own sample stream
        and rolling back the rest.  Speculation changes round counts
        and wall-clock, never completions — drafted serving stays
        bit-identical to undrafted serving and to the one-shot oracle
        (tests/test_serving_trace.py).  Attention models only (MoE
        included — dropless decode dispatch is batch-independent):
        rejecting a draft must roll the cache back, which recurrent
        (SSM) state cannot do; dense caches must be non-ring.
    auto_preempt:
        Paged only.  When admission would block on pool pressure, park
        the coldest preemptible lane's KV to host RAM
        (``ServingLoop._preempt_coldest``) instead of backpressuring,
        and re-admit parked requests as blocks free.  Preemption is
        also available explicitly (``ServingLoop.preempt``/``resume``)
        without this flag; either way resumed lanes continue
        bit-identically (the PRNG contract keys sampling by uid and
        token index, never by lane or block layout).
    mesh:
        Multi-device serving.  A ``(data, model)`` jax Mesh with model
        axis 1 (``launch.mesh.make_sim_mesh`` / ``make_tier_mesh``)
        runs every decode round under shard_map over the mesh's data
        axis (``batch.sharded_decode_round``): the lane pool splits
        into ``S = data`` equal shards of ``n_lanes / S`` lanes, and —
        paged — each shard owns a private ``pool_blocks``-block slab of
        the device block axis (``pool_blocks`` becomes PER-SHARD), so
        the decode hot path is collective-free: every lane reads only
        its own shard's blocks.  Admission balances requests across
        shards, shared-prefix units admit atomically into one shard,
        and preempted requests resume into their own shard.  The PRNG
        contract keys sampling by (uid, token index) only, so sharded
        serving is bit-identical to single-device serving
        (tests/test_serving_trace.py sharded mode).  ``n_lanes`` must
        divide by ``S`` with >= 2 lanes per shard (the oracle's
        >=2-row geometry).  A 1-device mesh is honored too — it pins
        execution to that device, which is how cascade tier placement
        (core/cascade_multi.py ``placement=``) puts tiers on disjoint
        device slices.  Model-axis tensor parallelism composes at the
        GSPMD level instead (distributed.sharding.param_specs + the
        plain rounds); passing a model>1 mesh here raises.
    """

    def __init__(self, params, cfg: ModelConfig, tokenizer, gcfg: GenConfig,
                 n_lanes: int = 32, round_tokens: int = 16,
                 max_prompt_len: int = 256,
                 buckets: Optional[Sequence[int]] = None,
                 admit_buckets: Optional[Sequence[int]] = None,
                 paged: bool = False, block_size: int = 32,
                 pool_blocks: Optional[int] = None,
                 share_prefix: bool = False,
                 prefix_cache_entries: int = 256,
                 chunk_size: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 spec_k: Optional[int] = None,
                 auto_preempt: bool = False,
                 state_slots: Optional[int] = None,
                 mesh=None):
        self.params, self.cfg, self.tokenizer, self.gcfg = \
            params, cfg, tokenizer, gcfg
        self.n_lanes = n_lanes
        self.round_tokens = round_tokens
        self.mesh = mesh
        if mesh is not None:
            if "data" not in mesh.shape:
                raise ValueError("Scheduler mesh needs a 'data' axis: the "
                                 "lane pool shards over it")
            if mesh.shape.get("model", 1) != 1:
                raise ValueError(
                    "Scheduler(mesh=...) is data-parallel only (model axis "
                    "must be 1): shard the params with "
                    "distributed.sharding.param_specs for tensor "
                    "parallelism instead")
            n_shards = mesh.shape["data"]
            if n_lanes % n_shards:
                raise ValueError(
                    f"n_lanes={n_lanes} must divide evenly over the mesh's "
                    f"{n_shards} data shards")
            if n_lanes // n_shards < 2:
                raise ValueError(
                    f"n_lanes={n_lanes} over {n_shards} shards leaves "
                    "fewer than 2 lanes per shard; size-1 batch dims "
                    "lower to differently-ordered reductions, breaking "
                    "the bit-match with single-device serving")
        else:
            n_shards = 1
        self.n_shards = n_shards
        self.lanes_per_shard = n_lanes // n_shards
        self.buckets = tuple(sorted(buckets or make_buckets(max_prompt_len)))
        # admission waves pad to at least 2 rows: size-1 batch dims can
        # lower to differently-ordered reductions (ulp-level drift), and
        # wave-size independence is what lets any serving trace bit-match
        # the per-request oracle (tests/test_serving_trace.py)
        self.admit_buckets = tuple(sorted(admit_buckets or
                                          make_buckets(n_lanes,
                                                       min(2, n_lanes))))
        # cache sized so any prompt bucket + any budget fits one lane
        self.s_max = max(self.buckets) + gcfg.max_new_tokens
        self.paged = paged
        # the cache protocol splits "paged" into its two real axes:
        # block-paged attention KV and slot-accounted recurrent state
        # (a pure-SSM model has no KV to page; a hybrid has both)
        proto = cache_protocol(cfg, paged)
        self.kv_paged = proto.paged_attention
        self.state_paged = paged and proto.state_slots
        self.block_size = block_size
        self.pool: Optional[BlockPool] = None    # most recent run's pool
        self.pools: Optional[List[BlockPool]] = None  # per-shard (sharded)
        self.share_prefix = share_prefix
        self.prefix_cache_entries = prefix_cache_entries
        self.prefix_cache: Optional[_PrefixCache] = None  # most recent run's
        if share_prefix and not self.kv_paged:
            raise ValueError(
                "share_prefix requires paged attention KV (paged=True on a "
                "model with attention): sharing is block-table indirection "
                "over the KV block pool, and recurrent (SSM) state cannot "
                "alias — each lane's state diverges from decode step 0")
        self.chunk_size = chunk_size
        self.prefill_budget = prefill_budget
        if chunk_size is not None:
            if chunk_size < 8:
                raise ValueError(
                    f"chunk_size={chunk_size} too small: sub-8 batch dims "
                    "can lower to differently-ordered reductions, breaking "
                    "the chunked == whole-prefill bit-match")
            if cfg.has_ssm and chunk_size % cfg.ssm_chunk:
                raise ValueError(
                    f"chunk_size={chunk_size} must be a multiple of "
                    f"ssm_chunk={cfg.ssm_chunk}: the SSD scan groups its "
                    "reductions per ssm_chunk positions, so a chunk start "
                    "off that grid would regroup them — chunked prefill "
                    "would stop bit-matching whole-prompt prefill")
            if cfg.has_ssm and share_prefix:
                raise ValueError(
                    "chunked prefill with share_prefix does not support "
                    "SSM-bearing models: a shared chunk row carries no "
                    "lane to persist conv/ssm state between chunks, and "
                    "fan-out replicates only pos/logits.  Use share_prefix "
                    "with whole-prompt prefill (insert_lanes_shared "
                    "replicates the state rows), or chunk without sharing")
            if cfg.has_attention:
                from repro.models import attention as attn_mod
                if max(self.buckets) > attn_mod.CHUNKED_THRESHOLD:
                    raise ValueError(
                        f"chunked prefill requires every prompt bucket "
                        f"within the direct-attention threshold "
                        f"({attn_mod.CHUNKED_THRESHOLD}): above it "
                        "whole-prompt prefill switches to online-softmax "
                        "attention, whose reductions are not bitwise "
                        "comparable to the chunk path's")
            if self.kv_paged and chunk_size % block_size:
                raise ValueError(
                    f"chunk_size={chunk_size} must be a multiple of "
                    f"block_size={block_size} so chunks land block-aligned "
                    "in the pool")
            if prefill_budget is not None and prefill_budget < chunk_size:
                raise ValueError(
                    f"prefill_budget={prefill_budget} below "
                    f"chunk_size={chunk_size} could never process a chunk")
        self.spec_k = spec_k
        if spec_k is not None:
            if spec_k < 1:
                raise ValueError(f"spec_k={spec_k} must be >= 1")
            if cfg.has_ssm:
                raise ValueError(
                    "speculative decoding does not support recurrent (SSM) "
                    "state: rejecting a draft must roll the cache back, and "
                    "cumulative conv/ssm state has no trash-slot rollback "
                    "the way attention KV positions do.  Serve this config "
                    "with spec_k=None (MoE and attention-only models keep "
                    "spec support — dropless decode dispatch made MoE "
                    "verify rounds batch-independent)")
            if not paged and \
                    model_lib.cache_length(cfg, self.s_max) != self.s_max:
                raise ValueError(
                    "speculative decoding requires a non-ring dense cache: "
                    "draft writes into a ring slot would overwrite window "
                    "history sequential decode still reads, and a rejected "
                    "draft could not roll that back")
        self.auto_preempt = auto_preempt
        if auto_preempt and not paged:
            raise ValueError("auto_preempt requires paged=True: dense "
                             "admission never blocks on cache memory")
        # ladders bounding compiled shapes of the shared fan-out paths
        # (lanes per prefill row, CoW copy pairs per wave)
        self._fan_buckets = make_buckets(n_lanes, 1)
        if self.kv_paged:
            self.max_blocks = -(-self.s_max // block_size)
            # offload/restore id-list ladder (blocks moved per preempt)
            self._blk_buckets = make_buckets(self.max_blocks, 1)
            # pool_blocks is PER SHARD (n_shards is 1 without a mesh):
            # each shard's lanes allocate from a private slab, so the
            # device block axis totals n_shards * (pool_blocks + 1) rows
            self.pool_blocks = (self.lanes_per_shard * self.max_blocks
                                if pool_blocks is None else pool_blocks)
            if self.pool_blocks < self.max_blocks:
                raise ValueError(
                    f"pool_blocks={self.pool_blocks} cannot hold one "
                    f"worst-case lane ({self.max_blocks} blocks): admission "
                    "could never make progress")
        if state_slots is not None and not self.state_paged:
            raise ValueError(
                "state_slots requires paged=True and an SSM-bearing model: "
                "dense serving keys recurrent state by lane, and attention "
                "KV is accounted in blocks (pool_blocks), not state slots")
        if self.state_paged:
            # per-lane recurrent state is O(1) in sequence length, so a
            # slot never grows — slots are PER SHARD like pool_blocks
            self.state_slots = (self.lanes_per_shard
                                if state_slots is None else state_slots)
            if self.state_slots < 1:
                raise ValueError(
                    f"state_slots={self.state_slots} cannot hold one lane: "
                    "admission could never make progress")
        if paged:
            # fail fast on configs the paged cache cannot serve
            model_lib.init_paged_decode_state(cfg, 1, self.s_max,
                                              block_size, 1)

    # ------------------------------------------------------------------
    def _encode(self, req: Request) -> List[int]:
        if req.tokens is not None:
            return list(req.tokens)[: max(self.buckets)]
        return self.tokenizer.encode(req.prompt, bos=True)[: max(self.buckets)]

    def _budget(self, req: Request) -> int:
        # `is None`, not `or`: an explicit max_new_tokens=0 is a real
        # (zero-token) budget, not a request for the default
        b = (self.gcfg.max_new_tokens if req.max_new_tokens is None
             else req.max_new_tokens)
        return max(0, min(b, self.gcfg.max_new_tokens))

    def _reservation(self, prompt_len: int, budget: int) -> int:
        """Blocks a lane may touch over its lifetime: prompt + budget,
        rounded up to whole blocks."""
        return -(-(prompt_len + budget) // self.block_size)

    def _intake(self, requests) -> Tuple[List, List[int]]:
        """Normalize the submitted mix of Requests and RequestGroups to
        admission units plus the flat uid order of the reply.

        Sharing off (or dense): groups dissolve into their members.
        Sharing on: groups survive as atomic units, chunked to the lane
        pool width (sharded: one SHARD's width — a unit's lanes must
        land in one shard's slab) so an oversized group can still
        admit."""
        units: List = []
        order: List[int] = []
        for r in requests:
            if isinstance(r, RequestGroup):
                order.extend(m.uid for m in r.requests)
                if self.share_prefix:
                    w = self.lanes_per_shard
                    for i in range(0, len(r.requests), w):
                        units.append(RequestGroup(
                            list(r.requests[i:i + w])))
                else:
                    units.extend(r.requests)
            else:
                order.append(r.uid)
                units.append(r)
        return units, order

    def _plan_unit(self, members: List[Request], enc: Dict[int, List[int]],
                   prefix_cache: Optional[_PrefixCache]
                   ) -> Tuple[List[_PlanRow], int]:
        """Lay out one admission unit as prefill rows and price its pool
        reservation.  Token-identical members collapse onto one shared
        row; otherwise every member rows alone (no sharing, still
        atomic).  The reservation covers newly allocated prompt blocks
        (cache hits excluded), every member's decode growth, and one
        CoW clone per extra holder of a partial tail block.

        ``prefix_cache`` is the calling ServingLoop's own cache (not
        the scheduler-level pointer, which only tracks the most recent
        loop): two concurrent loops on one scheduler must never plan
        against each other's pools."""
        toks0 = enc[members[0].uid]
        if len(members) > 1 and all(enc[m.uid] == toks0
                                    for m in members[1:]):
            row_members = [members]
        else:
            row_members = [[m] for m in members]
        rows, need = [], 0
        for ms in row_members:
            toks = enc[ms[0].uid]
            p_len = max(len(toks), 1)
            n_pb = -(-p_len // self.block_size)
            n_full = p_len // self.block_size
            partial = n_full < n_pb
            hit = (prefix_cache.lookup(toks)
                   if prefix_cache is not None else [])
            growth = sum(self._reservation(p_len, self._budget(m)) - n_pb
                         for m in ms)
            need += (n_pb - len(hit)) + growth
            if partial:
                need += len(ms) - 1
            rows.append(_PlanRow(toks=toks, members=ms, hit=hit, n_pb=n_pb,
                                 n_full=n_full, partial=partial))
        return rows, need

    # ------------------------------------------------------------------
    def loop(self, key, stop_policy: Optional[StopPolicy] = None
             ) -> "ServingLoop":
        """Open a streaming serving session over this scheduler's lane
        pool: ``submit()`` requests (including mid-flight, between
        rounds), ``step()`` one decode round at a time, ``drain()`` to
        completion, ``close()`` for the stats.  :meth:`run` is the
        batch-at-once wrapper over the same loop."""
        return ServingLoop(self, key, stop_policy)

    def run(self, requests: Sequence, key,
            stop_policy: Optional[StopPolicy] = None
            ) -> Tuple[List[Completion], SchedStats]:
        """Drive every request (or RequestGroup) to completion; returns
        completions in request order (groups flattened in place) plus
        scheduling statistics.

        Thin wrapper over :class:`ServingLoop` — submit everything up
        front, drain to completion (tests prove this is bit-identical
        to the pre-loop batch scheduler for dense, paged, and
        shared-prefix serving, greedy and sampled)."""
        loop = self.loop(key, stop_policy)
        loop.submit(requests)
        comps = loop.drain()
        return comps, loop.close()

    # ------------------------------------------------------------------
    def _cache_stats(self, stats: SchedStats, cache,
                     pools: Optional[List[BlockPool]]):
        """Fill the K/V-footprint fields (see SchedStats).  Sharded
        loops report aggregates over their per-shard pools (pool_blocks
        = total allocatable, peaks summed per shard)."""
        if not self.cfg.has_attention:
            return
        kv_bytes = cache["k"].nbytes + cache["v"].nbytes
        for s in ("k_scale", "v_scale"):
            if s in cache:
                kv_bytes += cache[s].nbytes
        if self.paged:
            # block axis: one (pool_blocks + 1)-row slab per shard
            per_block = kv_bytes // (self.n_shards * (self.pool_blocks + 1))
            per_slot = per_block // self.block_size
            sc = model_lib.cache_length(self.cfg, self.s_max)
            peak = sum(p.peak_in_use for p in pools)
            stats.pool_blocks = self.pool_blocks * self.n_shards
            stats.peak_blocks_in_use = peak
            stats.peak_cache_bytes = per_block * peak
            stats.dense_cache_bytes = per_slot * sc * self.n_lanes
        else:
            stats.peak_cache_bytes = kv_bytes
            stats.dense_cache_bytes = kv_bytes


class ServingLoop:
    """Incremental serving session over one :class:`Scheduler`'s lane
    pool — the streaming core that :meth:`Scheduler.run` wraps.

    Lifecycle::

        loop = sched.loop(key, stop_policy)
        loop.submit(requests)            # any mix of Request/RequestGroup
        while loop.has_work:
            done = loop.step()           # admit -> one decode round -> harvest
            loop.submit(more)            # mid-flight admission: new work
                                         # fills lanes freed this round
        stats = loop.close()

    ``submit`` may be called at any time between steps: new requests and
    RequestGroups enter the pending queue and are admitted into
    free/evicted lanes at the next step's admission phase, exactly as a
    between-rounds arrival would be in a live serving deployment.  This
    is what converts the scheduler from "replay a fixed batch" into
    "serve a stream" — the pipelined multi-tier cascade
    (``core/cascade_multi.run_cascade_pipelined``) and the Poisson
    arrival loop (``launch/serve.py``) are both built on it.

    ``step`` splits into ``dispatch()`` (admission + launching one
    jitted decode round, non-blocking thanks to JAX async dispatch) and
    ``harvest()`` (block on the round's tokens, truncate at EOS/budget,
    finalize, consult the StopPolicy).  A multi-loop driver can dispatch
    several independent loops' rounds before harvesting any of them, so
    one loop's host-side harvest work overlaps another's device compute.

    Determinism: the master key is fixed for the session and every
    request's sample stream is keyed by its own uid and token index
    (the batch.py PRNG contract), so submitting everything up front and
    draining reproduces ``Scheduler.run`` bit-for-bit — and any other
    admission timing of the same requests produces the same completions
    (dense, paged, and shared-prefix; greedy and sampled — proven in
    tests/test_serving_loop.py and tests/test_serving_trace.py).

    Per-request latency: every submitted uid is timestamped;
    completions carry ``ttft_s`` (submit -> first harvested token) and
    ``ttd_s`` (submit -> finalize), the per-request numbers a serving
    frontend reports.
    """

    def __init__(self, sched: Scheduler, key,
                 stop_policy: Optional[StopPolicy] = None):
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self.sched = sched
        self.key = key
        self.stop_policy = stop_policy
        self.stats = SchedStats()
        self._t0 = time.time()
        self.pending: "collections.deque" = collections.deque()
        self._order: List[int] = []
        self.lanes: List[Optional[_Lane]] = [None] * sched.n_lanes
        self._host_done = np.ones((sched.n_lanes,), bool)
        S = sched.n_shards
        if sched.kv_paged:
            # one pool per data shard, over a private (pool_blocks+1)-row
            # slab of the device block axis.  Block ids are GLOBAL
            # (id_base = s * (pool_blocks + 1)), so every piece of host
            # bookkeeping — lane tables, prefix caches, parked handles —
            # and every GSPMD insert/gather/scatter call site works on
            # them unchanged; only the decode dispatch converts to
            # shard-local ids (_local_tables)
            self.pools: Optional[List[BlockPool]] = [
                BlockPool(sched.pool_blocks, sched.block_size,
                          id_base=s * (sched.pool_blocks + 1))
                for s in range(S)]
            self.pool = self.pools[0] if S == 1 else None
            self.prefix_caches = (
                [_PrefixCache(p, sched.block_size,
                              sched.prefix_cache_entries)
                 for p in self.pools] if sched.share_prefix else None)
            self._host_table = np.zeros((sched.n_lanes, sched.max_blocks),
                                        np.int32)
            self._table_dirty = False
            # per-lane global->local id offset (lane i's shard's slab base)
            self._lane_base = np.repeat(
                np.arange(S, dtype=np.int32) * (sched.pool_blocks + 1),
                sched.lanes_per_shard)[:, None]
        else:
            self.pools = None
            self.pool = None
            self.prefix_caches = None
        self.prefix_cache = (self.prefix_caches[0]
                             if self.prefix_caches and S == 1 else None)
        sched.pool = self.pool
        sched.pools = self.pools
        sched.prefix_cache = self.prefix_cache
        if sched.paged:
            # pure-SSM paged has no KV pool; n_blocks is then unused by
            # init_paged_decode_state (no attention keys in the pytree)
            n_blocks = (S * (sched.pool_blocks + 1) - 1
                        if sched.kv_paged else 1)
            self.cache = model_lib.init_paged_decode_state(
                sched.cfg, sched.n_lanes, sched.s_max, sched.block_size,
                n_blocks)
        else:
            self.cache = model_lib.init_decode_state(sched.cfg, sched.n_lanes,
                                                     sched.s_max)
        if sched.state_paged:
            # recurrent-state slot accounting, one pool per shard like
            # the KV pools.  A slot is one lane's conv tail + SSD state
            # across all layers; the state itself stays lane-indexed
            # dense (O(1) per lane), so the pool tracks occupancy and
            # bytes, not device placement
            slot_bytes = (self.cache["conv"].nbytes
                          + self.cache["ssm"].nbytes) // sched.n_lanes
            self.state_pools: Optional[List[StateSlotPool]] = [
                StateSlotPool(sched.state_slots, slot_bytes,
                              id_base=s * (sched.state_slots + 1))
                for s in range(S)]
        else:
            self.state_pools = None
        self.cur_logits = jnp.zeros((sched.n_lanes, sched.cfg.vocab_size),
                                    jnp.float32)
        self.completions: Dict[int, Completion] = {}
        self.decided: set = set()
        # tokenization memo: a pool-blocked head-of-queue request is
        # re-examined every round; encode it once, not once per round
        self._enc: Dict[int, List[int]] = {}
        # per-lane sample-stream salts (the occupying request's uid);
        # see the batch.py PRNG contract
        self._salts = np.zeros((sched.n_lanes,), np.int32)
        self._emitted: List[Completion] = []
        self._submit_s: Dict[int, float] = {}
        self._released: set = set()
        self._inflight: Optional[Tuple[List[int], object, object]] = None
        self._closed = False
        # chunked prefill: queued prompt-chunk streams (see _PrefillJob)
        self._prefill_q: "collections.deque[_PrefillJob]" = collections.deque()
        # speculative drafts: uid -> (start, tokens) — a proposed
        # continuation of the request's output beginning at generated
        # offset `start` (see add_drafts)
        self._drafts: Dict[int, Tuple[int, List[int]]] = {}
        # preemption: parked requests (uid -> _Parked, insertion order =
        # resume priority) and the host-side KV bytes backing them
        # ((shard, host block id) -> per-pool-entry numpy arrays — (k, v)
        # fp, (k, v, k_scale, v_scale) quantized, paged only — host ids
        # are per-pool counters, so the shard disambiguates)
        self._parked: "collections.OrderedDict[int, _Parked]" = \
            collections.OrderedDict()
        self._host_kv: Dict[Tuple[int, int], Tuple[np.ndarray, ...]] = {}
        self._round_no = 0
        # releases of in-flight uids arriving while a round is dispatched
        # are applied at the next dispatch (the harvest indexes lanes)
        self._cancelq: set = set()
        # streaming hook: called as on_tokens(uid, tokens) from harvest
        # with each batch of newly committed tokens for a live request
        # (launch/async_serve.py feeds per-client queues from it)
        self.on_tokens = None

    # -- shard helpers (n_shards is 1 without a mesh) -------------------
    def _shard_of(self, i: int) -> int:
        """Data shard owning lane ``i``."""
        return i // self.sched.lanes_per_shard

    def _pool(self, i: int) -> BlockPool:
        """The block pool lane ``i`` allocates from."""
        return self.pools[i // self.sched.lanes_per_shard]

    def _state_pool(self, i: int) -> StateSlotPool:
        """The state-slot pool lane ``i`` allocates from."""
        return self.state_pools[i // self.sched.lanes_per_shard]

    def _prefix_cache_of(self, s: int) -> Optional["_PrefixCache"]:
        return self.prefix_caches[s] if self.prefix_caches else None

    def _free_by_shard(self) -> List[List[int]]:
        """Free lane ids grouped by shard, ascending within each."""
        out: List[List[int]] = [[] for _ in range(self.sched.n_shards)]
        for i in range(self.sched.n_lanes):
            if self.lanes[i] is None:
                out[self._shard_of(i)].append(i)
        return out

    def _shard_order(self, free_by: List[List[int]]) -> List[int]:
        """Shards with free lanes, most-free first (ties: lowest id) —
        the admission balance policy."""
        return sorted((s for s in range(self.sched.n_shards) if free_by[s]),
                      key=lambda s: (-len(free_by[s]), s))

    def _local_tables(self) -> np.ndarray:
        """Per-shard-local block tables for the shard_map'd decode
        round: each shard's slab starts at s * (pool_blocks + 1), so a
        global id maps to (id - base); 0 (trash) maps to the shard's
        own local trash row."""
        return np.where(self._host_table > 0,
                        self._host_table - self._lane_base,
                        0).astype(np.int32)

    # -- submission ----------------------------------------------------
    def submit(self, requests: Sequence,
               draft_tokens: Optional[Dict[int, Sequence[int]]] = None
               ) -> None:
        """Queue Requests / RequestGroups for admission at the next
        step.  Callable any time before :meth:`close` — including while
        earlier requests are still decoding (mid-flight admission).

        ``draft_tokens`` maps uids to speculative draft continuations
        (requires ``Scheduler(spec_k=...)``): e.g. a rejected cascade
        tier's completion submitted as the next tier's draft, verified
        ``spec_k`` tokens per round instead of decoded one by one."""
        units, order = self.sched._intake(requests)
        now = time.time()
        for uid in order:
            self._order.append(uid)
            self._submit_s[uid] = now
        self.pending.extend(units)
        if draft_tokens:
            for uid, toks in draft_tokens.items():
                self.add_drafts(uid, toks)

    def add_drafts(self, uid: int, tokens: Sequence[int],
                   start: int = 0) -> None:
        """Queue a draft continuation for request ``uid``: ``tokens``
        proposes its output from generated-token offset ``start``
        onward (0 = from the first generated token).  Replaces any
        queue the uid already had — a draft-SLM driver re-drafts from
        the request's current :meth:`progress` each burst.  Each round
        feeds up to ``spec_k`` tokens starting at the lane's current
        position; a queue the real stream has diverged from is dropped
        automatically (every token after a rejected draft was
        conditioned on it, so none of them can be worth verifying)."""
        if self.sched.spec_k is None:
            raise ValueError("draft tokens require Scheduler(spec_k=...)")
        toks = [int(t) for t in tokens]
        if toks:
            self._drafts[uid] = (start, toks)

    def progress(self, uid: int) -> Optional[np.ndarray]:
        """Tokens request ``uid`` has generated so far: a live lane's
        committed output, a finished request's full completion, or
        None when the uid is still pending (or unknown).  The hook a
        draft-SLM driver uses to build its next draft prompt."""
        for lane in self.lanes:
            if lane is not None and lane.req.uid == uid:
                return (np.concatenate(lane.parts) if lane.parts
                        else np.zeros((0,), np.int32))
        parked = self._parked.get(uid)
        if parked is not None:
            return (np.concatenate(parked.parts) if parked.parts
                    else np.zeros((0,), np.int32))
        comp = self.completions.get(uid)
        return comp.tokens if comp is not None else None

    @property
    def has_work(self) -> bool:
        """True while any request is pending, admitted, parked, or in
        flight."""
        return (bool(self.pending) or self._inflight is not None
                or bool(self._parked)
                or any(l is not None for l in self.lanes))

    def live_groups(self) -> set:
        """Group ids with at least one lane currently decoding or
        parked."""
        return ({l.req.group for l in self.lanes
                 if l is not None and l.req.group is not None}
                | {p.req.group for p in self._parked.values()
                   if p.req.group is not None})

    def parked_uids(self) -> List[int]:
        """Uids currently parked in host RAM, oldest first."""
        return list(self._parked)

    # -- preemption: park / resume -------------------------------------
    def preempt(self, uid: int, hold: bool = True) -> None:
        """Park a live request: its lane is freed (paged: its KV blocks
        move to host RAM via ``BlockPool.offload``; dense: its cache row
        is snapshotted) and the request waits in the parked set.  With
        ``hold=True`` (default) it stays parked until an explicit
        :meth:`resume`; ``hold=False`` lets the loop re-admit it
        automatically once a lane and pool capacity free up — the
        pressure policy's mode.

        A lane still mid-chunk-prefill has generated nothing and
        consumed no PRNG, so preempting it abandons the partial prefill
        and requeues the request at the head of the pending queue
        instead of offloading half-written state.

        Resume is bit-exact wherever the request lands: sampling is
        keyed ``fold_in(fold_in(key, uid), token_index)``, so the next
        token depends only on the committed tokens and logits carried in
        the parked record, never on the lane index or block ids."""
        if self._inflight is not None:
            raise RuntimeError("preempt() with a round in flight; "
                               "harvest() first")
        for i, lane in enumerate(self.lanes):
            if lane is not None and lane.req.uid == uid:
                break
        else:
            raise KeyError(f"preempt: uid {uid} has no live lane")
        if not lane.ready:
            self._requeue_prefilling(i)
        else:
            self._preempt_lane(i, hold)

    def resume(self, uid: int) -> bool:
        """Restore a parked request into a free lane now.  Returns False
        when no lane slot or pool capacity is available — the request
        stays parked but is marked auto-resumable, so the loop restores
        it as soon as capacity frees."""
        if self._inflight is not None:
            raise RuntimeError("resume() with a round in flight; "
                               "harvest() first")
        parked = self._parked.get(uid)
        if parked is None:
            raise KeyError(f"resume: uid {uid} is not parked")
        if self._restore_parked(uid):
            return True
        parked.hold = False
        return False

    # -- the streaming core --------------------------------------------
    def step(self, key=None) -> List[Completion]:
        """Admission + one decode round + harvest.  Returns every
        request finalized by this step (finished, killed by the
        StopPolicy, or dropped before admission because its group was
        already decided).  ``key``, if given, replaces the session
        master key before the round (pass the same key every step to
        reproduce a one-shot :meth:`Scheduler.run`)."""
        if key is not None:
            self.key = (jax.random.PRNGKey(key) if isinstance(key, int)
                        else key)
        if self.dispatch():
            return self.harvest()
        return self._take_emitted()

    def drain(self) -> List[Completion]:
        """Step until every submitted request has completed; returns
        all completions in submission order (skipping any a streaming
        consumer already released).  Parked requests are resumed —
        drain means run everything, so explicit holds are lifted."""
        for parked in self._parked.values():
            parked.hold = False
        while self.has_work:
            self.step()
        return [self.completions[uid] for uid in self._order
                if uid in self.completions]

    def take_completed(self) -> List[Completion]:
        """Completions finalized since the last step() /
        take_completed() call — notably those an in-flight round
        produced under close()."""
        return self._take_emitted()

    def release(self, uids: Iterable[int]) -> None:
        """Drop the retained Completion records (token arrays included)
        for delivered requests.  A long-lived streaming consumer that
        takes its results from step()'s return values should release
        them afterwards so session memory stays bounded by the lane
        pool (plus one int per decided vote group, which must be
        remembered to drop late submissions), not by total requests
        served.  drain() returns only unreleased completions, so batch
        (:meth:`Scheduler.run`) callers never release.

        Releasing an *unfinished* uid cancels it — the client went away
        (launch/async_serve.py maps stream cancellation here): a pending
        uid is dropped at admission, a decoding or mid-prefill lane is
        finalized cancelled with its blocks freed, a parked record drops
        its host blocks.  If a round is in flight the cancellation is
        applied at the next dispatch — within one round."""
        for uid in uids:
            self.completions.pop(uid, None)
            self._submit_s.pop(uid, None)
            self._enc.pop(uid, None)
            self._drafts.pop(uid, None)
            self._released.add(uid)
            if self._inflight is not None:
                self._cancelq.add(uid)
            else:
                self._cancel_live(uid)
        # amortized O(1) compaction of the submission-order log
        if len(self._released) > max(64, len(self._order) // 2):
            self._order = [u for u in self._order
                           if u not in self._released]
            self._released.clear()

    def close(self) -> SchedStats:
        """Finalize the session: release prefix-cache block holds (the
        pool drains to empty once every lane is done — leak checks rely
        on this) and fill the wall-clock / cache-footprint stats.
        Idempotent; does not force-drain outstanding work."""
        if self._closed:
            return self.stats
        self._closed = True
        if self._inflight is not None:
            # finalize the in-flight round without dropping its results:
            # they stay claimable via take_completed() / completions
            self._emitted = self.harvest()
        if self.prefix_caches is not None:
            for pc in self.prefix_caches:
                pc.clear()
        self.stats.wall_s = time.time() - self._t0
        self.sched._cache_stats(self.stats, self.cache, self.pools)
        if self.pools is not None:
            self.stats.cow_copies = sum(p.cow_copies for p in self.pools)
            self.stats.host_blocks_peak = sum(p.host_blocks_peak
                                              for p in self.pools)
            # leak audit at shutdown: None means every shard's pool
            # drained; a report string means blocks/reservations are
            # still held (a real leak, or close() before the backlog
            # drained) — launch/serve.py surfaces it in the summary
            reports = [(s, p.leak_report())
                       for s, p in enumerate(self.pools)]
            reports = [f"shard {s}: {r}" if len(self.pools) > 1 else r
                       for s, r in reports if r is not None]
            self.stats.leak_report = ("; ".join(reports)
                                      if reports else None)
        if self.state_pools is not None:
            sp = self.state_pools
            self.stats.state_slots = self.sched.state_slots * len(sp)
            self.stats.peak_state_slots = sum(p.peak_in_use for p in sp)
            self.stats.state_slot_bytes = sp[0].slot_bytes
            self.stats.peak_state_bytes = sum(p.peak_state_bytes
                                              for p in sp)
            # the state-slot pools get the same shutdown leak audit as
            # the block pools; reports from both are joined
            reports = [(s, p.leak_report()) for s, p in enumerate(sp)]
            reports = [f"state shard {s}: {r}" if len(sp) > 1
                       else f"state: {r}"
                       for s, r in reports if r is not None]
            if reports:
                joined = "; ".join(reports)
                self.stats.leak_report = (
                    joined if self.stats.leak_report is None
                    else f"{self.stats.leak_report}; {joined}")
        return self.stats

    # -- split-phase step: dispatch / harvest --------------------------
    def dispatch(self) -> bool:
        """Admission phase + launch one decode round without blocking
        on its result (JAX async dispatch).  Returns False when no lane
        is live after admission (nothing to decode — any decided-group
        drops are waiting in the emitted buffer).

        When any live lane has queued drafts the round runs the
        speculative verify path (``decode_round_spec``); undrafted
        lanes ride it bit-identically to a plain round (draft_len 0),
        so only two round executables ever compile."""
        if self._inflight is not None:
            raise RuntimeError("dispatch() with a round already in flight")
        t0 = time.time()
        self._round_no += 1
        if self._cancelq:
            # releases that arrived while the previous round was in
            # flight: applied before admission, i.e. within one round
            uids, self._cancelq = self._cancelq, set()
            for uid in uids:
                self._cancel_live(uid)
        if self._parked:
            # resume before admitting: parked requests were admitted
            # once already, so they outrank the pending queue
            self._try_resumes()
        if self.sched.share_prefix:
            self._admit_shared()
        else:
            self._admit()
        if self.sched.chunk_size is not None:
            # spend the round's prefill budget before launching decode:
            # lanes whose final chunk lands this pass decode this round
            self._run_prefill_chunks()
        live = [i for i in range(self.sched.n_lanes)
                if self.lanes[i] is not None and self.lanes[i].ready]
        if not live:
            self.stats.sched_s += time.time() - t0
            return False
        r = self.sched.round_tokens
        fed = self._stage_drafts(live) if self.sched.spec_k else {}
        if self.sched.kv_paged:
            # grow each live lane's block table one round ahead of its
            # decode position — plus its draft window, whose verify
            # writes land at positions pos..pos+draft_len-1 — (drawn
            # from its reservation, so this can never fail); writes
            # past the budget spill into the trash block by
            # construction
            for i in live:
                lane = self.lanes[i]
                dlen = fed[i][1] if i in fed else 0
                upto = min(lane.prompt_len + lane.generated + dlen + r,
                           lane.prompt_len + lane.budget)
                grow = -(-upto // self.sched.block_size) - len(lane.blocks)
                if grow > 0:
                    new_ids = self._pool(i).alloc(grow)
                    self._host_table[i, len(lane.blocks):
                                     len(lane.blocks) + grow] = new_ids
                    lane.blocks.extend(new_ids)
                    lane.reserved -= grow
                    self._table_dirty = True
            if self._table_dirty:
                # sharded rounds read per-shard LOCAL tables (each shard
                # sees only its own slab of the block axis); all other
                # call sites (GSPMD inserts/gathers) use global ids
                tbl = (self._local_tables() if self.sched.mesh is not None
                       else self._host_table)
                self.cache["block_tables"] = jnp.asarray(tbl)
                self._table_dirty = False
        steps = np.array([0 if l is None else l.generated
                          for l in self.lanes], np.int32)
        if fed:
            kd = self.sched.spec_k
            draft_mat = np.full((self.sched.n_lanes, kd),
                                self.sched.gcfg.pad_id, np.int32)
            dlen_arr = np.zeros((self.sched.n_lanes,), np.int32)
            for i, (off, n) in fed.items():
                _, dtoks = self._drafts[self.lanes[i].req.uid]
                draft_mat[i, :n] = dtoks[off: off + n]
                dlen_arr[i] = n
                self.stats.drafted_tokens += n
            t1 = time.time()
            self.stats.sched_s += t1 - t0
            if self.sched.mesh is not None:
                self.cache, self.cur_logits, _, spec_toks, accept, toks = \
                    sharded_decode_round_spec(
                        self.sched.mesh, self.sched.params, self.sched.cfg,
                        self.sched.gcfg, self.cache, self.cur_logits,
                        jnp.asarray(self._host_done), self.key,
                        jnp.asarray(self._salts), jnp.asarray(steps),
                        jnp.asarray(draft_mat), jnp.asarray(dlen_arr), r)
            else:
                self.cache, self.cur_logits, _, spec_toks, accept, toks = \
                    decode_round_spec(
                        self.sched.params, self.sched.cfg, self.sched.gcfg,
                        self.cache, self.cur_logits,
                        jnp.asarray(self._host_done), self.key,
                        jnp.asarray(self._salts), jnp.asarray(steps),
                        jnp.asarray(draft_mat), jnp.asarray(dlen_arr), r)
            self.stats.spec_rounds += 1
            spec = (spec_toks, accept, fed)
        else:
            t1 = time.time()
            self.stats.sched_s += t1 - t0
            if self.sched.mesh is not None:
                self.cache, self.cur_logits, _, toks = sharded_decode_round(
                    self.sched.mesh, self.sched.params, self.sched.cfg,
                    self.sched.gcfg, self.cache, self.cur_logits,
                    jnp.asarray(self._host_done), self.key,
                    jnp.asarray(self._salts), jnp.asarray(steps), r)
            else:
                self.cache, self.cur_logits, _, toks = decode_round(
                    self.sched.params, self.sched.cfg, self.sched.gcfg,
                    self.cache, self.cur_logits, jnp.asarray(self._host_done),
                    self.key, jnp.asarray(self._salts), jnp.asarray(steps), r)
            spec = None
        self.stats.rounds += 1
        self.stats.lane_rounds += len(live)
        self._inflight = (live, toks, spec)
        self.stats.dispatch_s += time.time() - t1
        return True

    def _stage_drafts(self, live: List[int]) -> Dict[int, Tuple[int, int]]:
        """Pick the draft window each live lane verifies this round:
        lane i gets ``(offset, count)`` into its uid's queued
        continuation — the tokens at its current generated position,
        capped by ``spec_k`` and its remaining budget.  Queues the
        stream has already moved past are dropped here."""
        fed: Dict[int, Tuple[int, int]] = {}
        kd = self.sched.spec_k
        for i in live:
            lane = self.lanes[i]
            entry = self._drafts.get(lane.req.uid)
            if entry is None:
                continue
            start, dtoks = entry
            off = lane.generated - start
            if off < 0 or off >= len(dtoks):
                self._drafts.pop(lane.req.uid, None)
                continue
            n = min(len(dtoks) - off, kd, lane.budget - lane.generated)
            if n > 0:
                fed[i] = (off, n)
        return fed

    def harvest(self) -> List[Completion]:
        """Block on the dispatched round, truncate each live lane's
        tokens at EOS / budget, finalize finished lanes, consult the
        StopPolicy, and return this step's completions.

        A speculative round's lane emits its committed draft prefix
        (``accept`` tokens) followed by the round's scan tokens — up to
        ``spec_k + round_tokens`` per harvest — truncated at EOS /
        budget exactly like a plain round's."""
        if self._inflight is None:
            return self._take_emitted()
        t0 = time.time()
        live, toks, spec = self._inflight
        self._inflight = None
        toks_np = np.asarray(toks)             # blocks on the device round
        now = time.time()
        r = self.sched.round_tokens
        lanes = self.lanes
        accept_of: Dict[int, int] = {}
        if spec is None:
            rows = toks_np[live]
            limits = np.array([min(r, lanes[i].budget - lanes[i].generated)
                               for i in live], np.int32)
        else:
            spec_dev, accept_dev, fed = spec
            spec_np = np.asarray(spec_dev)
            accept_np = np.asarray(accept_dev)
            kd = self.sched.spec_k
            rows = np.full((len(live), kd + r), self.sched.gcfg.pad_id,
                           np.int32)
            limits = np.empty((len(live),), np.int32)
            for j, i in enumerate(live):
                acc = int(accept_np[i]) if i in fed else 0
                accept_of[i] = acc
                rows[j, :acc] = spec_np[i, :acc]
                rows[j, acc: acc + r] = toks_np[i]
                limits[j] = min(acc + r,
                                lanes[i].budget - lanes[i].generated)
        lengths, eos_found = harvest_lengths(rows, limits,
                                             self.sched.gcfg.eos_id)
        newly: List[int] = []
        for j, i in enumerate(live):
            lane = lanes[i]
            n = int(lengths[j])
            if spec is not None:
                self._advance_drafts(lane, rows[j, :n])
                self.stats.accepted_draft_tokens += \
                    min(accept_of.get(i, 0), n)
            if lane.generated == 0 and n > 0 and lane.first_tok_s is None:
                lane.first_tok_s = now
            lane.parts.append(rows[j, :n])
            lane.generated += n
            self.stats.generated_tokens += n
            if n > 0:
                lane.last_tok_round = self._round_no
                if self.on_tokens is not None:
                    self.on_tokens(lane.req.uid, rows[j, :n])
            if eos_found[j] or lane.generated >= lane.budget:
                newly.append(i)

        # finalize + vote-aware early stop, in (gen_len, uid) order
        newly.sort(key=lambda i: (lanes[i].generated, lanes[i].req.uid))
        for i in newly:
            comp = self._finalize(i, cancelled=False)
            if self.stop_policy is not None:
                self.decided.update(self.stop_policy.observe(comp))
        if self.decided:
            for i in range(self.sched.n_lanes):
                if lanes[i] is not None and lanes[i].req.group in self.decided:
                    self._finalize(i, cancelled=True)
            for uid in [u for u, p in self._parked.items()
                        if p.req.group in self.decided]:
                # a parked member of a decided group will never resume:
                # drop its host blocks now, not at close
                self._finalize_parked(uid, cancelled=True)
        out = self._take_emitted()
        self.stats.harvest_s += time.time() - t0
        return out

    def _advance_drafts(self, lane: _Lane, emitted: np.ndarray) -> None:
        """Advance / invalidate the lane's draft queue against the
        tokens its round actually emitted (called before ``generated``
        moves): a queue the real stream diverged from is stale —
        everything after the divergence was conditioned on a rejected
        token — and an exhausted queue is dropped."""
        entry = self._drafts.get(lane.req.uid)
        if entry is None:
            return
        start, dtoks = entry
        off = lane.generated - start
        if off < 0:
            return
        m = min(len(emitted), len(dtoks) - off)
        if (list(emitted[:m]) != dtoks[off: off + m]
                or off + m >= len(dtoks)):
            self._drafts.pop(lane.req.uid, None)

    # -- internals -----------------------------------------------------
    def _take_emitted(self) -> List[Completion]:
        out, self._emitted = self._emitted, []
        return out

    def _latency(self, uid: int, first_tok_s: Optional[float], now: float):
        sub = self._submit_s.get(uid)
        if sub is None:
            return None, None
        return ((first_tok_s - sub if first_tok_s is not None else None),
                now - sub)

    def _finalize(self, i: int, cancelled: bool) -> Completion:
        lane = self.lanes[i]
        toks = (np.concatenate(lane.parts) if lane.parts
                else np.zeros((0,), np.int32))
        text = self.sched.tokenizer.decode(toks) if self.sched.tokenizer else ""
        ttft, ttd = self._latency(lane.req.uid, lane.first_tok_s, time.time())
        comp = Completion(lane.req.uid, lane.req.group, toks, len(toks),
                          text, cancelled, lane.req.meta,
                          ttft_s=ttft, ttd_s=ttd)
        if lane.req.uid not in self._released:
            # a released (cancelled) uid's client is gone: don't retain
            # or emit a record nobody will claim
            self.completions[lane.req.uid] = comp
        if self.sched.kv_paged:
            # reclaim immediately: blocks (and the unused tail of the
            # reservation) go back to the pool mid-flight, and the
            # lane's table row points at the trash block so its
            # remaining in-round steps write nowhere
            self._pool(i).free(lane.blocks)
            self._pool(i).unreserve(lane.reserved)
            lane.blocks, lane.reserved = [], 0
            self._host_table[i] = 0
            self._table_dirty = True
        if self.sched.state_paged:
            self._state_pool(i).free(lane.state_slot)
        self.lanes[i] = None
        self._host_done[i] = True
        self._submit_s.pop(lane.req.uid, None)
        self._drafts.pop(lane.req.uid, None)
        if cancelled:
            self.stats.cancelled += 1
        if lane.req.uid not in self._released:
            self._emitted.append(comp)
        return comp

    def _drop_decided(self, members: List[Request]) -> None:
        now = time.time()
        for m in members:
            _, ttd = self._latency(m.uid, None, now)
            comp = Completion(m.uid, m.group, np.zeros((0,), np.int32), 0,
                              "", True, m.meta, ttft_s=None, ttd_s=ttd)
            self.completions[m.uid] = comp
            self._submit_s.pop(m.uid, None)
            self._enc.pop(m.uid, None)
            self._drafts.pop(m.uid, None)
            self.stats.cancelled += 1
            self._emitted.append(comp)

    # -- preemption internals ------------------------------------------
    # Dense cache entries stacked (n_layers, batch, ...) — a lane's row
    # is [:, i]; "pos" (batch,) and "cache_pos" (batch, sc) index [i].
    _LANE_AXIS1 = ("k", "v", "k_scale", "v_scale", "conv", "ssm")

    def _requeue_prefilling(self, i: int) -> None:
        """Preempt a lane whose prompt is still chunk-prefilling: free
        its blocks (shared holds just decrement — co-members keep
        decoding) and put the request back at the head of the queue.
        Its dead chunk job is reaped before the next chunk batch runs,
        and re-admission reproduces the prefill exactly (no tokens were
        generated, no PRNG consumed)."""
        lane = self.lanes[i]
        if self.sched.kv_paged:
            self._pool(i).free(lane.blocks)
            self._pool(i).unreserve(lane.reserved)
            self._host_table[i] = 0
            self._table_dirty = True
        if self.sched.state_paged:
            self._state_pool(i).free(lane.state_slot)
        self.lanes[i] = None
        self._host_done[i] = True
        self.pending.appendleft(lane.req)
        self.stats.preempts += 1

    def _preempt_lane(self, i: int, hold: bool) -> None:
        """Park a ready lane: snapshot its decode-entry logits, move its
        KV to host, free the lane slot and its pool reservation."""
        lane = self.lanes[i]
        parked = _Parked(req=lane.req, budget=lane.budget, parts=lane.parts,
                         generated=lane.generated,
                         first_tok_s=lane.first_tok_s,
                         prompt_len=lane.prompt_len,
                         pos=int(np.asarray(self.cache["pos"][i])),
                         logits_row=np.asarray(self.cur_logits[i]),
                         hold=hold, parked_round=self._round_no,
                         shard=self._shard_of(i))
        if self.sched.kv_paged:
            parked.n_blocks = len(lane.blocks)
            parked.host, copies = self._pool(i).offload(lane.blocks)
            if copies:
                self._copy_blocks_to_host(copies, parked.shard)
            self._pool(i).unreserve(lane.reserved)
            self._host_table[i] = 0
            self._table_dirty = True
        row = {}
        if not self.sched.paged:
            row = {name: np.asarray(self.cache[name][:, i])
                   for name in self._LANE_AXIS1 if name in self.cache}
            if "cache_pos" in self.cache:
                row["cache_pos"] = np.asarray(self.cache["cache_pos"][i])
        elif self.sched.state_paged:
            # paged SSM / hybrid: the KV side (if any) rode the block
            # offload above; recurrent state is lane-indexed dense, so
            # its rows snapshot here.  Never via _LANE_AXIS1 wholesale —
            # paged "k"/"v" axis 1 is the BLOCK axis, not the lane axis
            row = {name: np.asarray(self.cache[name][:, i])
                   for name in ("conv", "ssm")}
        if row:
            parked.dense_row = row
            self.stats.offload_bytes += sum(a.nbytes for a in row.values())
        if self.sched.state_paged:
            parked.state_host = self._state_pool(i).offload(lane.state_slot)
        self.lanes[i] = None
        self._host_done[i] = True
        self._parked[lane.req.uid] = parked
        self.stats.preempts += 1

    def _copy_blocks_to_host(self, copies: List[Tuple[int, int]],
                             shard: int) -> None:
        """Snapshot the listed (device block, host block) pairs' KV into
        host RAM.  The gather captures the cache arrays' current values
        (immutable under JAX's functional updates), so later writes into
        recycled blocks can never corrupt the parked bytes.  Host block
        ids are per-pool counters, so the host store keys on
        ``(shard, host_id)``."""
        n = pick_bucket(len(copies), self.sched._blk_buckets)
        ids = np.zeros((n,), np.int32)      # padding gathers trash
        ids[: len(copies)] = [b for b, _ in copies]
        # tuple of (k, v) for fp pools, (k, v, k_scale, v_scale) for
        # quantized ones — blocks park as raw int8+scale pairs, no
        # dequantization round-trip, so restore is bit-exact
        arrays = [np.asarray(a) for a in
                  gather_blocks(self.cache, jnp.asarray(ids))]
        for j, (_, h) in enumerate(copies):
            parts = tuple(a[:, j].copy() for a in arrays)
            self._host_kv[(shard, h)] = parts
            self.stats.offload_bytes += sum(p.nbytes for p in parts)

    def _restore_parked(self, uid: int) -> bool:
        """Move a parked request back into a free lane (any lane —
        resume is layout-independent).  False when no lane slot or pool
        capacity is available; never mutates state before success."""
        parked = self._parked[uid]
        sched = self.sched
        if sched.paged:
            # paged: the parked blocks belong to one shard's slab, so
            # the request must land back in a lane of that shard
            lo = parked.shard * sched.lanes_per_shard
            lane_range = range(lo, lo + sched.lanes_per_shard)
        else:
            lane_range = range(sched.n_lanes)
        free_i = next((i for i in lane_range
                       if self.lanes[i] is None), None)
        if free_i is None:
            return False
        lane = _Lane(parked.req, parked.budget, parts=parked.parts,
                     generated=parked.generated,
                     first_tok_s=parked.first_tok_s,
                     prompt_len=parked.prompt_len,
                     last_tok_round=self._round_no)
        if sched.kv_paged:
            pool = self.pools[parked.shard]
            growth = sched._reservation(parked.prompt_len,
                                        parked.budget) - parked.n_blocks
            need = pool.restore_cost(parked.host) + growth
            if not pool.reserve(need):
                return False
        if sched.state_paged:
            spool = self.state_pools[parked.shard]
            if not spool.reserve(1):
                if sched.kv_paged:
                    pool.unreserve(need)
                return False
            lane.state_slot = spool.restore(parked.state_host)
        if sched.kv_paged:
            blocks, scatters, dropped = pool.restore(parked.host)
            if scatters:
                n = pick_bucket(len(scatters), sched._blk_buckets)
                ids = np.zeros((n,), np.int32)   # padding writes to trash
                first = self._host_kv[(parked.shard, scatters[0][0])]
                bufs = [np.zeros((p.shape[0], n) + p.shape[1:], p.dtype)
                        for p in first]
                for j, (h, d) in enumerate(scatters):
                    ids[j] = d
                    for buf, part in zip(
                            bufs, self._host_kv[(parked.shard, h)]):
                        buf[:, j] = part
                self.cache = scatter_blocks(
                    self.cache, jnp.asarray(ids),
                    tuple(jnp.asarray(b) for b in bufs))
            for h in dropped:
                self._host_kv.pop((parked.shard, h), None)
            lane.blocks, lane.reserved = blocks, growth
            self._host_table[free_i] = 0
            self._host_table[free_i, : len(blocks)] = blocks
            self._table_dirty = True
        if parked.dense_row is not None:
            # dense: every parked row; paged SSM/hybrid: conv/ssm rows
            for name, arr in parked.dense_row.items():
                if name == "cache_pos":
                    self.cache[name] = self.cache[name].at[free_i].set(
                        jnp.asarray(arr))
                else:
                    self.cache[name] = self.cache[name].at[:, free_i].set(
                        jnp.asarray(arr))
        self.cache["pos"] = self.cache["pos"].at[free_i].set(parked.pos)
        self.cur_logits = self.cur_logits.at[free_i].set(
            jnp.asarray(parked.logits_row))
        self._salts[free_i] = parked.req.uid & 0x7FFFFFFF
        self._host_done[free_i] = False
        self.lanes[free_i] = lane
        del self._parked[uid]
        self.stats.resumes += 1
        return True

    def _try_resumes(self) -> None:
        """Re-admit auto-resumable parked requests, oldest first,
        stopping at the first that does not fit (FIFO fairness: a big
        parked request is not starved by smaller ones jumping it)."""
        for uid in [u for u, p in self._parked.items() if not p.hold]:
            if not self._restore_parked(uid):
                break

    def _preempt_coldest(self, shard: Optional[int] = None) -> Optional[int]:
        """Pressure policy: park the least-recently-productive
        preemptible lane (LRU by last-harvest round, uid tiebreak).
        Never preempts a lane that is mid-chunk-prefill, has queued
        drafts mid-verify, was admitted/resumed this same round (the
        anti-thrash guard), or is the last live member of its vote
        group.  ``shard`` restricts candidates to one data shard (a
        sharded shared-prefix unit needs lanes AND blocks from the same
        shard).  Returns the freed lane index, or None."""
        groups = collections.Counter(
            lane.req.group for lane in self.lanes
            if lane is not None and lane.req.group is not None)
        cands = []
        for i, lane in enumerate(self.lanes):
            if lane is None or not lane.ready:
                continue
            if shard is not None and self._shard_of(i) != shard:
                continue
            if lane.last_tok_round >= self._round_no:
                continue
            if lane.req.uid in self._drafts:
                continue
            g = lane.req.group
            if g is not None and groups[g] <= 1:
                continue
            cands.append((lane.last_tok_round, lane.req.uid, i))
        if not cands:
            return None
        i = min(cands)[2]
        self._preempt_lane(i, hold=False)
        return i

    def _finalize_parked(self, uid: int, cancelled: bool) -> None:
        """Finish a parked request without resuming it (its vote group
        decided, or its client released it): drop its host blocks and
        emit whatever it generated before parking."""
        parked = self._parked.pop(uid)
        if parked.host is not None:
            for h in self.pools[parked.shard].discard(parked.host):
                self._host_kv.pop((parked.shard, h), None)
        if parked.state_host is not None:
            self.state_pools[parked.shard].discard(parked.state_host)
        toks = (np.concatenate(parked.parts) if parked.parts
                else np.zeros((0,), np.int32))
        text = self.sched.tokenizer.decode(toks) if self.sched.tokenizer \
            else ""
        ttft, ttd = self._latency(uid, parked.first_tok_s, time.time())
        comp = Completion(uid, parked.req.group, toks, len(toks), text,
                          cancelled, parked.req.meta, ttft_s=ttft, ttd_s=ttd)
        if uid not in self._released:
            self.completions[uid] = comp
            self._emitted.append(comp)
        self._submit_s.pop(uid, None)
        self._drafts.pop(uid, None)
        if cancelled:
            self.stats.cancelled += 1

    def _cancel_live(self, uid: int) -> None:
        """Cancel a released uid wherever it currently lives: a decoding
        or still-prefilling lane is finalized cancelled (blocks freed,
        prefix registration skipped by the job-reap machinery), a parked
        record drops its host blocks.  Pending uids need no action —
        admission skips released uids."""
        for i, lane in enumerate(self.lanes):
            if lane is not None and lane.req.uid == uid:
                self._finalize(i, cancelled=True)
                return
        if uid in self._parked:
            self._finalize_parked(uid, cancelled=True)

    # -- chunked prefill -----------------------------------------------
    def _job_alive(self, job: _PrefillJob) -> bool:
        """True while any of the job's lanes is still the lane object
        admission parked there (a StopPolicy kill mid-prefill finalizes
        the lane and may hand the slot to a new request)."""
        return any(self.lanes[i] is lane
                   for i, lane in zip(job.lanes, job.lane_objs))

    def _reap_prefill_jobs(self) -> None:
        """Drop completed and dead jobs from the queue.  A shared job
        whose lanes were all killed mid-prefill still holds the
        reservation earmarked for its CoW tail clones — return it."""
        live: List[_PrefillJob] = []
        for job in self._prefill_q:
            if not job.done and self._job_alive(job):
                live.append(job)
                continue
            if job.cow_reserved > 0:
                self._pool(job.lanes[0]).unreserve(job.cow_reserved)
                job.cow_reserved = 0
        self._prefill_q = collections.deque(live)

    def _run_prefill_chunks(self) -> None:
        """Spend this round's prefill token budget advancing queued
        chunk jobs.

        Round-robin passes: every live job advances ONE chunk per pass
        (batched by equal prompt bucket in queue order), so a short
        prompt behind a long one finishes its prefill in its first pass
        instead of waiting for the long prompt to drain — the
        processor-sharing discipline that keeps admission from ever
        barriering the loop.  Budget ``None`` keeps passing until every
        queued prompt is fully prefilled (whole-prefill latency shape,
        chunked math); a finite budget stops starting new batches once
        ``prefill_budget`` tokens of chunk capacity were spent, but
        always processes at least one batch so prefill can never
        starve."""
        sched = self.sched
        c = sched.chunk_size
        budget = sched.prefill_budget
        spent = 0
        while True:
            self._reap_prefill_jobs()
            if not self._prefill_q:
                return
            if budget is not None and spent >= budget:
                return
            snapshot = list(self._prefill_q)
            j = 0
            while j < len(snapshot):
                if budget is not None and spent >= budget:
                    return
                bucket = snapshot[j].bucket
                batch: List[_PrefillJob] = []
                cost = 0
                while (j < len(snapshot) and snapshot[j].bucket == bucket
                       and len(batch) < sched.n_lanes):
                    # budget counts REAL prompt tokens, so a wave of
                    # short prompts doesn't get priced like long-prompt
                    # chunks; the first batch always goes through
                    real = max(1, min(c, len(snapshot[j].toks)
                                      - snapshot[j].off))
                    if (budget is not None and batch
                            and spent + cost + real > budget):
                        break
                    batch.append(snapshot[j])
                    cost += real
                    j += 1
                self._chunk_batch(batch, bucket)
                spent += cost

    def _chunk_batch(self, batch: List[_PrefillJob], bucket: int) -> None:
        """Advance each job in ``batch`` by one chunk with a single
        jitted ``prefill_chunk_jit`` call at (admit-bucket, chunk_size,
        bucket) shapes, then activate rows whose prompt completed."""
        sched, stats = self.sched, self.stats
        c = sched.chunk_size
        admit_n = pick_bucket(len(batch), sched.admit_buckets)
        toks = np.full((admit_n, c), sched.gcfg.pad_id, np.int32)
        start = np.zeros((admit_n,), np.int32)
        lengths = np.ones((admit_n,), np.int32)
        lane_ids = np.full((admit_n,), sched.n_lanes, np.int32)
        n_rows = sched.max_blocks if sched.kv_paged else 1
        read_rows = np.zeros((admit_n, n_rows), np.int32)
        write_rows = np.zeros((admit_n, n_rows), np.int32)
        for j, job in enumerate(batch):
            seg = job.toks[job.off: job.off + c]
            toks[j, : len(seg)] = seg
            start[j] = job.off
            lengths[j] = max(len(job.toks), 1)
            if not job.shared:
                lane_ids[j] = job.lanes[0]
            if sched.kv_paged:
                read_rows[j] = job.read_row
                write_rows[j] = job.write_row
            stats.prefill_tokens += max(0, min(c, len(job.toks) - job.off))
            job.off += c
            if job.off >= max(len(job.toks), 1):
                job.done = True
                stats.prefill_prompts += 1
        stats.prefills += 1
        stats.prefill_chunks += len(batch)
        self.cache, self.cur_logits, chunk_logits = prefill_chunk_jit(
            sched.params, sched.cfg, self.cache, self.cur_logits,
            jnp.asarray(toks), jnp.asarray(start), jnp.asarray(lengths),
            jnp.asarray(lane_ids), jnp.asarray(read_rows),
            jnp.asarray(write_rows), bucket)
        done_rows = [(j, job) for j, job in enumerate(batch) if job.done]
        for j, job in done_rows:
            if job.shared:
                continue
            lane = job.lane_objs[0]
            i = job.lanes[0]
            if self.lanes[i] is not lane:
                continue             # killed mid-prefill; reap drops the job
            if sched.kv_paged:
                self._host_table[i] = job.read_row
                self._table_dirty = True
            lane.ready = True
            self._host_done[i] = False
        shared_done = [(j, job) for j, job in done_rows if job.shared]
        if shared_done:
            self._fanout_shared(shared_done, chunk_logits)

    def _fanout_shared(self, shared_done: List[Tuple[int, _PrefillJob]],
                       chunk_logits) -> None:
        """Activate completed shared-prefix rows: clone CoW tails for
        the surviving lanes, stitch their block tables onto the shared
        prompt blocks, register the prompt with the prefix cache (only
        now — its blocks are finally fully written), and replicate the
        prompt-last-token logits / position into every lane."""
        sched = self.sched
        cow_src: List[int] = []
        cow_dst: List[int] = []
        nrows = pick_bucket(len(shared_done), sched.admit_buckets)
        kmax = pick_bucket(max(len(job.members) for _, job in shared_done),
                           sched._fan_buckets)
        lane_rows = np.full((nrows, kmax), sched.n_lanes, np.int32)
        lens_arr = np.ones((nrows,), np.int32)
        row_ids = np.zeros((nrows,), np.int32)
        for r_i, (j, job) in enumerate(shared_done):
            pool = self._pool(job.lanes[0])   # a job's lanes share a shard
            row_ids[r_i] = j
            lens_arr[r_i] = max(len(job.toks), 1)
            alive = [(i, lane) for i, lane in zip(job.lanes, job.lane_objs)
                     if self.lanes[i] is lane]
            tail_of: Dict[int, int] = {}
            if job.partial and alive:
                tail = job.prompt_blocks[-1]
                for i, lane in alive:
                    blk, copied = pool.cow(tail)
                    if copied:
                        cow_src.append(tail)
                        cow_dst.append(blk)
                        job.cow_reserved -= 1
                    tail_of[i] = blk
            for slot_k, (i, lane) in enumerate(alive):
                lane.blocks = list(job.prompt_blocks)
                if job.partial:
                    lane.blocks[-1] = tail_of[i]
                self._host_table[i] = 0
                self._host_table[i, : job.n_pb] = lane.blocks
                lane_rows[r_i, slot_k] = i
                lane.ready = True
                self._host_done[i] = False
            self._table_dirty = True
            if job.cow_reserved > 0:
                # dead members never drew their CoW allowance
                pool.unreserve(job.cow_reserved)
                job.cow_reserved = 0
            pc = self._prefix_cache_of(self._shard_of(job.lanes[0]))
            if alive and pc is not None:
                pc.register(job.toks, job.prompt_blocks[: job.n_full])
        sel = chunk_logits[jnp.asarray(row_ids)]
        self.cache, self.cur_logits = fanout_lanes(
            self.cache, self.cur_logits, sel, jnp.asarray(lane_rows),
            jnp.asarray(lens_arr))
        if cow_src:
            n = pick_bucket(len(cow_src), sched._fan_buckets)
            src = np.zeros((n,), np.int32)
            dst = np.zeros((n,), np.int32)
            src[: len(cow_src)] = cow_src
            dst[: len(cow_dst)] = cow_dst
            self.cache = copy_blocks(self.cache, jnp.asarray(src),
                                     jnp.asarray(dst))

    def _admit(self) -> None:
        """Dense / paged (non-shared) admission: fill free lanes from
        the pending queue, bucket the wave, prefill, insert.

        Sharded: each request is placed in the shard with the most free
        lanes whose pool can cover its reservation (its lane is fixed
        here — lane index never affects completions, only which slab
        its blocks come from)."""
        sched, lanes, pending = self.sched, self.lanes, self.pending
        free_by = self._free_by_shard()
        wave: List[Tuple[Request, int]] = []    # (request, assigned lane)
        while pending and any(free_by):
            req = pending[0]
            if req.uid in self._released:
                pending.popleft()    # client cancelled before admission
                continue
            if req.group in self.decided:
                pending.popleft()
                self._drop_decided([req])
                continue
            if req.uid not in self._enc:
                self._enc[req.uid] = sched._encode(req)
            lane_i = None
            if sched.paged:
                # admission must secure every pool the protocol needs:
                # KV blocks (kv_paged) and a recurrent-state slot
                # (state_paged) from the SAME shard, atomically
                need = (sched._reservation(max(len(self._enc[req.uid]), 1),
                                           sched._budget(req))
                        if sched.kv_paged else 0)
                for s in self._shard_order(free_by):
                    if sched.kv_paged and not self.pools[s].reserve(need):
                        continue
                    if (sched.state_paged
                            and not self.state_pools[s].reserve(1)):
                        if sched.kv_paged:
                            self.pools[s].unreserve(need)
                        continue
                    lane_i = free_by[s].pop(0)
                    break
                if lane_i is None:
                    # pool pressure in every shard with a free lane:
                    # evict the coldest preemptible lane to host RAM
                    # and retry (the freed lane's shard regains blocks
                    # AND a lane), or leave the queue intact (FIFO) and
                    # retry after the next round frees blocks
                    if sched.auto_preempt:
                        idx = self._preempt_coldest()
                        if idx is not None:
                            free_by[self._shard_of(idx)].append(idx)
                            continue
                    self.stats.admission_blocked += 1
                    break
            else:
                s = self._shard_order(free_by)[0]
                lane_i = free_by[s].pop(0)
            pending.popleft()
            wave.append((req, lane_i))
        if not wave:
            return
        if sched.chunk_size is not None:
            # chunked admission: assign the lane (and, paged, its prompt
            # blocks) now, but queue the prompt as a chunk job instead of
            # prefilling it — the lane rides decode rounds done-masked
            # until its final chunk lands.  Its block-table row stays all
            # trash meanwhile, so the masked decode writes land nowhere.
            for r, i in wave:
                toks = self._enc[r.uid]
                lane = _Lane(r, sched._budget(r), ready=False,
                             last_tok_round=self._round_no)
                read_row = write_row = None
                if sched.paged:
                    lane.prompt_len = max(len(toks), 1)
                if sched.kv_paged:
                    n_pb = -(-lane.prompt_len // sched.block_size)
                    lane.blocks = self._pool(i).alloc(n_pb)
                    lane.reserved = sched._reservation(
                        lane.prompt_len, lane.budget) - n_pb
                    row = np.zeros((sched.max_blocks,), np.int32)
                    row[:n_pb] = lane.blocks
                    read_row = write_row = row
                    self._host_table[i] = 0
                    self._table_dirty = True
                if sched.state_paged:
                    lane.state_slot = self._state_pool(i).alloc()
                lanes[i] = lane
                self._salts[i] = r.uid & 0x7FFFFFFF
                self._host_done[i] = True
                self._prefill_q.append(_PrefillJob(
                    toks=list(toks),
                    bucket=pick_bucket(max(len(toks), 1), sched.buckets),
                    lanes=[i], lane_objs=[lane], members=[r],
                    read_row=read_row, write_row=write_row))
            for r, _ in wave:
                self._enc.pop(r.uid, None)
            return
        by_bucket: Dict[int, List[Tuple[Request, int]]] = \
            collections.defaultdict(list)
        for r, i in wave:
            by_bucket[pick_bucket(len(self._enc[r.uid]), sched.buckets)
                      ].append((r, i))
        for bucket in sorted(by_bucket):
            grp = by_bucket[bucket]
            admit_n = pick_bucket(len(grp), sched.admit_buckets)
            toks, lens = pad_token_rows([self._enc[r.uid] for r, _ in grp],
                                        sched.gcfg.pad_id, bucket, admit_n)
            lane_ids = np.full((admit_n,), sched.n_lanes, np.int32)
            # pure-SSM paged has no pages to scatter; a 1-wide dummy row
            # keeps insert_lanes_paged's signature uniform
            n_rows = sched.max_blocks if sched.kv_paged else 1
            block_rows = (np.zeros((admit_n, n_rows), np.int32)
                          if sched.paged else None)
            for j, (r, i) in enumerate(grp):
                lane_ids[j] = i
                lane = _Lane(r, sched._budget(r),
                             last_tok_round=self._round_no)
                if sched.paged:
                    lane.prompt_len = max(len(self._enc[r.uid]), 1)
                if sched.kv_paged:
                    n_pb = -(-lane.prompt_len // sched.block_size)
                    lane.blocks = self._pool(i).alloc(n_pb)
                    lane.reserved = sched._reservation(
                        lane.prompt_len, lane.budget) - n_pb
                    block_rows[j, :n_pb] = lane.blocks
                    self._host_table[i] = block_rows[j]
                    self._table_dirty = True
                if sched.state_paged:
                    lane.state_slot = self._state_pool(i).alloc()
                lanes[i] = lane
                self._salts[i] = r.uid & 0x7FFFFFFF
                self._host_done[i] = False
            if sched.paged:
                # prefill dense at the prompt bucket only, then scatter
                # the rows into their allocated pages
                last, new_cache = prefill_jit(
                    sched.params, sched.cfg, jnp.asarray(toks),
                    jnp.asarray(lens), bucket)
                self.cache, self.cur_logits = insert_lanes_paged(
                    self.cache, self.cur_logits, new_cache, last,
                    jnp.asarray(lane_ids), jnp.asarray(block_rows))
            else:
                last, new_cache = prefill_jit(
                    sched.params, sched.cfg, jnp.asarray(toks),
                    jnp.asarray(lens), sched.s_max)
                self.cache, self.cur_logits = insert_lanes(
                    self.cache, self.cur_logits, new_cache, last,
                    jnp.asarray(lane_ids))
            self.stats.prefills += 1
            self.stats.prefill_prompts += len(grp)
            self.stats.prefill_tokens += sum(len(self._enc[r.uid])
                                             for r, _ in grp)
        for r, _ in wave:
            self._enc.pop(r.uid, None)   # memo only matters pre-admission

    def _admit_shared(self) -> None:
        """Shared-prefix admission: atomic group units, one prefill row
        per distinct prompt, prompt blocks refcount-shared into every
        member lane, CoW on partial tails, prefix-cache
        reuse/registration.  See the Scheduler docstring."""
        sched, lanes, pending = self.sched, self.lanes, self.pending
        stats = self.stats
        free_by = self._free_by_shard()
        planned: List[_PlanRow] = []
        while pending:
            unit = pending[0]
            members = (unit.requests if isinstance(unit, RequestGroup)
                       else [unit])
            members = [m for m in members if m.uid not in self._released]
            if not members:
                pending.popleft()    # every member cancelled pre-admission
                continue
            if all(m.group is not None and m.group in self.decided
                   for m in members):
                pending.popleft()
                self._drop_decided(members)
                continue
            # atomic AND single-shard: the unit's lanes must all come
            # from one shard, whose slab holds its shared blocks
            cands = [s for s in self._shard_order(free_by)
                     if len(free_by[s]) >= len(members)]
            if not cands:
                break              # the whole unit or nothing
            for m in members:
                if m.uid not in self._enc:
                    self._enc[m.uid] = sched._encode(m)
            rows = None
            shard = None
            degraded = False
            for s in cands:
                pool = self.pools[s]
                pc = self._prefix_cache_of(s)
                while True:
                    rows, need = sched._plan_unit(members, self._enc, pc)
                    if need > sched.pool_blocks:
                        # the unit can never fit atomically in one
                        # shard's slab: degrade to per-lane units
                        # (constructor guarantees any single lane fits)
                        # and re-examine the head
                        pending.popleft()
                        for m in reversed(members):
                            pending.appendleft(m)
                        rows = None
                        degraded = True
                        break
                    if pool.reserve(need):
                        # hybrid: the unit's lanes each need a state
                        # slot from the same shard, atomically
                        if (not sched.state_paged or
                                self.state_pools[s].reserve(len(members))):
                            shard = s
                            break
                        pool.unreserve(need)
                    # shard pool pressure: shed its warm prefix-cache
                    # blocks, then preempt its cold lanes, before
                    # falling through to the next candidate shard
                    if pc.evict_lru():
                        continue
                    if sched.auto_preempt:
                        idx = self._preempt_coldest(shard=s)
                        if idx is not None:
                            free_by[s].append(idx)
                            continue
                    rows = None
                    break
                if degraded or shard is not None:
                    break
            if degraded:
                continue
            if shard is None:
                stats.admission_blocked += 1
                break
            pool = self.pools[shard]
            # hold the cache-hit blocks for every lane of each row now,
            # so later evictions can only drop the cache's own hold,
            # never the blocks these lanes are about to map; fix each
            # row's shard and lane assignment while we are at it
            for row in rows:
                row.shard = shard
                row.lanes = [free_by[shard].pop(0) for _ in row.members]
                if row.hit:
                    pool.share(row.hit, len(row.members))
                    stats.prefix_hits += 1
                    stats.prefix_hit_blocks += len(row.hit)
            pending.popleft()
            planned.extend(rows)
        if not planned:
            return
        if sched.chunk_size is not None:
            # chunked shared admission: allocate and refcount-share each
            # row's prompt blocks now (write side routes cache-hit
            # positions to trash, read side maps hit + own), park the K
            # lanes done-masked with all-trash tables, and queue one
            # chunk job per row — CoW tail clones and prefix-cache
            # registration wait until the row's final chunk has landed,
            # so no other admission can ever read half-written blocks.
            for row in planned:
                pool = self.pools[row.shard]
                p_len = max(len(row.toks), 1)
                h = len(row.hit)
                own = pool.alloc(row.n_pb - h)
                prompt_blocks = row.hit + own
                write_row = np.zeros((sched.max_blocks,), np.int32)
                write_row[h:row.n_pb] = own
                read_row = np.zeros((sched.max_blocks,), np.int32)
                read_row[:row.n_pb] = prompt_blocks
                k_members = len(row.members)
                if k_members > 1 and own:
                    pool.share(own, k_members - 1)
                lane_ids, lane_objs = [], []
                for m, i in zip(row.members, row.lanes):
                    lane = _Lane(m, sched._budget(m), ready=False,
                                 last_tok_round=self._round_no)
                    lane.prompt_len = p_len
                    lane.blocks = list(prompt_blocks)
                    lane.reserved = sched._reservation(
                        p_len, lane.budget) - row.n_pb
                    self._host_table[i] = 0
                    lanes[i] = lane
                    self._salts[i] = m.uid & 0x7FFFFFFF
                    self._host_done[i] = True
                    lane_ids.append(i)
                    lane_objs.append(lane)
                self._table_dirty = True
                stats.shared_lanes += k_members - 1
                self._prefill_q.append(_PrefillJob(
                    toks=list(row.toks),
                    bucket=pick_bucket(p_len, sched.buckets),
                    lanes=lane_ids, lane_objs=lane_objs,
                    members=list(row.members),
                    read_row=read_row, write_row=write_row, shared=True,
                    prompt_blocks=list(prompt_blocks), n_pb=row.n_pb,
                    n_full=row.n_full, partial=row.partial,
                    cow_reserved=(k_members - 1) if row.partial else 0))
            for row in planned:
                for m in row.members:
                    self._enc.pop(m.uid, None)
            return
        by_bucket: Dict[int, List[_PlanRow]] = collections.defaultdict(list)
        for row in planned:
            by_bucket[pick_bucket(len(row.toks), sched.buckets)].append(row)
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for bucket in sorted(by_bucket):
            rows = by_bucket[bucket]
            admit_n = pick_bucket(len(rows), sched.admit_buckets)
            kmax = pick_bucket(max(len(r.members) for r in rows),
                               sched._fan_buckets)
            toks, lens = pad_token_rows([r.toks for r in rows],
                                        sched.gcfg.pad_id, bucket, admit_n)
            lane_rows = np.full((admit_n, kmax), sched.n_lanes, np.int32)
            write_rows = np.zeros((admit_n, sched.max_blocks), np.int32)
            for j, row in enumerate(rows):
                pool = self.pools[row.shard]
                p_len = max(len(row.toks), 1)
                h = len(row.hit)
                own = pool.alloc(row.n_pb - h)
                prompt_blocks = row.hit + own
                # write side: cache-satisfied positions land in the
                # trash block (their KV already exists, and earlier
                # holders must keep bit-identical reads)
                write_rows[j, h:row.n_pb] = own
                k_members = len(row.members)
                if k_members > 1 and own:
                    pool.share(own, k_members - 1)
                self._prefix_cache_of(row.shard).register(
                    row.toks, prompt_blocks[:row.n_full])
                tail_of = {}
                if row.partial:
                    tail = prompt_blocks[-1]
                    for m in row.members:
                        blk, copied = pool.cow(tail)
                        if copied:
                            cow_src.append(tail)
                            cow_dst.append(blk)
                        tail_of[m.uid] = blk
                for mj, (m, i) in enumerate(zip(row.members, row.lanes)):
                    lane = _Lane(m, sched._budget(m),
                                 last_tok_round=self._round_no)
                    lane.prompt_len = p_len
                    lane.blocks = list(prompt_blocks)
                    if row.partial:
                        lane.blocks[-1] = tail_of[m.uid]
                    lane.reserved = sched._reservation(
                        p_len, lane.budget) - row.n_pb
                    if sched.state_paged:
                        lane.state_slot = self._state_pool(i).alloc()
                    self._host_table[i] = 0
                    self._host_table[i, :row.n_pb] = lane.blocks
                    lane_rows[j, mj] = i
                    lanes[i] = lane
                    self._salts[i] = m.uid & 0x7FFFFFFF
                    self._host_done[i] = False
                self._table_dirty = True
                stats.shared_lanes += k_members - 1
            last, new_cache = prefill_shared(
                sched.params, sched.cfg, jnp.asarray(toks),
                jnp.asarray(lens), bucket)
            self.cache, self.cur_logits = insert_lanes_shared(
                self.cache, self.cur_logits, new_cache, last,
                jnp.asarray(lane_rows), jnp.asarray(write_rows))
            stats.prefills += 1
            stats.prefill_prompts += len(rows)
            stats.prefill_tokens += sum(len(r.toks) for r in rows)
        if cow_src:
            # device half of CoW, after the inserts wrote the originals;
            # padded pairs clone trash onto trash
            n = pick_bucket(len(cow_src), sched._fan_buckets)
            src = np.zeros((n,), np.int32)
            dst = np.zeros((n,), np.int32)
            src[: len(cow_src)] = cow_src
            dst[: len(cow_dst)] = cow_dst
            self.cache = copy_blocks(self.cache, jnp.asarray(src),
                                     jnp.asarray(dst))
        for row in planned:
            for m in row.members:
                self._enc.pop(m.uid, None)   # memo only matters pre-admission
