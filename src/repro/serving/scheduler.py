"""Round-based continuous-batching scheduler, with an optional
block-paged KV cache.

A fixed pool of ``n_lanes`` decode lanes shares one device cache pytree
(leading lane axis) and advances in lockstep rounds of ``round_tokens``
tokens (``batch.decode_round``).  Between rounds the host:

  1. *admits* pending requests into free lanes — prompts are padded to
     a length bucket and the admission wave to a power-of-two size, so
     prefill compiles O(#buckets x #wave sizes) times total, then the
     prefilled rows are scattered into the pool (``batch.insert_lanes``
     or, paged, ``batch.insert_lanes_paged``);
  2. *harvests* the round's tokens per live lane, truncating at EOS or
     the per-request budget and finalizing finished lanes (which frees
     them — and, paged, their cache blocks — for the next admission);
  3. consults the ``StopPolicy``: every newly finished request is shown
     to the policy in (gen_len, uid) order, and any vote *group* the
     policy declares decided is killed mid-flight — its still-running
     lanes are evicted with whatever they generated so far and its
     never-admitted requests are dropped.  This is SATER's early stop
     as real freed compute — and, paged, real freed HBM.

Dense vs paged cache
--------------------
Dense (default): every lane owns ``s_max`` cache slots for its whole
lifetime, so HBM cost is ``n_lanes * s_max`` slots regardless of how
short responses actually are — with SATER's shortest-response training
and vote early stop, most of that is never written.  Paged
(``paged=True``): K/V live in a pool of ``block_size``-slot blocks
(model.init_paged_decode_state) managed by a host-side free-list
allocator (serving/block_pool.py).  A lane admitted with prompt length
P and budget G *reserves* ``ceil((P+G)/bs)`` blocks (so it can always
grow — no preemption needed), *allocates* ``ceil(P/bs)`` for the
prompt, and draws the rest lazily, one round ahead of its decode
position.  Admission blocks while the pool cannot cover a reservation
(``SchedStats.admission_blocked`` counts those waits), and every
finalize — EOS, budget, or a ``StopPolicy`` kill — returns the lane's
blocks to the pool immediately.  Evicted lanes keep stepping inside
the jitted round until their lane is re-admitted; their block-table
rows are re-pointed at the allocator's trash block first, so those
writes land nowhere.

Shared-prefix vote groups (``share_prefix=True``, paged only)
-------------------------------------------------------------
SATER's K-vote sampling submits the *same* prompt K times per question;
without sharing the scheduler prefills it K times and stores K copies
of its KV.  With ``share_prefix=True``, :class:`RequestGroup` units are
admitted *atomically* (all K lanes or none), prefilled **once** per
group (``batch.prefill_shared``), and the prompt's pool blocks are
mapped read-only into all K block tables — the allocator refcounts
each block (block_pool.BlockPool.share), so a block is freed only when
its last holder dies and a ``VoteEarlyStop`` kill can never double-free
a shared block.  Decode appends collide only in the last, partially
filled prompt block; each lane copy-on-writes it (``BlockPool.cow`` +
``batch.copy_blocks``) before its first decode write, so K lanes cost
one prompt prefill + one shared KV copy + K private tails.  Groups
whose prompts are not token-identical (e.g. RCV's per-lane confidence
headers, which differ from the first token) fall back to per-lane
admission transparently.

On top of group fan-out, a hash-keyed *prefix cache* shares full
prompt blocks across requests: every admitted prompt registers its
block-aligned prefixes, and later admissions whose prompts start with
a registered prefix (same instruction/system header) map the cached
blocks instead of allocating fresh ones — an HBM dedup (the prefill
still computes the prefix, but its writes are routed to the trash
block so earlier holders keep bit-identical reads).  Cache entries
hold refcounts; under pool pressure admission evicts them LRU before
backpressuring.

Request lifecycle:  pending -> admitted (prefill + lane insert)
  -> decoding (one round at a time) -> finished (EOS | budget)
                                    -> cancelled (group decided)

Determinism: step-t sampling uses fold_in(master_key, t) with t the
*global* round-step counter, shared by all lanes.  A request's tokens
therefore depend on its admission step and the lane-pool width, exactly
like batch composition affects real serving engines.  The paged cache
reproduces the dense cache's logical slot layout exactly (positions are
contiguous within a lane's block table), so for greedy decoding the
paged scheduler bit-matches the dense one and the one-shot engine
(tests/test_scheduler.py proves both) — on the jnp attention path used
off-TPU; the TPU Pallas paged-attention kernel is allclose to it, not
bit-equal.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving.batch import (GenConfig, copy_blocks, decode_round,
                                 insert_lanes, insert_lanes_paged,
                                 insert_lanes_shared, make_buckets,
                                 pad_token_rows, pick_bucket, prefill_jit,
                                 prefill_shared)
from repro.serving.block_pool import BlockPool


@dataclasses.dataclass
class Request:
    """One generation request.  ``group`` ties the K vote lanes of a
    question together for the StopPolicy; ``meta`` rides along to the
    completion (e.g. the confidence level the prompt asked for)."""
    uid: int
    prompt: Optional[str] = None
    tokens: Optional[Sequence[int]] = None   # pre-tokenized alternative
    group: Optional[int] = None
    max_new_tokens: Optional[int] = None     # default: gcfg.max_new_tokens
    meta: Optional[dict] = None


@dataclasses.dataclass
class RequestGroup:
    """K requests forming one vote group, submitted as a unit.

    With ``share_prefix=True`` the scheduler admits the group
    atomically (all lanes or none) and, when the members' prompts are
    token-identical, prefills the prompt once and maps its KV blocks
    read-only into every member's block table.  Members with differing
    prompts (or a dense / non-sharing scheduler) are admitted as
    independent requests — same results, no sharing.
    """
    requests: List[Request]


@dataclasses.dataclass
class Completion:
    """A finished (or cancelled) request as returned by
    :meth:`Scheduler.run`."""
    uid: int
    group: Optional[int]
    tokens: np.ndarray           # generated ids up to & incl. EOS
    gen_len: int                 # == len(tokens)
    text: str
    cancelled: bool              # killed by StopPolicy before finishing
    meta: Optional[dict] = None


class StopPolicy:
    """Hook consulted after every finished request.

    ``observe`` returns the group ids that are now *decided*: the
    scheduler evicts their running lanes and drops their pending
    requests.  The base policy never stops anything.
    """

    def observe(self, completion: Completion) -> Iterable[int]:
        return ()


@dataclasses.dataclass
class SchedStats:
    """Counters for one :meth:`Scheduler.run` call.

    The cache fields quantify the paged win: ``peak_cache_bytes`` is
    the high-water K/V footprint (for dense, the full static cache; for
    paged, peak blocks in use x block bytes), and ``dense_cache_bytes``
    is what a dense cache at the same lane count pins — their ratio is
    the HBM cut the block pool delivers.
    """
    rounds: int = 0              # decode_round invocations
    lane_rounds: int = 0         # sum over rounds of live lanes
    generated_tokens: int = 0    # tokens actually produced by live lanes
    prefills: int = 0            # prefill executions (admission waves)
    prefill_prompts: int = 0     # real prompt rows prefilled across waves
    prefill_tokens: int = 0      # real prompt tokens prefilled (a shared
    #                              group's prompt counts once, not K times)
    cancelled: int = 0           # requests killed by the StopPolicy
    wall_s: float = 0.0
    admission_blocked: int = 0   # admissions deferred on pool pressure
    pool_blocks: int = 0         # allocatable blocks (paged only)
    peak_blocks_in_use: int = 0  # allocator high-water mark (paged only)
    peak_cache_bytes: int = 0    # peak K/V footprint actually held
    dense_cache_bytes: int = 0   # dense-equivalent K/V footprint
    shared_lanes: int = 0        # lanes fed by another lane's prefill
    cow_copies: int = 0          # partial prompt blocks cloned for CoW
    prefix_hits: int = 0         # prompt rows that reused cached prefix blocks
    prefix_hit_blocks: int = 0   # pool blocks not allocated thanks to the cache


class _PrefixCache:
    """Hash-keyed map from block-aligned prompt-token prefixes to the
    live pool blocks already holding their K/V.

    Every admitted prompt registers all its *full* (block-aligned)
    prompt blocks under every aligned prefix length, so a later prompt
    sharing only the instruction/system header still hits.  Entries
    hold one allocator refcount per block (released on eviction), so a
    cached block survives its last lane — that is the cache's warmth —
    but admission evicts entries LRU whenever the pool cannot cover a
    new reservation, so cached blocks never deadlock admission.  Keys
    are the token tuples themselves: no hash-collision can alias two
    different prefixes onto one block list.
    """

    def __init__(self, pool: BlockPool, block_size: int, max_entries: int):
        self.pool, self.bs, self.cap = pool, block_size, max_entries
        self._entries: "collections.OrderedDict[tuple, List[int]]" = \
            collections.OrderedDict()

    def __len__(self):
        return len(self._entries)

    def lookup(self, toks: Sequence[int]) -> List[int]:
        """Blocks backing the longest registered aligned prefix of
        ``toks`` ([] on miss).  The caller must ``share`` them before
        anything may evict the entry."""
        for m in range(len(toks) // self.bs, 0, -1):
            key = tuple(toks[: m * self.bs])
            blocks = self._entries.get(key)
            if blocks is not None:
                self._entries.move_to_end(key)
                return list(blocks)
        return []

    def register(self, toks: Sequence[int], blocks: List[int]) -> None:
        """Register every aligned prefix of ``toks`` covered by
        ``blocks`` (the prompt's full blocks only — the caller must
        exclude any partially filled tail block, which lanes write)."""
        n_full = min(len(toks) // self.bs, len(blocks))
        for m in range(1, n_full + 1):
            key = tuple(toks[: m * self.bs])
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            self.pool.share(blocks[:m])
            self._entries[key] = list(blocks[:m])
            while len(self._entries) > self.cap:
                self.evict_lru()

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry, releasing its block
        holds.  False when the cache is already empty."""
        if not self._entries:
            return False
        _, blocks = self._entries.popitem(last=False)
        self.pool.free(blocks)
        return True

    def clear(self) -> None:
        while self.evict_lru():
            pass


@dataclasses.dataclass
class _PlanRow:
    """One prefill row planned during shared admission: the prompt, the
    lanes it feeds, and its prompt-block geometry."""
    toks: List[int]
    members: List[Request]
    hit: List[int]               # cached prefix blocks (not yet held)
    n_pb: int                    # ceil(P / block_size) prompt blocks
    n_full: int                  # P // block_size read-only full blocks
    partial: bool                # last prompt block is partially filled


@dataclasses.dataclass
class _Lane:
    req: Request
    budget: int
    parts: List[np.ndarray] = dataclasses.field(default_factory=list)
    generated: int = 0
    # paged bookkeeping
    prompt_len: int = 0
    blocks: List[int] = dataclasses.field(default_factory=list)
    reserved: int = 0            # promised-but-undrawn pool blocks


class Scheduler:
    """Continuous-batching engine over a fixed lane pool.

    Parameters
    ----------
    params, cfg, tokenizer, gcfg:
        Model weights/config, tokenizer (None for pre-tokenized
        requests) and generation settings.
    n_lanes, round_tokens:
        Lane-pool width and decode-round length (the early-stop grain:
        a StopPolicy can kill a group at most ``round_tokens`` tokens
        after the deciding lane finished).
    max_prompt_len, buckets, admit_buckets:
        Prompt-length bucket ladder and admission-wave size ladder;
        compiled shapes are bounded by their product.
    paged, block_size, pool_blocks:
        ``paged=True`` swaps the dense per-lane cache for the
        block-paged pool: ``block_size`` slots per block,
        ``pool_blocks`` allocatable blocks (default: enough for every
        lane at full ``s_max`` — set it lower to trade admission
        concurrency for HBM, the allocator backpressures admission
        instead of overflowing).  Must cover at least one worst-case
        lane (``ceil(s_max / block_size)`` blocks).
    share_prefix, prefix_cache_entries:
        ``share_prefix=True`` (paged only) enables shared-prefix
        serving: RequestGroups are admitted atomically and prefilled
        once, their prompt blocks refcount-shared across the K lanes
        (copy-on-write on the last partial block), plus a
        ``prefix_cache_entries``-entry LRU cache sharing full prompt
        blocks across requests with a common token prefix.
    """

    def __init__(self, params, cfg: ModelConfig, tokenizer, gcfg: GenConfig,
                 n_lanes: int = 32, round_tokens: int = 16,
                 max_prompt_len: int = 256,
                 buckets: Optional[Sequence[int]] = None,
                 admit_buckets: Optional[Sequence[int]] = None,
                 paged: bool = False, block_size: int = 32,
                 pool_blocks: Optional[int] = None,
                 share_prefix: bool = False,
                 prefix_cache_entries: int = 256):
        self.params, self.cfg, self.tokenizer, self.gcfg = \
            params, cfg, tokenizer, gcfg
        self.n_lanes = n_lanes
        self.round_tokens = round_tokens
        self.buckets = tuple(sorted(buckets or make_buckets(max_prompt_len)))
        self.admit_buckets = tuple(sorted(admit_buckets or
                                          make_buckets(n_lanes, 1)))
        # cache sized so any prompt bucket + any budget fits one lane
        self.s_max = max(self.buckets) + gcfg.max_new_tokens
        self.paged = paged
        self.block_size = block_size
        self.pool: Optional[BlockPool] = None    # most recent run's pool
        self.share_prefix = share_prefix
        self.prefix_cache_entries = prefix_cache_entries
        self.prefix_cache: Optional[_PrefixCache] = None  # most recent run's
        if share_prefix and not paged:
            raise ValueError("share_prefix requires paged=True: sharing is "
                             "block-table indirection over the block pool")
        # ladders bounding compiled shapes of the shared fan-out paths
        # (lanes per prefill row, CoW copy pairs per wave)
        self._fan_buckets = make_buckets(n_lanes, 1)
        if paged:
            self.max_blocks = -(-self.s_max // block_size)
            self.pool_blocks = (n_lanes * self.max_blocks
                                if pool_blocks is None else pool_blocks)
            if self.pool_blocks < self.max_blocks:
                raise ValueError(
                    f"pool_blocks={self.pool_blocks} cannot hold one "
                    f"worst-case lane ({self.max_blocks} blocks): admission "
                    "could never make progress")
            # fail fast on configs the paged cache cannot serve
            model_lib.init_paged_decode_state(cfg, 1, self.s_max,
                                              block_size, 1)

    # ------------------------------------------------------------------
    def _encode(self, req: Request) -> List[int]:
        if req.tokens is not None:
            return list(req.tokens)[: max(self.buckets)]
        return self.tokenizer.encode(req.prompt, bos=True)[: max(self.buckets)]

    def _budget(self, req: Request) -> int:
        b = req.max_new_tokens or self.gcfg.max_new_tokens
        return min(b, self.gcfg.max_new_tokens)

    def _reservation(self, prompt_len: int, budget: int) -> int:
        """Blocks a lane may touch over its lifetime: prompt + budget,
        rounded up to whole blocks."""
        return -(-(prompt_len + budget) // self.block_size)

    def _intake(self, requests) -> Tuple[List, List[int]]:
        """Normalize the submitted mix of Requests and RequestGroups to
        admission units plus the flat uid order of the reply.

        Sharing off (or dense): groups dissolve into their members.
        Sharing on: groups survive as atomic units, chunked to the lane
        pool width so a K > n_lanes group can still admit."""
        units: List = []
        order: List[int] = []
        for r in requests:
            if isinstance(r, RequestGroup):
                order.extend(m.uid for m in r.requests)
                if self.share_prefix:
                    for i in range(0, len(r.requests), self.n_lanes):
                        units.append(RequestGroup(
                            list(r.requests[i:i + self.n_lanes])))
                else:
                    units.extend(r.requests)
            else:
                order.append(r.uid)
                units.append(r)
        return units, order

    def _plan_unit(self, members: List[Request],
                   enc: Dict[int, List[int]]) -> Tuple[List[_PlanRow], int]:
        """Lay out one admission unit as prefill rows and price its pool
        reservation.  Token-identical members collapse onto one shared
        row; otherwise every member rows alone (no sharing, still
        atomic).  The reservation covers newly allocated prompt blocks
        (cache hits excluded), every member's decode growth, and one
        CoW clone per extra holder of a partial tail block."""
        toks0 = enc[members[0].uid]
        if len(members) > 1 and all(enc[m.uid] == toks0
                                    for m in members[1:]):
            row_members = [members]
        else:
            row_members = [[m] for m in members]
        rows, need = [], 0
        for ms in row_members:
            toks = enc[ms[0].uid]
            p_len = max(len(toks), 1)
            n_pb = -(-p_len // self.block_size)
            n_full = p_len // self.block_size
            partial = n_full < n_pb
            hit = (self.prefix_cache.lookup(toks)
                   if self.prefix_cache is not None else [])
            growth = sum(self._reservation(p_len, self._budget(m)) - n_pb
                         for m in ms)
            need += (n_pb - len(hit)) + growth
            if partial:
                need += len(ms) - 1
            rows.append(_PlanRow(toks=toks, members=ms, hit=hit, n_pb=n_pb,
                                 n_full=n_full, partial=partial))
        return rows, need

    # ------------------------------------------------------------------
    def run(self, requests: Sequence, key,
            stop_policy: Optional[StopPolicy] = None
            ) -> Tuple[List[Completion], SchedStats]:
        """Drive every request (or RequestGroup) to completion; returns
        completions in request order (groups flattened in place) plus
        scheduling statistics."""
        t0 = time.time()
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        stats = SchedStats()
        units, order = self._intake(requests)
        pending = collections.deque(units)
        lanes: List[Optional[_Lane]] = [None] * self.n_lanes
        host_done = np.ones((self.n_lanes,), bool)
        if self.paged:
            pool = BlockPool(self.pool_blocks, self.block_size)
            self.pool = pool
            self.prefix_cache = (_PrefixCache(pool, self.block_size,
                                              self.prefix_cache_entries)
                                 if self.share_prefix else None)
            cache = model_lib.init_paged_decode_state(
                self.cfg, self.n_lanes, self.s_max, self.block_size,
                self.pool_blocks)
            host_table = np.zeros((self.n_lanes, self.max_blocks), np.int32)
            table_dirty = False
        else:
            pool = None
            self.prefix_cache = None
            cache = model_lib.init_decode_state(self.cfg, self.n_lanes,
                                                self.s_max)
        cur_logits = jnp.zeros((self.n_lanes, self.cfg.vocab_size),
                               jnp.float32)
        completions: Dict[int, Completion] = {}
        decided: set = set()
        # tokenization memo: a pool-blocked head-of-queue request is
        # re-examined every round; encode it once, not once per round
        enc: Dict[int, List[int]] = {}
        global_step = 0

        def finalize(i: int, cancelled: bool):
            nonlocal table_dirty
            lane = lanes[i]
            toks = (np.concatenate(lane.parts) if lane.parts
                    else np.zeros((0,), np.int32))
            text = self.tokenizer.decode(toks) if self.tokenizer else ""
            comp = Completion(lane.req.uid, lane.req.group, toks, len(toks),
                              text, cancelled, lane.req.meta)
            completions[lane.req.uid] = comp
            if self.paged:
                # reclaim immediately: blocks (and the unused tail of the
                # reservation) go back to the pool mid-flight, and the
                # lane's table row points at the trash block so its
                # remaining in-round steps write nowhere
                pool.free(lane.blocks)
                pool.unreserve(lane.reserved)
                lane.blocks, lane.reserved = [], 0
                host_table[i] = 0
                table_dirty = True
            lanes[i] = None
            host_done[i] = True
            if cancelled:
                stats.cancelled += 1
            return comp

        def drop_decided(members: List[Request]):
            for m in members:
                completions[m.uid] = Completion(
                    m.uid, m.group, np.zeros((0,), np.int32), 0, "",
                    True, m.meta)
                stats.cancelled += 1

        def admit_shared():
            """Shared-prefix admission: atomic group units, one prefill
            row per distinct prompt, prompt blocks refcount-shared into
            every member lane, CoW on partial tails, prefix-cache
            reuse/registration.  See the class docstring."""
            nonlocal cache, cur_logits, table_dirty
            free = [i for i in range(self.n_lanes) if lanes[i] is None]
            planned: List[_PlanRow] = []
            taken = 0
            while pending:
                unit = pending[0]
                members = (unit.requests if isinstance(unit, RequestGroup)
                           else [unit])
                if all(m.group is not None and m.group in decided
                       for m in members):
                    pending.popleft()
                    drop_decided(members)
                    continue
                if taken + len(members) > len(free):
                    break              # atomic: the whole unit or nothing
                for m in members:
                    if m.uid not in enc:
                        enc[m.uid] = self._encode(m)
                rows = None
                blocked = False
                while True:
                    rows, need = self._plan_unit(members, enc)
                    if need > self.pool_blocks:
                        # the unit can never fit atomically: degrade to
                        # per-lane units (constructor guarantees any
                        # single lane fits) and re-examine the head
                        pending.popleft()
                        for m in reversed(members):
                            pending.appendleft(m)
                        rows = None
                        break
                    if pool.reserve(need):
                        break
                    # pool pressure: shed warm prefix-cache blocks
                    # before backpressuring admission
                    if not self.prefix_cache.evict_lru():
                        stats.admission_blocked += 1
                        blocked = True
                        break
                if blocked:
                    break
                if rows is None:
                    continue
                # hold the cache-hit blocks for every lane of each row
                # now, so later evictions can only drop the cache's own
                # hold, never the blocks these lanes are about to map
                for row in rows:
                    if row.hit:
                        pool.share(row.hit, len(row.members))
                        stats.prefix_hits += 1
                        stats.prefix_hit_blocks += len(row.hit)
                pending.popleft()
                planned.extend(rows)
                taken += len(members)
            if not planned:
                return
            by_bucket: Dict[int, List[_PlanRow]] = collections.defaultdict(list)
            for row in planned:
                by_bucket[pick_bucket(len(row.toks), self.buckets)
                          ].append(row)
            cow_src: List[int] = []
            cow_dst: List[int] = []
            for bucket in sorted(by_bucket):
                rows = by_bucket[bucket]
                admit_n = pick_bucket(len(rows), self.admit_buckets)
                kmax = pick_bucket(max(len(r.members) for r in rows),
                                   self._fan_buckets)
                toks, lens = pad_token_rows([r.toks for r in rows],
                                            self.gcfg.pad_id, bucket,
                                            admit_n)
                lane_rows = np.full((admit_n, kmax), self.n_lanes, np.int32)
                write_rows = np.zeros((admit_n, self.max_blocks), np.int32)
                for j, row in enumerate(rows):
                    p_len = max(len(row.toks), 1)
                    h = len(row.hit)
                    own = pool.alloc(row.n_pb - h)
                    prompt_blocks = row.hit + own
                    # write side: cache-satisfied positions land in the
                    # trash block (their KV already exists, and earlier
                    # holders must keep bit-identical reads)
                    write_rows[j, h:row.n_pb] = own
                    k_members = len(row.members)
                    if k_members > 1 and own:
                        pool.share(own, k_members - 1)
                    self.prefix_cache.register(row.toks,
                                               prompt_blocks[:row.n_full])
                    tail_of = {}
                    if row.partial:
                        tail = prompt_blocks[-1]
                        for m in row.members:
                            blk, copied = pool.cow(tail)
                            if copied:
                                cow_src.append(tail)
                                cow_dst.append(blk)
                            tail_of[m.uid] = blk
                    for mj, m in enumerate(row.members):
                        i = free.pop(0)
                        lane = _Lane(m, self._budget(m))
                        lane.prompt_len = p_len
                        lane.blocks = list(prompt_blocks)
                        if row.partial:
                            lane.blocks[-1] = tail_of[m.uid]
                        lane.reserved = self._reservation(
                            p_len, lane.budget) - row.n_pb
                        host_table[i] = 0
                        host_table[i, :row.n_pb] = lane.blocks
                        lane_rows[j, mj] = i
                        lanes[i] = lane
                        host_done[i] = False
                    table_dirty = True
                    stats.shared_lanes += k_members - 1
                last, new_cache = prefill_shared(
                    self.params, self.cfg, jnp.asarray(toks),
                    jnp.asarray(lens), bucket)
                cache, cur_logits = insert_lanes_shared(
                    cache, cur_logits, new_cache, last,
                    jnp.asarray(lane_rows), jnp.asarray(write_rows))
                stats.prefills += 1
                stats.prefill_prompts += len(rows)
                stats.prefill_tokens += sum(len(r.toks) for r in rows)
            if cow_src:
                # device half of CoW, after the inserts wrote the
                # originals; padded pairs clone trash onto trash
                n = pick_bucket(len(cow_src), self._fan_buckets)
                src = np.zeros((n,), np.int32)
                dst = np.zeros((n,), np.int32)
                src[: len(cow_src)] = cow_src
                dst[: len(cow_dst)] = cow_dst
                cache = copy_blocks(cache, jnp.asarray(src),
                                    jnp.asarray(dst))

        while pending or any(l is not None for l in lanes):
            # ---- admission: fill free lanes from the pending queue ----
            if self.share_prefix:
                admit_shared()
                wave: List[Request] = []
            else:
                free = [i for i in range(self.n_lanes)
                        if lanes[i] is None]
                wave = []
                while pending and len(wave) < len(free):
                    req = pending[0]
                    if req.group in decided:
                        pending.popleft()
                        drop_decided([req])
                        continue
                    if req.uid not in enc:
                        enc[req.uid] = self._encode(req)
                    if self.paged:
                        need = self._reservation(max(len(enc[req.uid]), 1),
                                                 self._budget(req))
                        if not pool.reserve(need):
                            # pool pressure: leave the queue intact (FIFO)
                            # and retry after the next round frees blocks
                            stats.admission_blocked += 1
                            break
                    pending.popleft()
                    wave.append(req)
            if wave:
                by_bucket: Dict[int, List[Request]] = collections.defaultdict(list)
                for r in wave:
                    by_bucket[pick_bucket(len(enc[r.uid]), self.buckets)
                              ].append(r)
                for bucket in sorted(by_bucket):
                    grp = by_bucket[bucket]
                    admit_n = pick_bucket(len(grp), self.admit_buckets)
                    toks, lens = pad_token_rows([enc[r.uid] for r in grp],
                                                self.gcfg.pad_id, bucket,
                                                admit_n)
                    lane_ids = np.full((admit_n,), self.n_lanes, np.int32)
                    block_rows = (np.zeros((admit_n, self.max_blocks),
                                           np.int32) if self.paged else None)
                    for j, r in enumerate(grp):
                        i = free.pop(0)
                        lane_ids[j] = i
                        lane = _Lane(r, self._budget(r))
                        if self.paged:
                            lane.prompt_len = max(len(enc[r.uid]), 1)
                            n_pb = -(-lane.prompt_len // self.block_size)
                            lane.blocks = pool.alloc(n_pb)
                            lane.reserved = self._reservation(
                                lane.prompt_len, lane.budget) - n_pb
                            block_rows[j, :n_pb] = lane.blocks
                            host_table[i] = block_rows[j]
                            table_dirty = True
                        lanes[i] = lane
                        host_done[i] = False
                    if self.paged:
                        # prefill dense at the prompt bucket only, then
                        # scatter the rows into their allocated pages
                        last, new_cache = prefill_jit(
                            self.params, self.cfg, jnp.asarray(toks),
                            jnp.asarray(lens), bucket)
                        cache, cur_logits = insert_lanes_paged(
                            cache, cur_logits, new_cache, last,
                            jnp.asarray(lane_ids), jnp.asarray(block_rows))
                    else:
                        last, new_cache = prefill_jit(
                            self.params, self.cfg, jnp.asarray(toks),
                            jnp.asarray(lens), self.s_max)
                        cache, cur_logits = insert_lanes(
                            cache, cur_logits, new_cache, last,
                            jnp.asarray(lane_ids))
                    stats.prefills += 1
                    stats.prefill_prompts += len(grp)
                    stats.prefill_tokens += sum(len(enc[r.uid]) for r in grp)

            live = [i for i in range(self.n_lanes) if lanes[i] is not None]
            if not live:
                continue           # only decided-group requests were queued

            # ---- one decode round over the whole pool ----
            r = self.round_tokens
            if self.paged:
                # grow each live lane's block table one round ahead of
                # its decode position (drawn from its reservation, so
                # this can never fail); writes past the budget spill
                # into the trash block by construction
                for i in live:
                    lane = lanes[i]
                    upto = min(lane.prompt_len + lane.generated + r,
                               lane.prompt_len + lane.budget)
                    grow = -(-upto // self.block_size) - len(lane.blocks)
                    if grow > 0:
                        new_ids = pool.alloc(grow)
                        host_table[i, len(lane.blocks):
                                   len(lane.blocks) + grow] = new_ids
                        lane.blocks.extend(new_ids)
                        lane.reserved -= grow
                        table_dirty = True
                if table_dirty:
                    cache["block_tables"] = jnp.asarray(host_table)
                    table_dirty = False
            cache, cur_logits, _, toks = decode_round(
                self.params, self.cfg, self.gcfg, cache, cur_logits,
                jnp.asarray(host_done), key, jnp.int32(global_step), r)
            global_step += r
            stats.rounds += 1
            stats.lane_rounds += len(live)
            toks_np = np.asarray(toks)

            # ---- harvest: EOS / budget per live lane ----
            newly: List[int] = []
            for i in live:
                lane = lanes[i]
                take = toks_np[i, : min(r, lane.budget - lane.generated)]
                eos = np.nonzero(take == self.gcfg.eos_id)[0]
                finished = False
                if len(eos):
                    take = take[: int(eos[0]) + 1]
                    finished = True
                lane.parts.append(take)
                lane.generated += len(take)
                stats.generated_tokens += len(take)
                if finished or lane.generated >= lane.budget:
                    newly.append(i)

            # ---- finalize + vote-aware early stop ----
            newly.sort(key=lambda i: (lanes[i].generated, lanes[i].req.uid))
            for i in newly:
                comp = finalize(i, cancelled=False)
                if stop_policy is not None:
                    decided.update(stop_policy.observe(comp))
            if decided:
                for i in range(self.n_lanes):
                    if lanes[i] is not None and lanes[i].req.group in decided:
                        finalize(i, cancelled=True)

        if self.prefix_cache is not None:
            # the cache's lifetime is the run: release its block holds
            # so the pool drains to empty (leak checks rely on this)
            self.prefix_cache.clear()
        stats.wall_s = time.time() - t0
        self._cache_stats(stats, cache, pool)
        if pool is not None:
            stats.cow_copies = pool.cow_copies
        return [completions[uid] for uid in order], stats

    # ------------------------------------------------------------------
    def _cache_stats(self, stats: SchedStats, cache, pool: Optional[BlockPool]):
        """Fill the K/V-footprint fields (see SchedStats)."""
        if not self.cfg.has_attention:
            return
        kv_bytes = cache["k"].nbytes + cache["v"].nbytes
        for s in ("k_scale", "v_scale"):
            if s in cache:
                kv_bytes += cache[s].nbytes
        if self.paged:
            per_block = kv_bytes // (self.pool_blocks + 1)   # incl. trash
            per_slot = per_block // self.block_size
            sc = model_lib.cache_length(self.cfg, self.s_max)
            stats.pool_blocks = self.pool_blocks
            stats.peak_blocks_in_use = pool.peak_in_use
            stats.peak_cache_bytes = per_block * pool.peak_in_use
            stats.dense_cache_bytes = per_slot * sc * self.n_lanes
        else:
            stats.peak_cache_bytes = kv_bytes
            stats.dense_cache_bytes = kv_bytes
