"""Draft-SLM speculative serving: one lane pool drafts, another
verifies, interleaved split-phase.

Classic speculative decoding pairs a small *draft* model with the
*target* model: the draft proposes a burst of tokens cheaply, the
target verifies the whole burst in one forward pass and keeps the
longest prefix matching its own (greedy or salted-sampled) stream.
This module runs that loop on the serving stack's own primitives — no
new device code:

  * the **target** scheduler runs with ``spec_k`` set, so its rounds
    verify queued drafts via ``batch.decode_round_spec``;
  * the **draft** scheduler is a plain serving loop over the small
    model; each of its requests is a short *burst*: the target
    request's prompt plus everything the target has committed so far
    (``ServingLoop.progress``), continued ``draft_burst`` tokens;
  * one host loop drives both, split-phase: both loops' rounds are
    dispatched before either is harvested, so the draft model's decode
    overlaps the target's verify round on-device (JAX async dispatch)
    — the same overlap discipline the pipelined cascade uses.

Harvested bursts are fed to the target with
``add_drafts(uid, tokens, start=<progress at burst submission>)``; the
start offset lets the target skip any tokens it already generated
while the burst was in flight, and its divergence pruning drops stale
bursts automatically.  Because verification only ever commits tokens
the target would have sampled anyway (the ``decode_round_spec``
contract), completions are bit-identical to undrafted serving — the
draft model can only change wall-clock and round counts, never output.

Sizing note: the draft scheduler's ``max_prompt_len`` must cover the
target's prompt *plus* its generation budget (burst prompts grow with
target progress); a burst whose prompt gets bucket-truncated just
produces low-acceptance drafts, costing speed, never correctness.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.scheduler import (Completion, Request, SchedStats,
                                     Scheduler, StopPolicy)

# draft-burst uids live in their own namespace so a burst can never
# collide with a target request uid in the draft loop's bookkeeping
_DRAFT_UID_BASE = 1 << 48


def speculative_generate(target: Scheduler, draft: Scheduler,
                         requests: Sequence[Request], key,
                         draft_burst: Optional[int] = None,
                         stop_policy: Optional[StopPolicy] = None
                         ) -> Tuple[List[Completion], SchedStats, SchedStats]:
    """Serve ``requests`` on ``target`` (which must have ``spec_k``
    set) with ``draft`` generating speculative bursts for every live
    request.  Returns ``(completions, target_stats, draft_stats)``,
    completions in submission order — bit-identical to serving the
    same requests on ``target`` without a draft model.

    ``draft_burst`` is the tokens per draft burst (default
    ``2 * spec_k``: one burst covers two verify rounds, so the pipeline
    rarely runs dry while the next burst is in flight).
    """
    if target.spec_k is None:
        raise ValueError("speculative_generate requires the target "
                         "Scheduler to be built with spec_k=...")
    burst = draft_burst if draft_burst is not None else 2 * target.spec_k
    if burst < 1:
        raise ValueError(f"draft_burst={burst} must be >= 1")

    loop_t = target.loop(key, stop_policy)
    loop_d = draft.loop(key)
    loop_t.submit(requests)
    # the burst prompt needs the request's token form; encode once with
    # the *target*'s rules (the models must share a tokenizer for the
    # draft's proposals to mean anything)
    prompt_of: Dict[int, List[int]] = {
        r.uid: target._encode(r) for r in requests}
    bursts: Dict[int, Tuple[int, int]] = {}     # duid -> (uid, start)
    inflight: Dict[int, int] = {}               # uid -> its current duid
    next_duid = _DRAFT_UID_BASE
    done: set = set()
    completions: List[Completion] = []

    while loop_t.has_work:
        # split-phase: launch both rounds before blocking on either
        dt = loop_t.dispatch()
        dd = loop_d.has_work and loop_d.dispatch()
        comps_t = loop_t.harvest() if dt else loop_t.take_completed()
        comps_d = loop_d.harvest() if dd else loop_d.take_completed()
        for c in comps_t:
            done.add(c.uid)
            inflight.pop(c.uid, None)
            completions.append(c)
        for c in comps_d:
            uid, start = bursts.pop(c.uid)
            if inflight.get(uid) != c.uid or uid in done:
                continue                        # stale burst; drop it
            inflight.pop(uid)
            if c.gen_len:
                loop_t.add_drafts(uid, c.tokens, start=start)
        loop_d.release([c.uid for c in comps_d])
        # re-draft every live, undrafted, burst-less target request
        # from its current progress
        for lane in loop_t.lanes:
            if lane is None or not lane.ready:
                continue
            uid = lane.req.uid
            if (uid in inflight or uid in done
                    or uid in loop_t._drafts or uid not in prompt_of):
                continue
            progress = loop_t.progress(uid)
            start = 0 if progress is None else len(progress)
            toks = prompt_of[uid] + ([] if progress is None
                                     else [int(t) for t in progress])
            duid = next_duid
            next_duid += 1
            bursts[duid] = (uid, start)
            inflight[uid] = duid
            loop_d.submit([Request(uid=duid, tokens=toks,
                                   max_new_tokens=burst)])

    # run the draft loop's outstanding bursts dry (they are short, and
    # a drained loop returns its pool blocks — leak_report stays clean)
    while loop_d.has_work:
        loop_d.step()
    order = {uid: j for j, uid in enumerate(r.uid for r in requests)}
    completions.sort(key=lambda c: order.get(c.uid, len(order)))
    return completions, loop_t.close(), loop_d.close()
