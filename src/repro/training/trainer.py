"""Generic training loop + SFT step builders (full-params or LoRA).

Steps are pure functions built once per (cfg, optimizer) and jitted by
the caller; the distributed launcher wraps the same builders in pjit
with sharding annotations (see launch/train.py).
"""

from __future__ import annotations

import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.training import lora as lora_lib
from repro.training.optimizer import Optimizer


# ----------------------------------------------------------------------
# Loss on a packed batch {tokens, loss_mask}
# ----------------------------------------------------------------------

def batch_loss(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]
    mask = batch["loss_mask"][:, 1:]
    logits, aux = model_lib.forward(params, cfg, tokens=inputs)
    return model_lib.lm_loss(cfg, logits, labels, mask, aux)


def _microbatched(loss_fn, microbatches: int):
    """Split the batch on axis 0 and average loss via lax.scan (grad
    accumulation happens implicitly through the scan's linearization)."""
    if microbatches <= 1:
        return loss_fn

    def wrapped(params, cfg, batch):
        def one(carry, mb):
            loss, metrics = loss_fn(params, cfg, mb)
            return carry, (loss, metrics)

        mbs = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
            batch)
        _, (losses, metrics) = jax.lax.scan(one, 0, mbs)
        return jnp.mean(losses), jax.tree.map(jnp.mean, metrics)

    return wrapped


# ----------------------------------------------------------------------
# Step builders
# ----------------------------------------------------------------------

def make_sft_step(cfg: ModelConfig, opt: Optimizer,
                  loss_fn: Callable = batch_loss):
    """Full-parameter SFT step: state = {params, opt_state, step}."""
    loss_fn = _microbatched(loss_fn, cfg.microbatches)

    def step(state, batch):
        def lf(p):
            return loss_fn(p, cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        new_params, new_opt = opt.update(grads, state["opt_state"], state["params"])
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt_state": new_opt,
                "step": state["step"] + 1}, metrics

    return step


def make_lora_sft_step(cfg: ModelConfig, opt: Optimizer, lcfg: lora_lib.LoraConfig,
                       loss_fn: Callable = batch_loss):
    """LoRA SFT step: state = {base, lora, opt_state, step}; grads only
    touch the adapter tree (base is stop-grad inside merge)."""
    loss_fn = _microbatched(loss_fn, cfg.microbatches)

    def step(state, batch):
        def lf(lora_tree):
            merged = lora_lib.merge(state["base"], lora_tree, lcfg)
            return loss_fn(merged, cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["lora"])
        new_lora, new_opt = opt.update(grads, state["opt_state"], state["lora"])
        metrics = dict(metrics, loss=loss)
        return {"base": state["base"], "lora": new_lora, "opt_state": new_opt,
                "step": state["step"] + 1}, metrics

    return step


# ----------------------------------------------------------------------
# Loop
# ----------------------------------------------------------------------

def train_loop(step_fn, state, batches: Iterable, log_every: int = 20,
               log_fn=print, max_steps: Optional[int] = None,
               checkpoint_every: Optional[int] = None,
               checkpoint_fn: Optional[Callable] = None):
    step_fn = jax.jit(step_fn)
    history = []
    t0 = time.time()
    for i, batch in enumerate(batches):
        if max_steps is not None and i >= max_steps:
            break
        state, metrics = step_fn(state, batch)
        if checkpoint_every and checkpoint_fn and i and i % checkpoint_every == 0:
            checkpoint_fn(state, i)
        if i % log_every == 0 or (max_steps and i == max_steps - 1):
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            if log_fn:
                log_fn(f"step {i:5d} " + " ".join(
                    f"{k}={v:.4f}" for k, v in m.items()
                    if k not in ("step", "wall_s", "n_tokens")))
    return state, history
