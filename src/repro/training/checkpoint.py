"""Checkpointing: pytrees -> .npz + a JSON treedef manifest.

No orbax offline; this is a dependency-free save/restore that round-trips
arbitrary nested dict/list pytrees of jnp arrays, including optimizer
state and LoRA adapter trees (None leaves preserved).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="", out=None, meta=None):
    out = {} if out is None else out
    meta = {} if meta is None else meta
    if tree is None:
        meta[prefix] = "none"
    elif isinstance(tree, dict):
        meta[prefix] = {"dict": sorted(tree.keys())}
        for k in sorted(tree.keys()):
            _flatten(tree[k], f"{prefix}/{k}", out, meta)
    elif isinstance(tree, (list, tuple)):
        meta[prefix] = {"list": len(tree), "tuple": isinstance(tree, tuple)}
        for i, v in enumerate(tree):
            _flatten(v, f"{prefix}/{i}", out, meta)
    else:
        arr = np.asarray(tree)
        if arr.dtype == jnp.bfloat16:
            out[prefix] = arr.view(np.uint16)
            meta[prefix] = "bfloat16"
        else:
            out[prefix] = arr
            meta[prefix] = "array"
    return out, meta


def _unflatten(prefix, arrays, meta):
    m = meta[prefix]
    if m == "none":
        return None
    if m == "array":
        return jnp.asarray(arrays[prefix])
    if m == "bfloat16":
        return jnp.asarray(arrays[prefix].view(np.uint16)).view(jnp.bfloat16)
    if isinstance(m, dict) and "dict" in m:
        return {k: _unflatten(f"{prefix}/{k}", arrays, meta) for k in m["dict"]}
    if isinstance(m, dict) and "list" in m:
        items = [_unflatten(f"{prefix}/{i}", arrays, meta) for i in range(m["list"])]
        return tuple(items) if m.get("tuple") else items
    raise ValueError(f"bad meta at {prefix}: {m}")


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, meta = _flatten(jax.device_get(tree))
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    mpath = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    with open(mpath, "w") as f:
        json.dump(meta, f)


def restore(path: str) -> Any:
    npz = path if path.endswith(".npz") else path + ".npz"
    mpath = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    with np.load(npz) as data:
        arrays = {k: data[k] for k in data.files}
    with open(mpath) as f:
        meta = json.load(f)
    return _unflatten("", arrays, meta)
