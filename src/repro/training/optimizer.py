"""AdamW + cosine-with-warmup LR schedule, implemented from scratch
(no optax in this container).

The paper trains both SATER stages with AdamW lr=1e-4, cosine schedule,
10% warmup, for one epoch (Appendix C) — those are the defaults here.
API mirrors optax's (init, update) pair so it drops into pjit'd steps.
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def cosine_warmup_schedule(base_lr: float, total_steps: int,
                           warmup_ratio: float = 0.1,
                           final_lr_ratio: float = 0.0):
    warmup = max(1, int(total_steps * warmup_ratio))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / warmup
        t = jnp.clip((step - warmup) / max(1, total_steps - warmup), 0.0, 1.0)
        cos = final_lr_ratio + (1 - final_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, clip_norm: float = 1.0) -> Optimizer:
    """AdamW with decoupled weight decay and global-norm clipping.

    Moments are kept in f32 regardless of param dtype (mixed-precision
    master-moment convention); params are updated in their own dtype.
    """

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": zeros, "nu": jax.tree.map(jnp.zeros_like, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9)) if clip_norm else 1.0
        lr = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32) * scale
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / bc1
            nhat = nu / bc2
            delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        return new_p, {"mu": new_mu, "nu": new_nu, "step": step}

    return Optimizer(init, update)
