"""LoRA adapters (the paper fine-tunes with rank=8, alpha=16, dropout=0.1).

Adapters mirror selected 2-D weight leaves of the base param tree as
{"a": (d_in, r), "b": (r, d_out)} pairs; :func:`merge` produces effective
params ``w + (a @ b) * alpha / r`` with the base tree under stop_gradient,
so ``jax.grad`` w.r.t. the adapter tree touches only LoRA weights.

The DPO reference model comes for free: ``policy = merge(base, lora)``
and ``reference = base`` — one copy of the base weights in memory.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


# leaf names inside a layer dict that receive adapters
DEFAULT_TARGETS = ("wq", "wk", "wv", "wo", "wi_gate", "wi_up", "wi", "wo_mlp")


@dataclasses.dataclass(frozen=True)
class LoraConfig:
    rank: int = 8
    alpha: float = 16.0
    dropout: float = 0.1   # applied to the input of A during training
    targets: Sequence[str] = DEFAULT_TARGETS


def _is_target(path, leaf, targets) -> bool:
    if leaf.ndim < 2:
        return False
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    # target attention + mlp projections inside layer stacks
    return name in targets and any(
        (getattr(p, "key", None) in ("attn", "mlp", "moe", "shared")) for p in path)


def init_lora(params, cfg: LoraConfig, key):
    """Build an adapter tree with the same structure (None on non-targets)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, leaf), k in zip(flat, keys):
        if _is_target(path, leaf, cfg.targets):
            *lead, d_in, d_out = leaf.shape
            a = jax.random.normal(k, (*lead, d_in, cfg.rank)) * (1.0 / d_in ** 0.5)
            b = jnp.zeros((*lead, cfg.rank, d_out))
            leaves.append({"a": a.astype(leaf.dtype), "b": b.astype(leaf.dtype)})
        else:
            leaves.append(None)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), leaves)


def merge(params, lora_tree, cfg: LoraConfig, stop_base_grad: bool = True,
          spec_tree=None):
    """Effective params: base + a@b * (alpha / rank).  Base is stop-grad.

    ``spec_tree`` (optional PartitionSpec tree): §Perf — without it, XLA
    tends to all-gather the full merged weight every layer (the sharded
    base plus the replicated LoRA delta resolves to replicated); pinning
    the merged leaf to the base sharding keeps the add shard-local.
    """
    scale = cfg.alpha / cfg.rank

    def mrg(p, ad, spec=None):
        if stop_base_grad:
            p = jax.lax.stop_gradient(p)
        if ad is not None:
            delta = jnp.einsum("...ir,...ro->...io", ad["a"], ad["b"]) * scale
            p = (p.astype(jnp.float32) + delta.astype(jnp.float32)).astype(p.dtype)
        if spec is not None:
            p = jax.lax.with_sharding_constraint(p, spec)
        return p

    # lora_tree subtrees ({"a","b"} dicts / None) are matched whole against
    # params leaves via flatten_up_to inside tree.map.
    if spec_tree is None:
        return jax.tree.map(mrg, params, lora_tree)
    return jax.tree.map(mrg, params, lora_tree, spec_tree)


def n_lora_params(lora_tree) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(lora_tree))
