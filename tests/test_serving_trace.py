"""Randomized differential serving-trace harness.

The serving engine's central promise after the per-request PRNG
contract (serving/batch.py) is *trace independence*: what a request
generates depends only on (master key, uid, prompt, budget, decode
settings) — never on when it arrived, which lane it landed in, which
cache layout served it, or whether its prompt was prefilled whole or
in chunks.  This module generates random serving traces — arrivals
between rounds, vote-group sizes, per-request budgets, ``release()``
calls, mid-flight StopPolicy kills, and (in the preempted variants)
random ``preempt()``/``resume()`` schedules that park live lanes to
host RAM and restore them into whatever lane is free — and drives
them through every serving configuration:

    {dense, paged, shared-prefix} x {chunked, unchunked} x {greedy, sampled}

plus, per cache mode, a *drafted* variant (``spec_k`` set, every
submission carrying speculative draft queues mixing oracle prefixes
with junk tails), asserting each completion is bit-identical to a
one-shot ``engine.generate`` oracle run for that request alone
(cancelled requests must be an exact prefix of their oracle tokens),
and that the block pool's ``leak_report()`` is clean after
``close()``.  Speculation riding the same oracle check is the
strongest form of its contract: verify rounds may change how many
rounds a trace takes, never one bit of what any request generates.

Two drivers share the machinery:

  * a seeded-fuzz driver that always runs (no extra deps), covering the
    full 12-configuration matrix over a few generated traces;
  * a hypothesis *stateful* machine (skipped when hypothesis is not
    installed) that interleaves submit/step/kill/release arbitrarily
    against the most intricate configuration (shared-prefix + chunked)
    and checks the same oracle equivalence at teardown.
"""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.serving.batch import GenConfig, pick_bucket
from repro.serving.engine import generate
from repro.serving.scheduler import (Request, RequestGroup, Scheduler,
                                     StopPolicy)

MAXP = 48          # prompt-length cap == largest prompt bucket
MAXNEW = 10        # decode budget cap (oracle decodes this, then truncates)
N_LANES = 4
ROUND = 5
BLOCK = 8
MASTER_KEY = 7


@pytest.fixture(scope="module")
def setup():
    return _setup()


_CACHED = {}


def _setup():
    """Tiny attention-only model, shared by both drivers (module-level
    cache so the hypothesis machine, which cannot take fixtures, reuses
    the same jit cache)."""
    if not _CACHED:
        from repro.data.tokenizer import default_tokenizer
        from repro.models import model as M
        tok = default_tokenizer()
        cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                          d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                          d_ff=128, vocab_size=tok.vocab_size, remat=False,
                          source="test")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        _CACHED["v"] = (params, cfg, tok)
    return _CACHED["v"]


def _gcfg(temperature):
    return GenConfig(max_new_tokens=MAXNEW, temperature=temperature,
                     top_p=1.0, eos_id=2)


def _scheduler(params, cfg, temperature, mode, chunked,
               prefill_budget=None, spec=False, pool_blocks=None,
               auto_preempt=False, mesh=None, n_lanes=N_LANES):
    return Scheduler(params, cfg, tokenizer=None, gcfg=_gcfg(temperature),
                     n_lanes=n_lanes, round_tokens=ROUND,
                     max_prompt_len=MAXP,
                     paged=mode in ("paged", "shared"), block_size=BLOCK,
                     share_prefix=mode == "shared",
                     chunk_size=BLOCK if chunked else None,
                     prefill_budget=prefill_budget if chunked else None,
                     spec_k=4 if spec else None,
                     pool_blocks=pool_blocks, auto_preempt=auto_preempt,
                     mesh=mesh)


# ----------------------------------------------------------------------
# The per-request oracle
# ----------------------------------------------------------------------

class Oracle:
    """One-shot ``engine.generate`` per request, at the scheduler's
    exact geometry: the prompt padded to its scheduler bucket, the
    decode cache at the scheduler's ``s_max`` width, the request's uid
    as its sample-stream salt.  The row is duplicated to a 2-row batch
    because size-1 batch dims can lower to differently-ordered
    reductions (see the scheduler's admit-bucket note)."""

    def __init__(self, params, cfg, sched: Scheduler, temperature):
        self.params, self.cfg = params, cfg
        self.buckets = sched.buckets
        self.s_max = sched.s_max
        self.gcfg = _gcfg(temperature)
        self.key = jax.random.PRNGKey(MASTER_KEY)
        self._memo = {}

    def tokens(self, uid, prompt_toks, budget):
        """The exact token array a serving completion for this request
        must carry (truncated at EOS or ``budget``)."""
        memo_key = (uid, tuple(prompt_toks), budget)
        if memo_key in self._memo:
            return self._memo[memo_key]
        toks = list(prompt_toks)[:max(self.buckets)]
        bucket = pick_bucket(max(len(toks), 1), self.buckets)
        rows = np.zeros((2, bucket), np.int32)
        rows[0, :len(toks)] = toks
        rows[1, :len(toks)] = toks
        lens = np.full((2,), max(len(toks), 1), np.int32)
        gen, _ = generate(self.params, self.cfg, rows, lens, self.key,
                          self.gcfg, salts=np.array([uid, uid], np.int32),
                          s_max=self.s_max)
        seg = gen[0, :budget]
        eos = np.nonzero(seg == self.gcfg.eos_id)[0]
        n = int(eos[0]) + 1 if eos.size else budget
        out = seg[:n].copy()
        self._memo[memo_key] = out
        return out


# ----------------------------------------------------------------------
# Trace generation + replay
# ----------------------------------------------------------------------

class ScriptedKills(StopPolicy):
    """Kills a group the moment any of its members finalizes, for the
    trace's predetermined kill set — eviction churn (including kills
    landing mid-prefill) for the differential run to ride over."""

    def __init__(self, kill_groups):
        self.kill_groups = set(kill_groups)

    def observe(self, completion):
        if completion.group in self.kill_groups:
            return (completion.group,)
        return ()


def make_trace(seed, n_rounds=10, vocab=96):
    """A trace is pure data: per-round submission lists (mixing plain
    Requests and RequestGroups, token-identical and not), release
    rounds, and the group kill set — everything a replay needs."""
    rng = np.random.RandomState(seed)
    uid = [0]
    group = [0]

    def request(g=None, toks=None):
        u = uid[0]
        uid[0] += 1
        if toks is None:
            plen = int(rng.choice([0, 1, 3, 9, 17, 33, 40],
                                  p=[.05, .15, .2, .2, .2, .15, .05]))
            toks = rng.randint(3, vocab, (plen,)).tolist()
        budget = int(rng.choice([0, 1, 4, 7, MAXNEW],
                                p=[.05, .15, .3, .3, .2]))
        return Request(uid=u, tokens=toks, group=g, max_new_tokens=budget)

    rounds = []
    for _ in range(n_rounds):
        subs = []
        for _ in range(int(rng.randint(0, 3))):
            kind = rng.rand()
            if kind < 0.45:
                subs.append(request())
            else:
                g = group[0]
                group[0] += 1
                k = int(rng.randint(2, 4))
                if kind < 0.8:          # token-identical vote group
                    proto = request(g)
                    members = [proto] + [
                        request(g, toks=list(proto.tokens))
                        for _ in range(k - 1)]
                    for m in members[1:]:
                        m.max_new_tokens = proto.max_new_tokens
                else:                   # RCV-style ragged group
                    members = [request(g) for _ in range(k)]
                subs.append(RequestGroup(members))
        rounds.append(subs)
    kill = {g for g in range(group[0]) if rng.rand() < 0.3}
    release_rounds = {r for r in range(n_rounds) if rng.rand() < 0.4}
    return rounds, kill, release_rounds


def _flatten(rounds):
    out = []
    for subs in rounds:
        for s in subs:
            out.extend(s.requests if isinstance(s, RequestGroup) else [s])
    return out


def _random_preempts(loop, rng, hold_ok=True):
    """Between rounds: randomly preempt live lanes (parking decoding
    lanes to host RAM, requeueing mid-prefill ones) and resume randomly
    chosen parked requests.  Any schedule is legal — trace independence
    says the generated bits cannot change."""
    for uid in [l.req.uid for l in loop.lanes if l is not None]:
        if rng.rand() < 0.25:
            loop.preempt(uid, hold=hold_ok and rng.rand() < 0.4)
    for uid in loop.parked_uids():
        if rng.rand() < 0.5:
            loop.resume(uid)


def replay(sched: Scheduler, rounds, kill, release_rounds, draft_fn=None,
           preempt_rng=None):
    """Drive one scheduler through the trace: submit between rounds,
    step, release delivered uids on release rounds, then drain.
    ``draft_fn(req)``, if given, supplies each submission's speculative
    draft queue (None to leave a request undrafted).  ``preempt_rng``,
    if given, weaves a random preempt/resume schedule between rounds."""
    loop = sched.loop(jax.random.PRNGKey(MASTER_KEY),
                      stop_policy=ScriptedKills(kill))
    got = {}
    for r, subs in enumerate(rounds):
        if subs:
            drafts = None
            if draft_fn is not None:
                drafts = {}
                for s in subs:
                    for m in (s.requests if isinstance(s, RequestGroup)
                              else [s]):
                        d = draft_fn(m)
                        if d:
                            drafts[m.uid] = d
                drafts = drafts or None
            loop.submit(subs, draft_tokens=drafts)
        if preempt_rng is not None:
            _random_preempts(loop, preempt_rng)
        done = loop.step()
        for c in done:
            assert c.uid not in got, "uid completed twice"
            got[c.uid] = c
        if r in release_rounds:
            loop.release(c.uid for c in done)
    if preempt_rng is not None:
        for uid in loop.parked_uids():
            loop.resume(uid)     # lift holds; failures downgrade to auto
    while loop.has_work:
        if preempt_rng is not None:
            # keep churning while draining, but only auto-resumable
            # parks so the drain is guaranteed to make progress
            _random_preempts(loop, preempt_rng, hold_ok=False)
        for c in loop.step():
            assert c.uid not in got, "uid completed twice"
            got[c.uid] = c
    loop.close()
    return got, loop.stats


def _prefix_agreement(got, want):
    """Fraction of ``want`` that ``got`` reproduces as an exact prefix
    (1.0 for an empty oracle stream)."""
    if not len(want):
        return 1.0
    n = 0
    for a, b in zip(got, want):
        if a != b:
            break
        n += 1
    return n / len(want)


def check_trace(params, cfg, temperature, mode, chunked, trace,
                prefill_budget=None, drafted=False, preempt_seed=None,
                mesh=None, n_lanes=N_LANES, tol=0.0, oracle_cfg=None):
    """Replay ``trace`` and compare against the per-request oracle.

    ``tol=0.0`` (every non-quantized mode, and whole-prefill quantized
    modes against a same-config oracle) demands bit-equality.  A
    nonzero ``tol`` switches to the quantized tiers' tolerance
    contract: mean token-prefix agreement across uncancelled requests
    must reach ``1 - tol`` (quantization noise may flip a token, after
    which the streams legitimately diverge — so agreement is measured
    up to the first mismatch, not pointwise).  ``oracle_cfg`` lets a
    quantized trace be scored against the fp oracle."""
    rounds, kill, release_rounds = trace
    sched = _scheduler(params, cfg, temperature, mode, chunked,
                       prefill_budget, spec=drafted, mesh=mesh,
                       n_lanes=n_lanes)
    oracle = Oracle(params, oracle_cfg if oracle_cfg is not None else cfg,
                    sched, temperature)
    draft_fn = None
    if drafted:
        # drafts mix exact oracle prefixes (real acceptance, any
        # temperature) with junk tails (exercising reject + rollback)
        drng = np.random.RandomState(97)

        def draft_fn(req):
            if drng.rand() < 0.25:
                return None
            want = oracle.tokens(req.uid, req.tokens, req.max_new_tokens)
            m = int(drng.randint(0, len(want) + 1))
            junk = drng.randint(3, 90,
                                (int(drng.randint(0, 4)),)).tolist()
            return [int(t) for t in want[:m]] + junk
    preempt_rng = (np.random.RandomState(preempt_seed)
                   if preempt_seed is not None else None)
    got, stats = replay(sched, rounds, kill, release_rounds, draft_fn,
                        preempt_rng=preempt_rng)
    if drafted:
        assert stats.accepted_draft_tokens > 0, \
            "drafted trace never accepted a draft — speculation untested"
    if preempt_seed is not None:
        assert stats.preempts > 0, \
            "preempted trace never preempted — schedule untested"
    reqs = _flatten(rounds)
    assert set(got) == {r.uid for r in reqs}
    if tol:
        agree = [_prefix_agreement(got[r.uid].tokens,
                                   oracle.tokens(r.uid, r.tokens,
                                                 r.max_new_tokens))
                 for r in reqs if not got[r.uid].cancelled]
        assert np.mean(agree) >= 1.0 - tol, \
            f"({mode}, chunked={chunked}): mean prefix agreement " \
            f"{np.mean(agree):.3f} below tolerance {1.0 - tol}"
    else:
        for r in reqs:
            c = got[r.uid]
            want = oracle.tokens(r.uid, r.tokens, r.max_new_tokens)
            if c.cancelled:
                # killed mid-flight: whatever it generated must be an
                # exact prefix of what it would have generated
                assert c.gen_len <= len(want)
                assert np.array_equal(c.tokens, want[:c.gen_len]), \
                    f"uid {r.uid} ({mode}, chunked={chunked}): " \
                    "prefix diverged"
            else:
                assert np.array_equal(c.tokens, want), \
                    f"uid {r.uid} ({mode}, chunked={chunked}): " \
                    "tokens diverged"
    if sched.pool is not None:
        assert sched.pool.leak_report() is None
    # close() joins every per-shard pool's leak report into stats (the
    # sharded loop has no single ``pool``); None covers all shards
    assert stats.leak_report is None
    return got


# ----------------------------------------------------------------------
# Seeded-fuzz driver: the full configuration matrix
# ----------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("seed", [11, 29])
def test_trace_matrix_bitmatches_oracle(setup, seed, temperature):
    """Every serving configuration must reproduce the per-request
    oracle bit-for-bit on the same randomized trace — cache layout,
    prefix sharing, chunked prefill, and speculative verify rounds
    change how/when work happens, never what gets generated."""
    params, cfg, _ = _setup()
    trace = make_trace(seed)
    for mode in ("dense", "paged", "shared"):
        for chunked, budget in ((False, None), (True, None), (True, 16)):
            check_trace(params, cfg, temperature, mode, chunked, trace,
                        prefill_budget=budget)
        check_trace(params, cfg, temperature, mode, False, trace,
                    drafted=True)


def test_trace_uncancelled_equal_across_modes(setup):
    """Cross-mode coherence on one trace without kills: every mode's
    completions are literally identical (not just oracle-equal), so the
    matrix collapses to one canonical output."""
    params, cfg, _ = _setup()
    trace = make_trace(53)
    trace = (trace[0], set(), trace[2])          # no kills
    sigs = []
    for mode in ("dense", "paged", "shared"):
        for chunked in (False, True):
            got = check_trace(params, cfg, 0.7, mode, chunked, trace)
            sigs.append(sorted((u, c.tokens.tolist())
                               for u, c in got.items()))
    assert all(s == sigs[0] for s in sigs[1:])


# ----------------------------------------------------------------------
# Quantized tiers: bit-exact vs the quant oracle, tolerance vs fp
# ----------------------------------------------------------------------

def _quant_cfg(cfg):
    import dataclasses
    return dataclasses.replace(cfg, kv_quant=True)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_quant_trace_matrix_bitmatches_quant_oracle(setup, temperature):
    """int8-KV serving keeps the full determinism contract *within* the
    quantized world: every whole-prefill quant configuration — dense,
    paged, shared-prefix, drafted verify rounds, a random
    preempt/resume schedule — must reproduce the quantized one-shot
    engine bit-for-bit.  Quantization happens once per cache slot at
    lane insertion; after that, blocks move as raw int8 + scales
    through sharing, CoW, offload, and rollback, so nothing in the
    serving trace can perturb a single bit."""
    params, cfg, _ = _setup()
    qcfg = _quant_cfg(cfg)
    trace = make_trace(31)
    for mode in ("dense", "paged", "shared"):
        check_trace(params, qcfg, temperature, mode, False, trace)
    check_trace(params, qcfg, temperature, "paged", False, trace,
                drafted=True)
    check_trace(params, qcfg, temperature, "paged", False, trace,
                preempt_seed=71)


def test_quant_chunked_trace_within_tolerance_and_schedule_stable(setup):
    """Chunked prefill is the one quant mode that is *not* bit-equal to
    the whole-prefill oracle (each chunk's K/V is computed over the
    previous chunks' dequantized values, then quantized — a different
    rounding than quantizing the whole prompt at once), so it is held
    to the tolerance contract instead.  It must still be bit-stable
    across prefill budgets: the chunk width fixes the rounding points,
    so *when* chunks land cannot change the bits."""
    params, cfg, _ = _setup()
    qcfg = _quant_cfg(cfg)
    trace = make_trace(31)
    trace = (trace[0], set(), trace[2])   # no kills: a cancelled lane's
    #                                       length depends on round timing
    got1 = check_trace(params, qcfg, 0.7, "paged", True, trace, tol=0.5)
    got2 = check_trace(params, qcfg, 0.7, "paged", True, trace,
                       prefill_budget=16, tol=0.5)
    sig = lambda got: sorted((u, c.tokens.tolist()) for u, c in got.items())
    assert sig(got1) == sig(got2), \
        "chunked quant output depended on the prefill budget"


def test_quant_trace_tracks_fp_oracle_at_tolerance(setup):
    """Scored against the *fp* oracle, the quant trace passes only the
    tolerance bar — and greedy decoding shows the divergence is real
    quantization noise, not sampling jitter."""
    params, cfg, _ = _setup()
    qcfg = _quant_cfg(cfg)
    trace = make_trace(31)
    check_trace(params, qcfg, 0.0, "paged", False, trace, tol=0.5,
                oracle_cfg=cfg)


def test_quant_sharded_trace_bitmatches_quant_oracle(setup):
    """Scale pools shard exactly like their int8 value pools (same flat
    slot ids, same specs), so the 4-shard quant trace keeps the
    single-device quant oracle bit-for-bit."""
    from repro.launch.mesh import make_sim_mesh
    params, cfg, _ = _setup()
    qcfg = _quant_cfg(cfg)
    trace = make_trace(29)
    check_trace(params, qcfg, 0.7, "paged", False, trace,
                mesh=make_sim_mesh(4), n_lanes=8)


# ----------------------------------------------------------------------
# Sharded serving: the same traces on a simulated 4-device mesh
# ----------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_sharded_trace_matrix_bitmatches_oracle(setup, temperature):
    """The same randomized traces on a simulated 4-device data mesh
    (per-shard lane/KV pools, decode rounds under shard_map) must
    reproduce the unchanged single-device ``engine.generate`` oracle
    bit-for-bit across {paged, shared} x {whole, chunked} — shard
    placement is pure layout, invisible in the output — and every
    shard's pool must come back leak-clean (``stats.leak_report``
    joins all four)."""
    from repro.launch.mesh import make_sim_mesh
    params, cfg, _ = _setup()
    mesh = make_sim_mesh(4)
    trace = make_trace(29)
    for mode in ("paged", "shared"):
        for chunked in (False, True):
            check_trace(params, cfg, temperature, mode, chunked, trace,
                        mesh=mesh, n_lanes=8)


def test_sharded_trace_drafted_and_preempted(setup):
    """Sharded decode's other two hot paths ride the same oracle check:
    speculative verify rounds (``sharded_decode_round_spec``) and a
    random preempt/resume schedule (host offload keyed per shard,
    restore pinned to the parked shard's lanes)."""
    from repro.launch.mesh import make_sim_mesh
    params, cfg, _ = _setup()
    mesh = make_sim_mesh(4)
    trace = make_trace(11)
    check_trace(params, cfg, 0.7, "paged", False, trace,
                mesh=mesh, n_lanes=8, drafted=True)
    check_trace(params, cfg, 0.7, "shared", True, trace,
                mesh=mesh, n_lanes=8, preempt_seed=71)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_preempt_trace_matrix_bitmatches_oracle(setup, temperature):
    """A seeded-fuzz preempt/resume schedule woven into a randomized
    trace must leave every completion bit-identical to the oracle in
    every {cache} x {chunking} mode: parking a lane to host RAM and
    restoring it (into whichever lane is free) is invisible in the
    output, and both device and host pools come back leak-clean."""
    params, cfg, _ = _setup()
    trace = make_trace(17)
    for mode in ("dense", "paged", "shared"):
        for chunked in (False, True):
            check_trace(params, cfg, temperature, mode, chunked, trace,
                        preempt_seed=71)


# ----------------------------------------------------------------------
# Directed preempt/resume regressions
# ----------------------------------------------------------------------

def test_explicit_preempt_resume_roundtrip(setup):
    """Park a decoding request mid-stream with ``hold=True``, let the
    other lanes run on, resume it, and require a bit-exact completion —
    in both cache layouts (paged offloads KV blocks to host, dense
    snapshots its cache row)."""
    params, cfg, _ = _setup()
    for mode in ("paged", "dense"):
        sched = _scheduler(params, cfg, 0.7, mode, chunked=False)
        oracle = Oracle(params, cfg, sched, 0.7)
        reqs = [Request(uid=u, tokens=[5 + u] * (3 + 7 * u),
                        max_new_tokens=MAXNEW) for u in range(3)]
        loop = sched.loop(jax.random.PRNGKey(MASTER_KEY))
        loop.submit(reqs)
        loop.step()
        # whichever request is still decoding (some may EOS round 1)
        target = next(l.req.uid for l in loop.lanes if l is not None)
        loop.preempt(target, hold=True)
        assert loop.parked_uids() == [target]
        done_early = {c.uid for c in loop.step()}
        assert target not in done_early, "held request must stay parked"
        assert loop.resume(target)
        comps = {c.uid: c for c in loop.drain()}
        loop.close()
        for r in reqs:
            want = oracle.tokens(r.uid, r.tokens, r.max_new_tokens)
            assert np.array_equal(comps[r.uid].tokens, want), mode
        stats = loop.stats
        assert stats.preempts == 1 and stats.resumes == 1
        assert stats.offload_bytes > 0
        if mode == "paged":
            assert stats.host_blocks_peak > 0
            assert sched.pool.leak_report() is None


def test_preempt_during_chunked_prefill_requeues(setup):
    """Preempting a lane whose prompt is still chunk-prefilling has no
    KV worth offloading: the partial prefill is abandoned, its blocks
    freed, and the request requeued — it must still complete
    bit-identically."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.7, "paged", chunked=True,
                       prefill_budget=BLOCK)    # one chunk per round
    oracle = Oracle(params, cfg, sched, 0.7)
    long_toks = np.random.RandomState(5).randint(3, 90, (40,)).tolist()
    reqs = [Request(uid=0, tokens=long_toks, max_new_tokens=8),
            Request(uid=1, tokens=[7, 8, 9], max_new_tokens=MAXNEW)]
    loop = sched.loop(jax.random.PRNGKey(MASTER_KEY))
    loop.submit(reqs)
    loop.step()                                 # chunk 1 of 5 lands
    lane0 = next(l for l in loop.lanes if l is not None and l.req.uid == 0)
    assert not lane0.ready, "uid 0 should still be prefilling"
    loop.preempt(0)
    assert loop.parked_uids() == []             # requeued, not parked
    assert loop.stats.preempts == 1
    comps = {c.uid: c for c in loop.drain()}
    loop.close()
    for r in reqs:
        want = oracle.tokens(r.uid, r.tokens, r.max_new_tokens)
        assert np.array_equal(comps[r.uid].tokens, want)
    assert sched.pool.leak_report() is None


def test_shared_group_preempt_offloads_once_resumes_elsewhere(setup):
    """Preempting two members of a shared-prefix vote group must
    offload the read-only prompt blocks once (the second member
    attaches to the first's host copies), and resuming after fillers
    took the freed lanes must land them in *different* lanes — still
    bit-exact, because nothing in the sampling stream depends on lane
    index or block ids."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.7, "shared", chunked=False)
    oracle = Oracle(params, cfg, sched, 0.7)
    toks = np.random.RandomState(9).randint(3, 90, (17,)).tolist()
    grp = RequestGroup([Request(uid=u, tokens=list(toks), group=0,
                                max_new_tokens=MAXNEW) for u in range(3)])
    loop = sched.loop(jax.random.PRNGKey(MASTER_KEY))
    loop.submit([grp])
    loop.step()
    old_lane = {l.req.uid: i for i, l in enumerate(loop.lanes)
                if l is not None}
    loop.preempt(0, hold=True)
    loop.preempt(1, hold=True)
    h0 = set(loop._parked[0].host.ids)
    h1 = set(loop._parked[1].host.ids)
    assert h0 & h1, "shared prompt blocks must be co-held, not re-copied"
    filler = Request(uid=9, tokens=[3, 4, 5], max_new_tokens=MAXNEW)
    loop.submit([filler])
    loop.step()                    # filler occupies one freed lane
    assert loop.resume(0) and loop.resume(1)
    new_lane = {l.req.uid: i for i, l in enumerate(loop.lanes)
                if l is not None}
    assert {new_lane[0], new_lane[1]} != {old_lane[0], old_lane[1]}, \
        "resume should have landed at least one member in a new lane"
    comps = {c.uid: c for c in loop.drain()}
    loop.close()
    for r in list(grp.requests) + [filler]:
        want = oracle.tokens(r.uid, r.tokens, r.max_new_tokens)
        assert np.array_equal(comps[r.uid].tokens, want)
    assert loop.stats.resumes == 2
    assert sched.pool.leak_report() is None


def test_auto_preempt_offload_thrash_tiny_pool(setup):
    """``auto_preempt=True`` with a pool too small for the offered load:
    admission pressure must evict cold lanes to host RAM instead of
    blocking, re-admit them later, and every completion must still be
    bit-exact with both pools leak-clean."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.7, "paged", chunked=False,
                       pool_blocks=14, auto_preempt=True)
    oracle = Oracle(params, cfg, sched, 0.7)
    rng = np.random.RandomState(21)
    # 17-token prompts + MAXNEW budget = 4 blocks/lane, so 4 lanes want
    # 16 blocks from a 14-block pool: admission must preempt to proceed
    reqs = [Request(uid=u, tokens=rng.randint(3, 90, (17,)).tolist(),
                    max_new_tokens=MAXNEW) for u in range(6)]
    loop = sched.loop(jax.random.PRNGKey(MASTER_KEY))
    loop.submit(reqs)
    comps = {c.uid: c for c in loop.drain()}
    loop.close()
    for r in reqs:
        want = oracle.tokens(r.uid, r.tokens, r.max_new_tokens)
        assert np.array_equal(comps[r.uid].tokens, want)
    stats = loop.stats
    assert stats.preempts > 0 and stats.resumes > 0, \
        "tiny pool should have forced at least one offload/resume cycle"
    assert stats.host_blocks_peak > 0 and stats.offload_bytes > 0
    assert sched.pool.leak_report() is None


def test_release_mid_prefill_job_frees_blocks_skips_prefix_cache(setup):
    """``release()`` of a request still queued in a ``_PrefillJob``
    (client cancelled mid-chunk): the partial prompt blocks must come
    back to the pool, the dead prompt must never be registered in the
    prefix cache, and nothing is delivered for the released uids."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.0, "shared", chunked=True,
                       prefill_budget=BLOCK)
    loop = sched.loop(jax.random.PRNGKey(MASTER_KEY))
    toks = np.random.RandomState(3).randint(3, 90, (40,)).tolist()
    grp = RequestGroup([Request(uid=u, tokens=list(toks), group=0,
                                max_new_tokens=6) for u in range(2)])
    loop.submit([grp])
    loop.step()                   # chunk 1 of 5 lands; job still active
    assert any(l is not None and not l.ready for l in loop.lanes)
    loop.release([0, 1])          # both clients went away mid-prefill
    comps = loop.drain()
    loop.close()
    assert comps == [], "released requests must not be delivered"
    assert len(loop.prefix_cache) == 0, \
        "a prompt whose every lane was released must not be cached"
    assert sched.pool.leak_report() is None


# ----------------------------------------------------------------------
# Directed chunked-prefill regressions
# ----------------------------------------------------------------------

def test_kill_mid_prefill_frees_partial_blocks(setup):
    """A group killed while its prompt is still chunk-prefilling must
    drop its lanes with zero tokens and return every allocated block —
    the 'killing a lane mid-prefill frees its partial blocks'
    guarantee."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.7, "shared", chunked=True,
                       prefill_budget=BLOCK)   # one chunk per round
    rng = np.random.RandomState(0)
    # group 0: trivial prompts, budget 2 -> finishes fast; group 1: long
    # prompts that need ~5 chunk rounds -> still prefilling at the kill

    class CrossKill(StopPolicy):
        def observe(self, comp):
            # group 0's first finisher decides group 1 (cross-group
            # trigger, so the kill lands while group 1 still prefills)
            return (1,) if comp.group == 0 else ()

    fast = RequestGroup([Request(uid=j, tokens=[5, 6, 7], group=0,
                                 max_new_tokens=2) for j in range(2)])
    long_toks = rng.randint(3, 90, (40,)).tolist()
    slow = RequestGroup([Request(uid=10 + j, tokens=list(long_toks), group=1,
                                 max_new_tokens=8) for j in range(2)])
    loop = sched.loop(jax.random.PRNGKey(MASTER_KEY),
                      stop_policy=CrossKill())
    loop.submit([fast, slow])
    comps = loop.drain()
    loop.close()
    by_uid = {c.uid: c for c in comps}
    assert not by_uid[0].cancelled
    killed = [by_uid[10], by_uid[11]]
    assert all(c.cancelled and c.gen_len == 0 for c in killed), \
        "group 1 should die before its prefill completes"
    assert sched.pool.leak_report() is None


def test_zero_budget_request_completes_empty(setup):
    """max_new_tokens=0 is a real budget (regression: it used to fall
    back to the default), finalizing with zero tokens in both prefill
    modes."""
    params, cfg, _ = _setup()
    for chunked in (False, True):
        sched = _scheduler(params, cfg, 0.7, "paged", chunked=chunked)
        comps, _ = sched.run(
            [Request(uid=0, tokens=[4, 5, 6], max_new_tokens=0),
             Request(uid=1, tokens=[7, 8], max_new_tokens=3)],
            jax.random.PRNGKey(MASTER_KEY))
        assert comps[0].gen_len == 0 and not comps[0].cancelled
        assert comps[1].gen_len <= 3
        assert sched.pool.leak_report() is None


def test_chunked_requires_supported_config(setup):
    params, cfg, _ = _setup()
    with pytest.raises(ValueError, match="multiple of"):
        Scheduler(None, cfg, None, _gcfg(0.0), paged=True, block_size=8,
                  chunk_size=12)
    with pytest.raises(ValueError, match="too small"):
        Scheduler(None, cfg, None, _gcfg(0.0), chunk_size=4)
    with pytest.raises(ValueError, match="prefill_budget"):
        Scheduler(None, cfg, None, _gcfg(0.0), chunk_size=16,
                  prefill_budget=8)


# ----------------------------------------------------------------------
# Heterogeneous architectures: pure-SSM, hybrid, MoE lane pools
# ----------------------------------------------------------------------

_ARCH_CACHED = {}


def _arch_setup(kind):
    """Tiny pure-SSM / hybrid / MoE models at the harness geometry.
    ``ssm_chunk`` equals BLOCK so the chunked configurations align
    chunk starts with SSD scan boundaries (the scheduler guard)."""
    if kind not in _ARCH_CACHED:
        from repro.data.tokenizer import default_tokenizer
        from repro.models import model as M
        tok = default_tokenizer()
        base = dict(n_layers=2, d_model=64, d_ff=128,
                    vocab_size=tok.vocab_size, remat=False, source="test")
        if kind == "ssm":
            cfg = ModelConfig(name="tiny-ssm", arch_type="ssm", n_heads=0,
                              n_kv_heads=0, head_dim=0, ssm_state=16,
                              ssm_head_dim=32, ssm_chunk=BLOCK, **base)
        elif kind == "hybrid":
            cfg = ModelConfig(name="tiny-hy", arch_type="hybrid", n_heads=2,
                              n_kv_heads=2, head_dim=32, ssm_state=16,
                              ssm_head_dim=32, ssm_chunk=BLOCK, **base)
        else:
            cfg = ModelConfig(name="tiny-moe", arch_type="moe", n_heads=2,
                              n_kv_heads=2, head_dim=32, n_experts=4,
                              moe_top_k=2, moe_d_ff=64, **base)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        _ARCH_CACHED[kind] = (params, cfg)
    return _ARCH_CACHED[kind]


@pytest.mark.parametrize("kind", ["ssm", "hybrid", "moe"])
def test_arch_trace_matrix_bitmatches_oracle(kind):
    """The trace-independence contract is architecture-blind: pure-SSM
    lanes (state-slot protocol), hybrid lanes (paged KV + state slots),
    and MoE lanes (dropless decode dispatch) must reproduce the
    per-request ``engine.generate`` oracle bit-for-bit across their
    cache protocols' serving modes — including chunked prefill
    (SSD-scan-aligned chunks), a random preempt/resume schedule
    (conv/ssm rows parked to host RAM), and, MoE, shared-prefix and
    speculative verify rounds."""
    params, cfg = _arch_setup(kind)
    trace = make_trace(23)
    check_trace(params, cfg, 0.7, "dense", False, trace)
    check_trace(params, cfg, 0.7, "paged", False, trace)
    check_trace(params, cfg, 0.7, "paged", True, trace, prefill_budget=16)
    check_trace(params, cfg, 0.7, "paged", False, trace, preempt_seed=71)
    if kind == "moe":
        # recurrent state can neither alias (share_prefix) nor roll
        # back (spec); MoE keeps both — dropless made its decode
        # dispatch batch-independent, so verify rounds stay bit-exact
        check_trace(params, cfg, 0.7, "shared", False, trace)
        check_trace(params, cfg, 0.7, "paged", False, trace, drafted=True)


@pytest.mark.parametrize("kind", ["ssm", "hybrid"])
def test_state_slot_backpressure_serializes_admission(kind):
    """A state-slot pool sized below the lane count makes the state
    slab — not the lane pool — the admission bottleneck: admissions
    serialize on slot reservation (for a hybrid, after its KV
    reservation succeeded and was returned), every completion is still
    oracle-exact, the slot high-water mark respects the cap, and the
    pools drain leak-clean."""
    params, cfg = _arch_setup(kind)
    sched = Scheduler(params, cfg, tokenizer=None, gcfg=_gcfg(0.7),
                      n_lanes=N_LANES, round_tokens=ROUND,
                      max_prompt_len=MAXP, paged=True, block_size=BLOCK,
                      state_slots=2)
    oracle = Oracle(params, cfg, sched, 0.7)
    rng = np.random.RandomState(13)
    reqs = [Request(uid=u, tokens=rng.randint(3, 90, (9,)).tolist(),
                    max_new_tokens=MAXNEW) for u in range(6)]
    comps, stats = sched.run(reqs, jax.random.PRNGKey(MASTER_KEY))
    for r, c in zip(reqs, comps):
        want = oracle.tokens(r.uid, r.tokens, r.max_new_tokens)
        assert np.array_equal(c.tokens, want)
    assert stats.admission_blocked > 0, \
        "2 slots under 6 requests must have backpressured admission"
    assert stats.state_slots == 2
    assert stats.peak_state_slots == 2
    assert stats.state_slot_bytes > 0
    assert stats.peak_state_bytes == \
        stats.peak_state_slots * stats.state_slot_bytes
    assert stats.leak_report is None


@pytest.mark.parametrize("kind", ["ssm", "hybrid"])
def test_ssm_preempt_resume_state_slot_roundtrip(kind):
    """Explicit preempt/resume of a recurrent lane: parking snapshots
    its conv/ssm rows to host RAM (pure-SSM has no KV blocks to
    offload) and frees its state slot; with the slots repopulated by a
    filler, resume must report False and wait — then complete bit-exact
    once a slot frees, with clean accounting."""
    params, cfg = _arch_setup(kind)
    sched = Scheduler(params, cfg, tokenizer=None, gcfg=_gcfg(0.7),
                      n_lanes=N_LANES, round_tokens=2,
                      max_prompt_len=MAXP, paged=True, block_size=BLOCK,
                      state_slots=2)
    oracle = Oracle(params, cfg, sched, 0.7)
    reqs = [Request(uid=u, tokens=[5 + u] * (3 + 5 * u),
                    max_new_tokens=MAXNEW) for u in range(2)]
    loop = sched.loop(jax.random.PRNGKey(MASTER_KEY))
    loop.submit(reqs)
    loop.step()
    target = next(l.req.uid for l in loop.lanes if l is not None)
    loop.preempt(target, hold=True)
    assert loop.parked_uids() == [target]
    filler = Request(uid=9, tokens=[3, 4, 5], max_new_tokens=MAXNEW)
    loop.submit([filler])             # takes the freed state slot
    loop.step()
    # both slots re-occupied: a free lane alone cannot resume the
    # parked lane — the attempt fails and downgrades the hold to auto
    assert not loop.resume(target)
    comps = {c.uid: c for c in loop.drain()}
    loop.close()
    for r in reqs + [filler]:
        want = oracle.tokens(r.uid, r.tokens, r.max_new_tokens)
        assert np.array_equal(comps[r.uid].tokens, want)
    stats = loop.stats
    assert stats.preempts == 1 and stats.resumes == 1
    assert stats.offload_bytes > 0    # conv/ssm rows crossed to host
    assert stats.leak_report is None


def test_moe_decode_lane_count_invariance():
    """Regression for the expert-capacity bug: decode capacity used to
    be ``moe_capacity(cfg, t)`` with ``t`` the round's live-lane count,
    so a token's expert dispatch (and logits) depended on how many
    other lanes happened to be decoding.  Dropless decode dispatch must
    make a request's tokens identical whether it serves alone or beside
    a full pool of unrelated traffic."""
    params, cfg = _arch_setup("moe")
    probe = Request(uid=0, tokens=[11, 12, 13, 14, 15],
                    max_new_tokens=MAXNEW)
    outs = []
    for fillers in (0, 3):
        sched = Scheduler(params, cfg, tokenizer=None, gcfg=_gcfg(0.7),
                          n_lanes=N_LANES, round_tokens=ROUND,
                          max_prompt_len=MAXP, paged=True,
                          block_size=BLOCK)
        rng = np.random.RandomState(fillers)
        reqs = [Request(uid=0, tokens=list(probe.tokens),
                        max_new_tokens=MAXNEW)]
        reqs += [Request(uid=10 + j, tokens=rng.randint(3, 90, (7,)).tolist(),
                         max_new_tokens=MAXNEW) for j in range(fillers)]
        comps, _ = sched.run(reqs, jax.random.PRNGKey(MASTER_KEY))
        outs.append(comps[0].tokens.tolist())
    assert outs[0] == outs[1], \
        "MoE decode output depended on the live-lane count"


def test_arch_scheduler_guards():
    """The per-architecture guards raise actionable errors exactly
    where the protocol forbids a mode — and accept what it allows
    (regressions: chunked hybrid and chunked/spec MoE used to be
    rejected wholesale)."""
    _, ssm_cfg = _arch_setup("ssm")
    _, hy_cfg = _arch_setup("hybrid")
    _, moe_cfg = _arch_setup("moe")
    g = _gcfg(0.0)
    # chunk starts must align with the SSD scan grid
    with pytest.raises(ValueError, match="ssm_chunk"):
        Scheduler(None, hy_cfg, None, g, paged=True, block_size=4,
                  chunk_size=12)
    # recurrent state cannot alias: no share_prefix without paged KV
    with pytest.raises(ValueError, match="share_prefix requires paged"):
        Scheduler(None, ssm_cfg, None, g, paged=True, share_prefix=True)
    # shared chunk rows carry no lane to persist conv/ssm state
    with pytest.raises(ValueError, match="share_prefix"):
        Scheduler(None, hy_cfg, None, g, paged=True, block_size=BLOCK,
                  share_prefix=True, chunk_size=BLOCK)
    # a rejected draft cannot roll cumulative state back
    with pytest.raises(ValueError, match="recurrent"):
        Scheduler(None, ssm_cfg, None, g, spec_k=2)
    # state_slots is meaningful only under the state-slot protocol
    with pytest.raises(ValueError, match="state_slots requires"):
        Scheduler(None, ssm_cfg, None, g, state_slots=2)   # dense
    cfg_attn = _setup()[1]
    with pytest.raises(ValueError, match="state_slots requires"):
        Scheduler(None, cfg_attn, None, g, paged=True, state_slots=2)
    with pytest.raises(ValueError, match="state_slots"):
        Scheduler(None, ssm_cfg, None, g, paged=True, state_slots=0)
    # allowed: chunked hybrid (aligned), chunked + drafted MoE
    Scheduler(None, hy_cfg, None, g, paged=True, block_size=BLOCK,
              chunk_size=BLOCK)
    Scheduler(None, moe_cfg, None, g, paged=True, block_size=BLOCK,
              chunk_size=BLOCK, spec_k=2)


# ----------------------------------------------------------------------
# Hypothesis stateful machine (optional dep): shared + chunked loop
# ----------------------------------------------------------------------

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on bare installs
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    class ServingTraceMachine(RuleBasedStateMachine):
        """Arbitrary interleavings of submit / step / kill / release /
        preempt / resume against the most intricate configuration
        (shared-prefix paged + chunked prefill, sampled decoding),
        checked against the same per-request oracle at teardown."""

        def __init__(self):
            super().__init__()
            params, cfg, _ = _setup()
            self.params, self.cfg = params, cfg
            self.sched = _scheduler(params, cfg, 0.7, "shared",
                                    chunked=True, prefill_budget=BLOCK)
            self.policy = ScriptedKills(set())
            self.loop = self.sched.loop(jax.random.PRNGKey(MASTER_KEY),
                                        stop_policy=self.policy)
            self.oracle = Oracle(params, cfg, self.sched, 0.7)
            self.requests = {}
            self.got = {}
            self.next_uid = 0
            self.next_group = 0
            self.last_delivered = []

        def _mk_request(self, rng, group, toks=None):
            u = self.next_uid
            self.next_uid += 1
            if toks is None:
                plen = int(rng.randint(0, 34))
                toks = rng.randint(3, 90, (plen,)).tolist()
            req = Request(uid=u, tokens=toks, group=group,
                          max_new_tokens=int(rng.randint(0, MAXNEW + 1)))
            self.requests[u] = req
            return req

        @initialize()
        def start(self):
            pass

        @rule(seed=st.integers(0, 10 ** 6))
        def submit_plain(self, seed):
            rng = np.random.RandomState(seed)
            self.loop.submit([self._mk_request(rng, None)])

        @rule(seed=st.integers(0, 10 ** 6), k=st.integers(2, 3),
              identical=st.booleans())
        def submit_group(self, seed, k, identical):
            rng = np.random.RandomState(seed)
            g = self.next_group
            self.next_group += 1
            if identical:
                proto = self._mk_request(rng, g)
                members = [proto]
                for _ in range(k - 1):
                    m = self._mk_request(rng, g, toks=list(proto.tokens))
                    m.max_new_tokens = proto.max_new_tokens
                    members.append(m)
            else:
                members = [self._mk_request(rng, g) for _ in range(k)]
            self.loop.submit([RequestGroup(members)])

        @rule()
        def step(self):
            done = self.loop.step()
            for c in done:
                assert c.uid not in self.got
                self.got[c.uid] = c
            self.last_delivered = [c.uid for c in done]

        @rule(seed=st.integers(0, 10 ** 6))
        def kill_some_group(self, seed):
            if self.next_group:
                rng = np.random.RandomState(seed)
                self.policy.kill_groups.add(int(rng.randint(
                    0, self.next_group)))

        @rule()
        def release_delivered(self):
            self.loop.release(self.last_delivered)
            self.last_delivered = []

        @rule(seed=st.integers(0, 10 ** 6))
        def preempt_random_live(self, seed):
            live = [l.req.uid for l in self.loop.lanes if l is not None]
            if live:
                rng = np.random.RandomState(seed)
                # auto-resumable parks only, so teardown's drain loop is
                # guaranteed to make progress without explicit resumes
                self.loop.preempt(int(live[rng.randint(len(live))]),
                                  hold=False)

        @rule(seed=st.integers(0, 10 ** 6))
        def resume_random_parked(self, seed):
            parked = self.loop.parked_uids()
            if parked:
                rng = np.random.RandomState(seed)
                self.loop.resume(int(parked[rng.randint(len(parked))]))

        @invariant()
        def pool_accounting_sane(self):
            pool = self.sched.pool
            assert pool.in_use + pool.n_free == pool.n_blocks
            assert pool.reserved <= pool.n_free
            # every parked record's host blocks are live host-side, and
            # nothing else is
            want_host = set()
            for p in self.loop._parked.values():
                if p.host is not None:
                    want_host.update(p.host.ids)
            assert set(pool._host_refs) == want_host

        def teardown(self):
            while self.loop.has_work:
                for c in self.loop.step():
                    assert c.uid not in self.got
                    self.got[c.uid] = c
            self.loop.close()
            assert set(self.got) == set(self.requests)
            for u, req in self.requests.items():
                c = self.got[u]
                want = self.oracle.tokens(u, req.tokens, req.max_new_tokens)
                if c.cancelled:
                    assert np.array_equal(c.tokens, want[:c.gen_len])
                else:
                    assert np.array_equal(c.tokens, want)
            assert self.sched.pool.leak_report() is None

    ServingTraceMachine.TestCase.settings = settings(
        max_examples=8, stateful_step_count=14, deadline=None)
    TestServingTraceMachine = ServingTraceMachine.TestCase
