"""Streaming serving loop (ServingLoop): submit/step/drain must
bit-match the one-shot Scheduler.run across every cache layout (dense,
paged, shared-prefix) and both decode modes (greedy, sampled);
mid-flight admission under eviction churn must leak no pool blocks;
the pipelined multi-tier cascade must reproduce the sequential-barrier
path's decisions under greedy decoding."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import cascade_multi as cm
from repro.core import routing as routing_lib
from repro.core import voting
from repro.core.confidence import Vote
from repro.data import tasks as tasks_lib
from repro.serving.batch import GenConfig
from repro.serving.scheduler import (Request, RequestGroup, Scheduler,
                                     StopPolicy)

MAXP = 64


@pytest.fixture(scope="module")
def setup():
    from repro.data.tokenizer import default_tokenizer
    from repro.models import model as M
    tok = default_tokenizer()
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128, vocab_size=tok.vocab_size, remat=False,
                      source="test")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg, tok


def _scheduler(params, cfg, tok, gcfg, mode):
    return Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=5,
                     max_prompt_len=MAXP,
                     paged=mode in ("paged", "shared"), block_size=8,
                     share_prefix=mode == "shared")


def _vote_groups(n_questions, k, max_new=None):
    return [RequestGroup([
        Request(uid=qi * k + j, prompt=f"Q: item {qi} says hello\nA: ",
                group=qi, max_new_tokens=max_new) for j in range(k)])
        for qi in range(n_questions)]


# ----------------------------------------------------------------------
# Bit-match: submit/step/drain == one-shot run()
# ----------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "paged", "shared"])
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_loop_bitmatches_run(setup, mode, temperature):
    """Submitting everything up front and stepping the loop dry must
    reproduce Scheduler.run token-for-token — run() is a thin wrapper
    over the same loop, and this pins that contract for every cache
    layout and both greedy and sampled decoding."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=14, temperature=temperature)
    sched = _scheduler(params, cfg, tok, gcfg, mode)
    reqs = _vote_groups(4, 3)
    key = jax.random.PRNGKey(3)

    run_comps, run_stats = sched.run(reqs, key)

    loop = sched.loop(key)
    loop.submit(reqs)
    stepped = []
    while loop.has_work:
        stepped.extend(loop.step())
    stats = loop.close()

    # each uid completes exactly once, through step()'s return values
    assert sorted(c.uid for c in stepped) == list(range(12))
    by_uid = {c.uid: c for c in stepped}
    for cr in run_comps:
        cl = by_uid[cr.uid]
        assert cr.gen_len == cl.gen_len
        assert np.array_equal(cr.tokens, cl.tokens)
    assert stats.generated_tokens == run_stats.generated_tokens
    assert stats.rounds == run_stats.rounds
    assert stats.prefill_tokens == run_stats.prefill_tokens
    if mode in ("paged", "shared"):
        assert sched.pool.leak_report() is None


def test_drain_returns_submission_order(setup):
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=8, temperature=0.7, eos_id=-1)
    sched = _scheduler(params, cfg, tok, gcfg, "dense")
    loop = sched.loop(jax.random.PRNGKey(1))
    loop.submit([Request(uid=i, prompt=f"Q: item {i}\nA: ")
                 for i in range(6)])
    comps = loop.drain()
    assert [c.uid for c in comps] == list(range(6))
    for c in comps:
        assert c.ttft_s is not None and c.ttd_s is not None
        assert 0 <= c.ttft_s <= c.ttd_s


# ----------------------------------------------------------------------
# Mid-flight admission under churn: no leak, no double-free
# ----------------------------------------------------------------------

class _KillOddGroups(StopPolicy):
    """Kills any odd group as soon as one of its lanes finishes —
    eviction churn for the admission path to ride over."""

    def observe(self, comp):
        if comp.group is not None and comp.group % 2 == 1:
            return (comp.group,)
        return ()


def test_midflight_admission_churn_no_leak(setup):
    """Requests and vote groups submitted *while* earlier ones decode
    (and while a StopPolicy evicts lanes mid-flight) must all complete,
    with the block pool draining to empty — no leak, no double-free —
    and the reservation high-water must reflect the churn."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=12, temperature=0.7, eos_id=-1)
    sched = _scheduler(params, cfg, tok, gcfg, "shared")
    loop = sched.loop(jax.random.PRNGKey(7), stop_policy=_KillOddGroups())

    # lane 0 of each group finishes first (short budget), so the policy
    # kills odd groups while their other lanes are still decoding
    first_wave = [RequestGroup([
        Request(uid=qi * 3 + j, prompt=f"Q: item {qi} says hello\nA: ",
                group=qi, max_new_tokens=(4 if j == 0 else None))
        for j in range(3)]) for qi in range(3)]
    loop.submit(first_wave)                               # uids 0..8
    got = []
    for _ in range(2):
        got.extend(loop.step())
    # mid-flight: more groups plus plain requests into evicted lanes
    late = [RequestGroup([
        Request(uid=100 + qi * 3 + j, prompt=f"Q: late {qi}\nA: ",
                group=10 + qi) for j in range(3)]) for qi in range(2)]
    loop.submit(late)
    got.extend(loop.step())
    loop.submit([Request(uid=200, prompt="Q: solo\nA: ")])
    while loop.has_work:
        got.extend(loop.step())
    stats = loop.close()

    expected = set(range(9)) | {100 + i for i in range(6)} | {200}
    assert {c.uid for c in got} == expected
    assert len(got) == len(expected)                      # exactly once
    assert stats.cancelled > 0                            # churn happened
    assert sched.pool.leak_report() is None
    assert sched.pool.peak_reserved > 0
    # killed groups really stopped early; survivors ran to budget
    by_uid = {c.uid: c for c in got}
    assert by_uid[200].gen_len == 12 and not by_uid[200].cancelled


def test_submit_after_group_decided_is_dropped(setup):
    """A group decided before some of its requests were ever admitted
    drops the stragglers with zero generated tokens — including ones
    submitted after the decision."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=8, temperature=0.7, eos_id=-1)
    sched = _scheduler(params, cfg, tok, gcfg, "dense")
    loop = sched.loop(jax.random.PRNGKey(2), stop_policy=_KillOddGroups())
    loop.submit([Request(uid=0, prompt="Q: a\nA: ", group=1)])
    while loop.has_work:
        loop.step()
    assert 1 in loop.decided
    loop.submit([Request(uid=1, prompt="Q: b\nA: ", group=1)])
    comps = loop.drain()
    late = loop.completions[1]
    assert late.cancelled and late.gen_len == 0
    assert len(comps) == 2


# ----------------------------------------------------------------------
# Per-group tau: one policy serving several tiers (fused loops)
# ----------------------------------------------------------------------

def _fake_completion(group, vote: Vote, uid=0):
    from repro.serving.scheduler import Completion
    return Completion(uid=uid, group=group, tokens=np.zeros((0,), np.int32),
                      gen_len=vote.gen_tokens, text="", cancelled=False,
                      meta={"vote": vote})


def test_vote_early_stop_per_group_tau():
    policy = routing_lib.VoteEarlyStop(
        0.5, {}, parse=lambda c: c.meta["vote"])
    policy.add_group(0, [1.0, 1.0], tau=1.0)    # strict tier
    policy.add_group(1, [1.0, 1.0], tau=0.1)    # loose tier
    v = Vote(answer="a", confidence=1.0, gen_tokens=5)
    # same first vote: the loose group accepts, the strict one cannot
    assert policy.observe(_fake_completion(1, v, uid=10)) == (1,)
    assert policy.decisions[1].accepted
    assert policy.observe(_fake_completion(0, v, uid=11)) == ()
    assert 0 not in policy.decisions


# ----------------------------------------------------------------------
# Pipelined cascade == sequential barriers (greedy decisions)
# ----------------------------------------------------------------------

def test_pipelined_cascade_matches_sequential_greedy(setup):
    """With greedy decoding the vote texts depend only on the prompts,
    so the pipelined cascade (mid-flight escalation, fused same-SLM
    lane pool) must reproduce the barrier path's accept/route decisions
    question for question."""
    params, cfg, tok = setup
    slm = routing_lib.SLM(params, cfg, tok,
                          GenConfig(max_new_tokens=16, temperature=0.0),
                          max_prompt_len=MAXP, lane_budget=8,
                          round_tokens=4)
    items = tasks_lib.make_benchmark("arith", 4, seed=1)
    tiers = [cm.Tier(slm=slm, tau=1.0, mode="FCV", k=3),
             cm.Tier(slm=slm, tau=1.0, mode="FCV", k=3)]
    terminal = cm.TerminalTier(llm=routing_lib.OracleLLM(accuracy=1.0))
    key = jax.random.PRNGKey(9)

    out_seq = cm.run_cascade(tiers, terminal, items, key,
                             stream_early_stop=True)
    out_pipe, ps = cm.run_cascade_pipelined(tiers, terminal, items, key)

    assert [o.accepted_tier for o in out_pipe] == \
        [o.accepted_tier for o in out_seq]
    assert [o.correct for o in out_pipe] == [o.correct for o in out_seq]
    assert ps.rounds > 0 and ps.generated_tokens > 0
    assert 0.0 <= ps.overlap_fraction <= 1.0
    assert ps.fused_loops == 1 and ps.n_loops == 1    # tiers share the SLM
    assert len(ps.ttd_s) == len(items)
    assert all(t > 0 for t in ps.ttd_s)


def test_pipelined_cascade_distinct_slms_two_loops(setup):
    """Tiers with distinct SLM objects get one serving loop each,
    interleaved split-phase in the host loop — outcomes must still
    match the barrier path under greedy decoding."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=12, temperature=0.0)

    def mk():
        return routing_lib.SLM(params, cfg, tok, gcfg, max_prompt_len=MAXP,
                               lane_budget=4, round_tokens=4)

    items = tasks_lib.make_benchmark("arith", 3, seed=2)
    tiers = [cm.Tier(slm=mk(), tau=1.0, mode="FCV", k=2),
             cm.Tier(slm=mk(), tau=1.0, mode="FCV", k=2)]
    terminal = cm.TerminalTier(llm=routing_lib.OracleLLM(accuracy=1.0))
    key = jax.random.PRNGKey(4)
    out_seq = cm.run_cascade(tiers, terminal, items, key,
                             stream_early_stop=True)
    out_pipe, ps = cm.run_cascade_pipelined(tiers, terminal, items, key)
    assert ps.n_loops == 2 and ps.fused_loops == 0
    assert [o.accepted_tier for o in out_pipe] == \
        [o.accepted_tier for o in out_seq]
    assert [o.correct for o in out_pipe] == [o.correct for o in out_seq]


def test_pipelined_cascade_tier_placement(setup):
    """``placement`` pins each tier to its own device slice: outcomes
    must be unchanged (placement is pure layout), one shared SLM placed
    on two DISJOINT slices deliberately un-fuses into two loops, and
    the same SLM placed twice on the SAME slice keeps its fused loop."""
    params, cfg, tok = setup
    slm = routing_lib.SLM(params, cfg, tok,
                          GenConfig(max_new_tokens=8, temperature=0.0),
                          max_prompt_len=MAXP, lane_budget=4,
                          round_tokens=4)
    items = tasks_lib.make_benchmark("arith", 2, seed=7)
    tiers = [cm.Tier(slm=slm, tau=1.0, mode="FCV", k=2),
             cm.Tier(slm=slm, tau=1.0, mode="FCV", k=2)]
    terminal = cm.TerminalTier(llm=routing_lib.OracleLLM(accuracy=1.0))
    key = jax.random.PRNGKey(6)
    devs = jax.devices()

    out_ref, _ = cm.run_cascade_pipelined(tiers, terminal, items, key)
    out_disj, ps = cm.run_cascade_pipelined(
        tiers, terminal, items, key,
        placement={0: devs[0:2], 1: devs[2:4]})
    assert ps.n_loops == 2 and ps.fused_loops == 0
    out_same, ps2 = cm.run_cascade_pipelined(
        tiers, terminal, items, key,
        placement={0: devs[0:2], 1: devs[0:2]})
    assert ps2.n_loops == 1 and ps2.fused_loops == 1
    for out in (out_disj, out_same):
        assert [o.accepted_tier for o in out] == \
            [o.accepted_tier for o in out_ref]
        assert [o.correct for o in out] == [o.correct for o in out_ref]

    with pytest.raises(ValueError, match="placement names tier"):
        cm.run_cascade_pipelined(tiers, terminal, items, key,
                                 placement={5: devs[0:1]})


def test_cascade_decisions_equal(setup):
    """decide-level parity: voting.decide_no_early_stop over the same
    greedy votes must agree with what both cascade paths recorded (the
    two paths share VoteEarlyStop; this ties them back to the paper's
    voting rule)."""
    params, cfg, tok = setup
    slm = routing_lib.SLM(params, cfg, tok,
                          GenConfig(max_new_tokens=16, temperature=0.0),
                          max_prompt_len=MAXP, lane_budget=8,
                          round_tokens=4)
    items = tasks_lib.make_benchmark("arith", 3, seed=5)
    levels = [1.0] * 3
    votes = routing_lib.sample_k(slm, items, levels, jax.random.PRNGKey(0),
                                 seed_offset=0)
    for vs in votes:
        ref = voting.decide_no_early_stop(vs, 1.0)
        es = voting.decide_with_early_stop(vs, 1.0)
        assert ref.accepted == es.accepted
