"""Multi-tier cascade (beyond-paper extension) — semantic tests using a
scripted fake SLM (no model inference)."""


import jax

from repro.core import cascade_multi as cm
from repro.core.confidence import Vote
from repro.core.routing import OracleLLM
from repro.data import tasks as T


class FakeSLM:
    """Monkeypatch target — cascade_multi only calls sample_k(slm, ...)."""


def _fake_votes(answer, conf, n, tok=10):
    return [Vote(answer=answer, confidence=conf, gen_tokens=tok)
            for _ in range(n)]


def test_two_tier_reduces_to_terminal_fallthrough(monkeypatch):
    items = T.make_benchmark("arith", 6, seed=0)
    # tier 0 always rejects -> everything reaches the terminal oracle
    def fake_sample_k(slm, its, levels, key, seed_offset=0):
        return [_fake_votes(None, 1.0, len(levels)) for _ in its]

    monkeypatch.setattr(cm, "sample_k", fake_sample_k)
    tier = cm.Tier(slm=FakeSLM(), tau=0.6, mode="FCV", k=4)
    term = cm.TerminalTier(llm=OracleLLM(accuracy=1.0, avg_out_tokens=20))
    out = cm.run_cascade([tier], term, items, jax.random.PRNGKey(0))
    s = cm.summarize(out, 1)
    assert s["tier_histogram"] == [0, 6]
    assert s["accuracy"] == 1.0
    assert s["AROL"] > 0          # rejection overhead was paid


def test_first_tier_accepts_when_confident(monkeypatch):
    items = T.make_benchmark("arith", 5, seed=1)

    def fake_sample_k(slm, its, levels, key, seed_offset=0):
        return [_fake_votes(it.answer, 1.0, len(levels)) for it in its]

    monkeypatch.setattr(cm, "sample_k", fake_sample_k)
    tier = cm.Tier(slm=FakeSLM(), tau=0.6, mode="FCV", k=4)
    term = cm.TerminalTier(llm=OracleLLM(accuracy=1.0))
    out = cm.run_cascade([tier], term, items, jax.random.PRNGKey(0))
    s = cm.summarize(out, 1)
    assert s["tier_histogram"] == [5, 0]
    assert s["accuracy"] == 1.0
    assert s["AROL"] == 0.0


def test_middle_tier_catches_what_tier0_rejects(monkeypatch):
    items = T.make_benchmark("modchain", 8, seed=2)
    calls = []

    def fake_sample_k(slm, its, levels, key, seed_offset=0):
        calls.append(seed_offset)
        if seed_offset == 0:       # tier 0 rejects all
            return [_fake_votes(None, 1.0, len(levels)) for _ in its]
        return [_fake_votes(it.answer, 1.0, len(levels)) for it in its]

    monkeypatch.setattr(cm, "sample_k", fake_sample_k)
    tiers = [cm.Tier(slm=FakeSLM(), tau=0.6, k=4, out_price=0.02),
             cm.Tier(slm=FakeSLM(), tau=0.6, k=4, out_price=0.08)]
    term = cm.TerminalTier(llm=OracleLLM(accuracy=1.0))
    out = cm.run_cascade(tiers, term, items, jax.random.PRNGKey(0))
    s = cm.summarize(out, 2)
    assert s["tier_histogram"] == [0, 8, 0]
    # AGL of the winning tier includes the tier-0 decision overhead
    assert s["AGL"] > 0
    assert calls == [0, 1]


def test_cost_monotone_in_tier_depth(monkeypatch):
    """Falling further down the chain can only cost more."""
    items = T.make_benchmark("arith", 4, seed=3)

    def rejecting(slm, its, levels, key, seed_offset=0):
        return [_fake_votes(None, 1.0, len(levels)) for _ in its]

    def accepting(slm, its, levels, key, seed_offset=0):
        return [_fake_votes(it.answer, 1.0, len(levels)) for it in its]

    term = cm.TerminalTier(llm=OracleLLM(accuracy=1.0, avg_out_tokens=40))
    tier = cm.Tier(slm=FakeSLM(), tau=0.6, k=4)

    monkeypatch.setattr(cm, "sample_k", accepting)
    cheap = cm.summarize(cm.run_cascade([tier], term, items,
                                        jax.random.PRNGKey(0)), 1)
    monkeypatch.setattr(cm, "sample_k", rejecting)
    costly = cm.summarize(cm.run_cascade([tier], term, items,
                                         jax.random.PRNGKey(0)), 1)
    assert costly["cost"] > cheap["cost"]
