"""End-to-end behaviour tests: engine -> sampling -> voting -> routing ->
metrics, on a tiny model (mechanism-level; the learning-quality runs live
in examples/ and benchmarks/)."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import metrics as metrics_lib
from repro.core import routing as routing_lib
from repro.core.cost import DEFAULT, with_ratio
from repro.data import tasks as tasks_lib
from repro.data.tokenizer import default_tokenizer
from repro.serving.engine import GenConfig


def tiny_cfg(vocab):
    return ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                       d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                       d_ff=128, vocab_size=vocab, remat=False,
                       source="test")


@pytest.fixture(scope="module")
def slm():
    from repro.models import model as M
    tok = default_tokenizer()
    cfg = tiny_cfg(tok.vocab_size)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return routing_lib.SLM(params, cfg, tok,
                           GenConfig(max_new_tokens=24, temperature=0.7),
                           max_prompt_len=160, lane_budget=40)


@pytest.fixture(scope="module")
def items():
    return tasks_lib.make_benchmark("arith", 6, seed=1)


def test_batch_generate_shapes(slm):
    texts, lens = routing_lib.batch_generate(
        slm, ["Q: Compute 1 + 1.\nA: ", "Q: hi\nA: "], jax.random.PRNGKey(1))
    assert len(texts) == 2 and len(lens) == 2
    assert all(l >= 1 for l in lens)


def test_cascade_outcomes_structure(slm, items):
    llm = routing_lib.OracleLLM(accuracy=1.0, avg_out_tokens=40)
    out = routing_lib.cascade_outcomes(slm, items, llm, jax.random.PRNGKey(2),
                                       mode="FCV", k=4,
                                       thresholds=[0.0, 0.6, 1.0])
    assert set(out) == {0.0, 0.6, 1.0}
    for tau, rows in out.items():
        assert len(rows) == len(items)
        for r in rows:
            assert r.slm_engaged
            assert r.slm_out_tokens >= 0
            assert r.decision_tokens >= 0
    # tau=0: nothing routed (any score >= 0)
    assert not any(r.routed for r in out[0.0])


def test_cascade_early_stop_cheaper_than_full(slm, items):
    llm = routing_lib.OracleLLM()
    key = jax.random.PRNGKey(3)
    es = routing_lib.cascade_outcomes(slm, items, llm, key, mode="FCV", k=4,
                                      thresholds=[0.6], early_stop=True)
    full = routing_lib.cascade_outcomes(slm, items, llm, key, mode="FCV", k=4,
                                        thresholds=[0.6], early_stop=False)
    t_es = sum(r.slm_out_tokens for r in es[0.6])
    t_full = sum(r.slm_out_tokens for r in full[0.6])
    assert t_es <= t_full


def test_pregen_outcomes_and_toa(slm, items):
    llm = routing_lib.OracleLLM(accuracy=0.9, avg_out_tokens=40)
    key = jax.random.PRNGKey(4)
    out = routing_lib.pregen_outcomes_sater(slm, items, llm, key,
                                            thresholds=[0.0, 0.5, 1.0])
    (c_s, p_s), slm_corr, slm_out, _ = routing_lib.slm_only_endpoint(
        slm, items, llm, key, DEFAULT)
    golden = metrics_lib.golden_toga_100(
        slm_corr, [len(routing_lib.format_prompt(it)) for it in items],
        slm_out, DEFAULT, [40] * len(items))
    summ = metrics_lib.outcome_toa_summary(out, DEFAULT, (c_s, p_s), golden)
    for k in ("toa", "toa_100", "togr"):
        assert np.isfinite(summ[k])


def test_latency_metrics(slm, items):
    llm = routing_lib.OracleLLM()
    out = routing_lib.cascade_outcomes(slm, items, llm, jax.random.PRNGKey(5),
                                       mode="RCV", k=4, thresholds=[0.6])
    lat = metrics_lib.outcome_latency(out[0.6])
    assert lat["AGL"] >= 0 and lat["AROL"] >= 0
    assert 0 <= lat["frac_accepted"] <= 1


def test_cost_ratio_scaling(slm, items):
    # higher LLM cost ratio makes routing everything more expensive
    llm = routing_lib.OracleLLM()
    out = routing_lib.cascade_outcomes(slm, items, llm, jax.random.PRNGKey(6),
                                       mode="SC", k=3, thresholds=[1.0])
    pts_cheap = metrics_lib.points_from_outcomes(out, with_ratio(13.75))
    pts_dear = metrics_lib.points_from_outcomes(out, with_ratio(100))
    # with costs normalized to LLM-only, the SLM overhead term shrinks as
    # the ratio grows, so normalized cascade cost is LOWER at ratio 100
    assert pts_dear[0][0] <= pts_cheap[0][0] + 1e-9
