"""Block-pool allocator + paged scheduler integration: alloc/free
round-trips, refcounted sharing and copy-on-write, reservation-gated
admission backpressure, and no block leaked (or double-freed) when
VoteEarlyStop kills vote groups — shared-prefix or not — mid-flight."""

import collections
import random

import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core import routing as routing_lib
from repro.serving.batch import GenConfig
from repro.serving.block_pool import BlockPool, StateSlotPool
from repro.serving.scheduler import (Request, RequestGroup, Scheduler,
                                     StopPolicy)

MAXP = 64


@pytest.fixture(scope="module")
def setup():
    from repro.data.tokenizer import default_tokenizer
    from repro.models import model as M
    tok = default_tokenizer()
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128, vocab_size=tok.vocab_size, remat=False,
                      source="test")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg, tok


# ----------------------------------------------------------------------
# Allocator unit behaviour
# ----------------------------------------------------------------------

def test_alloc_free_roundtrip():
    pool = BlockPool(8, block_size=16)
    a = pool.alloc(0)
    assert a == [] and pool.in_use == 0
    assert pool.reserve(5)
    ids = pool.alloc(5)
    assert len(ids) == len(set(ids)) == 5
    assert all(1 <= i <= 8 for i in ids)          # 0 is the trash block
    assert pool.in_use == 5 and pool.n_free == 3 and pool.peak_in_use == 5
    pool.free(ids[:2])
    assert pool.in_use == 3 and pool.peak_in_use == 5
    # freed ids come back out (LIFO) before untouched ones
    assert pool.reserve(2)
    assert set(pool.alloc(2)) == set(ids[:2])
    pool.free(ids[2:] + ids[:2])
    assert pool.in_use == 0 and pool.n_free == 8


def test_reservation_gates_admission():
    pool = BlockPool(4, block_size=8)
    assert pool.reserve(3)
    assert not pool.reserve(2)        # only 1 unpromised block left
    assert pool.reserve(1)
    assert pool.available == 0
    # draws come out of the reservation, not on top of it
    pool.alloc(2)
    assert pool.reserved == 2 and pool.available == 0
    pool.unreserve(2)
    assert pool.available == 2


def test_alloc_and_free_misuse_raise():
    pool = BlockPool(2, block_size=8)
    with pytest.raises(RuntimeError):
        pool.alloc(1)                 # nothing reserved
    with pytest.raises(ValueError):
        pool.free([0])                # trash block is not allocatable
    with pytest.raises(ValueError):
        pool.free([1])                # never allocated
    pool.reserve(1)
    (bid,) = pool.alloc(1)
    pool.free([bid])
    with pytest.raises(ValueError):
        pool.free([bid])              # double-free
    pool.reserve(2)
    a, b = pool.alloc(2)
    with pytest.raises(ValueError):
        pool.free([a, a])             # duplicate in one call
    with pytest.raises(ValueError):
        pool.unreserve(1)
    with pytest.raises(ValueError):
        BlockPool(0, block_size=8)


# ----------------------------------------------------------------------
# Refcounted sharing + copy-on-write
# ----------------------------------------------------------------------

def test_share_and_refcounted_free():
    """free() releases one hold; a block returns to the pool only when
    its last holder lets go."""
    pool = BlockPool(8, block_size=16)
    assert pool.reserve(3)
    ids = pool.alloc(3)
    pool.share(ids, 2)                      # 3 holders each
    assert all(pool.refcount(i) == 3 for i in ids)
    pool.free(ids)
    pool.free(ids)
    assert pool.in_use == 3 and pool.n_free == 5   # still held once
    pool.free(ids)
    assert pool.in_use == 0 and pool.n_free == 8
    with pytest.raises(ValueError):
        pool.free(ids)                      # all holds already released


def test_free_multiset_respects_holds():
    """One free() call may release several holds of the same block, but
    never more than exist."""
    pool = BlockPool(4, block_size=8)
    assert pool.reserve(1)
    (a,) = pool.alloc(1)
    pool.share([a], 2)
    pool.free([a, a])                       # two of the three holds
    assert pool.refcount(a) == 1 and pool.in_use == 1
    with pytest.raises(ValueError):
        pool.free([a, a])                   # only one hold left
    assert pool.refcount(a) == 1            # failed free mutated nothing
    pool.free([a])
    assert pool.in_use == 0


def test_share_requires_allocated():
    pool = BlockPool(4, block_size=8)
    with pytest.raises(ValueError):
        pool.share([1])                     # never allocated
    assert pool.reserve(1)
    (a,) = pool.alloc(1)
    pool.free([a])
    with pytest.raises(ValueError):
        pool.share([a])                     # already back in the pool


def test_cow_exclusive_holder_keeps_block():
    pool = BlockPool(4, block_size=8)
    assert pool.reserve(1)
    (a,) = pool.alloc(1)
    assert pool.cow(a) == (a, False)        # sole holder: no copy
    assert pool.in_use == 1 and pool.cow_copies == 0


def test_cow_shared_materializes_private_copies():
    """K holders of a partial tail block resolve to K distinct private
    blocks: K-1 copies drawn from reservations, the last holder keeps
    the original."""
    pool = BlockPool(8, block_size=8)
    assert pool.reserve(3)                  # tail + two CoW copies
    (tail,) = pool.alloc(1)
    pool.share([tail], 2)                   # 3 holders (K = 3 vote lanes)
    got = [pool.cow(tail) for _ in range(3)]
    copies = [b for b, copied in got if copied]
    assert len(copies) == 2 and tail not in copies
    assert got[-1] == (tail, False)         # last holder: original, free
    assert pool.cow_copies == 2 and pool.reserved == 0
    assert len({b for b, _ in got}) == 3    # three distinct private blocks
    assert all(pool.refcount(b) == 1 for b, _ in got)
    pool.free([tail] + copies)
    assert pool.in_use == 0


def test_cow_unallocated_raises():
    with pytest.raises(ValueError):
        BlockPool(2, block_size=8).cow(1)


# ----------------------------------------------------------------------
# Interleaved-op driver (shared with the hypothesis property test)
# ----------------------------------------------------------------------

def drive_block_pool(ops, n_blocks=12, block_size=8):
    """Interpret (op, arg) pairs as reserve/unreserve/alloc/share/cow/
    free/offload/restore/discard against a model of holders, checking
    after every step:

      invariant 1:  in_use + n_free == n_blocks (no leak),
      invariant 2:  reserved <= n_free (promises are backed),
      sharing:      refcount(b) == holds the model granted — so a block
                    is never live in two *unrelated* lanes (alloc and
                    cow assert their fresh block has no other holder),
      free list:    refcount 0 <=> the block is in the free list,
      host side:    every host hold the model granted (offload moves a
                    device hold across the boundary one-for-one, restore
                    moves it back) is exactly what the pool records; the
                    dual-residence twin maps are a bijection touching
                    only blocks live on BOTH sides; over-restore raises
                    before mutating anything.
    """
    pool = BlockPool(n_blocks, block_size)
    lanes = []                    # each: list of held block ids
    holds = collections.Counter()
    host_holds = collections.Counter()
    parked = []                   # outstanding HostBlocks handles
    reserved = 0
    for op, arg in ops:
        if op == 0:               # reserve
            n = arg % (n_blocks + 2)
            before = pool.available
            ok = pool.reserve(n)
            assert ok == (n <= before)
            if ok:
                reserved += n
        elif op == 1:             # return part of a reservation
            if reserved:
                n = arg % reserved + 1
                pool.unreserve(n)
                reserved -= n
        elif op == 2:             # draw a new private lane
            if reserved:
                n = arg % reserved + 1
                ids = pool.alloc(n)
                reserved -= n
                assert len(set(ids)) == n
                for i in ids:
                    assert holds[i] == 0, \
                        "freshly alloc'd block already live elsewhere"
                    holds[i] += 1
                lanes.append(list(ids))
        elif op == 3:             # share a lane's blocks into a new lane
            if lanes:
                src = lanes[arg % len(lanes)]
                pool.share(src)
                for i in src:
                    holds[i] += 1
                lanes.append(list(src))
        elif op == 4:             # copy-on-write a lane's tail block
            if lanes:
                lane = lanes[arg % len(lanes)]
                tail = lane[-1]
                if pool.refcount(tail) == 1 or reserved >= 1:
                    refs = pool.refcount(tail)
                    blk, copied = pool.cow(tail)
                    assert copied == (refs > 1)
                    if copied:
                        reserved -= 1
                        assert holds[blk] == 0, \
                            "CoW copy given a block live elsewhere"
                        holds[tail] -= 1
                        holds[blk] += 1
                        lane[-1] = blk
                    else:
                        assert blk == tail
        elif op == 5:             # free a whole lane
            if lanes:
                lane = lanes.pop(arg % len(lanes))
                pool.free(lane)
                for i in lane:
                    holds[i] -= 1
        elif op == 6:             # park a lane's holds in host RAM
            if lanes:
                lane = lanes.pop(arg % len(lanes))
                hb, copies = pool.offload(lane)
                assert len(hb.ids) == len(lane)
                # only first offloaders copy; co-holders attach for free
                assert {h for _, h in copies} <= set(hb.ids)
                for i in lane:
                    holds[i] -= 1      # one device hold crosses over...
                for h in hb.ids:
                    host_holds[h] += 1  # ...to exactly one host hold
                parked.append(hb)
        elif op == 7:             # redeem a parked handle
            if parked:
                hb = parked[arg % len(parked)]
                cost = pool.restore_cost(hb)
                if cost > reserved:
                    # over-restore raises BEFORE mutating anything
                    snap = (pool.in_use, pool.reserved,
                            dict(pool._host_refs), dict(pool._host_of))
                    try:
                        pool.restore(hb)
                        raise AssertionError(
                            "under-reserved restore must raise")
                    except RuntimeError:
                        pass
                    assert snap == (pool.in_use, pool.reserved,
                                    dict(pool._host_refs),
                                    dict(pool._host_of))
                else:
                    parked.remove(hb)
                    blocks, scatters, _ = pool.restore(hb)
                    reserved -= cost
                    assert len(blocks) == len(hb.ids)
                    # twinned blocks re-share in place: no bytes moved
                    assert len(scatters) == cost
                    for i in blocks:
                        holds[i] += 1
                    for h in hb.ids:
                        host_holds[h] -= 1
                    lanes.append(list(blocks))
        elif op == 8:             # drop a parked handle (cancellation)
            if parked:
                hb = parked.pop(arg % len(parked))
                dropped = pool.discard(hb)
                for h in hb.ids:
                    host_holds[h] -= 1
                assert set(dropped) == \
                    {h for h in hb.ids if host_holds[h] == 0}
        assert pool.in_use + pool.n_free == pool.n_blocks
        assert pool.reserved == reserved
        assert pool.reserved <= pool.n_free
        for i in range(1, pool.n_blocks + 1):
            assert pool.refcount(i) == holds[i]
            assert (pool.refcount(i) == 0) == (i in pool._free_set)
        # host refcounts: exactly the holds the model granted
        assert pool._host_refs == {h: c for h, c in host_holds.items() if c}
        # dual-residence twins: a bijection over blocks live on BOTH sides
        assert pool._dev_of == {h: d for d, h in pool._host_of.items()}
        for d, h in pool._host_of.items():
            assert pool.refcount(d) > 0 and pool.host_refcount(h) > 0
    for lane in lanes:
        pool.free(lane)
    for hb in parked:
        pool.discard(hb)
    pool.unreserve(reserved)
    assert pool.in_use == 0 and pool.n_free == pool.n_blocks
    assert pool.leak_report() is None


def test_block_pool_interleaved_ops_seeded_fuzz():
    """Deterministic companion of the hypothesis property test in
    tests/test_property.py (same driver), runnable without hypothesis."""
    rng = random.Random(0)
    for _ in range(150):
        ops = [(rng.randrange(9), rng.randrange(64))
               for _ in range(rng.randrange(1, 40))]
        drive_block_pool(ops)


# ----------------------------------------------------------------------
# Host offload unit behaviour
# ----------------------------------------------------------------------

def test_offload_restore_roundtrip():
    """A private lane offloads (device holds released, one copy per
    block) and restores (fresh blocks from the caller's reservation,
    one scatter per block, host side drained)."""
    pool = BlockPool(8, block_size=8)
    assert pool.reserve(3)
    ids = pool.alloc(3)
    hb, copies = pool.offload(ids)
    assert [d for d, _ in copies] == ids          # first offload: all copy
    assert pool.in_use == 0 and pool.host_in_use == 3
    assert pool.offloaded_blocks == 3
    assert pool.restore_cost(hb) == 3
    assert pool.reserve(3)
    blocks, scatters, dropped = pool.restore(hb)
    assert len(blocks) == 3 and len(scatters) == 3
    assert sorted(dropped) == sorted(hb.ids)      # last holds redeemed
    assert pool.host_in_use == 0 and pool.in_use == 3
    pool.free(blocks)
    assert pool.leak_report() is None


def test_offload_shared_block_copies_once():
    """Two holders of a shared block each offload: the first copies,
    the second attaches to the same host block (refcount 2), and the
    host ids agree."""
    pool = BlockPool(8, block_size=8)
    assert pool.reserve(1)
    (b,) = pool.alloc(1)
    pool.share([b])                               # two holders
    hb1, copies1 = pool.offload([b])
    assert copies1 == [(b, hb1.ids[0])]
    assert pool.refcount(b) == 1                  # co-holder still live
    hb2, copies2 = pool.offload([b])
    assert copies2 == [] and hb2.ids == hb1.ids   # attach, no second copy
    assert pool.host_refcount(hb1.ids[0]) == 2
    assert pool.in_use == 0 and pool.host_in_use == 1
    # both restore shared: one fresh block, then a zero-copy re-share
    assert pool.reserve(1)
    blocks1, scatters1, dropped1 = pool.restore(hb1)
    assert len(scatters1) == 1 and dropped1 == []
    assert pool.restore_cost(hb2) == 0            # live twin: free
    blocks2, scatters2, dropped2 = pool.restore(hb2)
    assert blocks2 == blocks1 and scatters2 == []
    assert dropped2 == hb2.ids
    assert pool.refcount(blocks1[0]) == 2
    pool.free(blocks1 + blocks2)
    assert pool.leak_report() is None


def test_restore_under_reserved_raises_before_mutating():
    pool = BlockPool(8, block_size=8)
    assert pool.reserve(2)
    ids = pool.alloc(2)
    hb, _ = pool.offload(ids)
    assert pool.reserve(1)                        # 1 < restore_cost == 2
    snap = (pool.in_use, pool.reserved, dict(pool._host_refs))
    with pytest.raises(RuntimeError, match="reserve"):
        pool.restore(hb)
    assert snap == (pool.in_use, pool.reserved, dict(pool._host_refs))
    pool.unreserve(1)
    assert pool.reserve(2)
    blocks, _, _ = pool.restore(hb)
    pool.free(blocks)
    assert pool.leak_report() is None


def test_stale_handle_and_over_discard_raise():
    pool = BlockPool(8, block_size=8)
    assert pool.reserve(1)
    hb, _ = pool.offload(pool.alloc(1))
    assert pool.discard(hb) == hb.ids
    with pytest.raises(ValueError, match="discard"):
        pool.discard(hb)                          # handle already dead
    with pytest.raises(ValueError, match="restore"):
        pool.restore(hb)
    assert pool.leak_report() is None


def test_offload_requires_holds():
    pool = BlockPool(4, block_size=8)
    with pytest.raises(ValueError, match="offload"):
        pool.offload([1])                         # never allocated
    assert pool.reserve(1)
    (b,) = pool.alloc(1)
    with pytest.raises(ValueError, match="offload"):
        pool.offload([b, b])                      # held once, listed twice
    assert pool.refcount(b) == 1                  # nothing mutated
    pool.free([b])
    assert pool.leak_report() is None


def test_leak_report_flags_host_side():
    pool = BlockPool(4, block_size=8)
    assert pool.reserve(1)
    hb, _ = pool.offload(pool.alloc(1))
    report = pool.leak_report()
    assert report is not None and "host" in report
    pool.discard(hb)
    assert pool.leak_report() is None


# ----------------------------------------------------------------------
# State-slot pool (recurrent / SSM leg of the cache protocol)
# ----------------------------------------------------------------------

def test_state_slot_alloc_free_roundtrip():
    pool = StateSlotPool(3, slot_bytes=128)
    assert pool.reserve(2) and not pool.reserve(2)   # only 1 unpromised
    a = pool.alloc()
    b = pool.alloc()
    assert a != b and all(1 <= s <= 3 for s in (a, b))
    assert pool.in_use == 2 and pool.peak_in_use == 2
    assert pool.peak_state_bytes == 2 * 128
    pool.free(a)
    assert pool.in_use == 1 and pool.peak_in_use == 2
    # freed slots come back out (LIFO) before untouched ones
    assert pool.reserve(1)
    assert pool.alloc() == a
    pool.free(a)
    pool.free(b)
    assert pool.leak_report() is None


def test_state_slot_misuse_raises():
    pool = StateSlotPool(2)
    with pytest.raises(RuntimeError, match="reserv"):
        pool.alloc()                  # nothing reserved
    with pytest.raises(ValueError):
        pool.free(1)                  # never allocated
    assert pool.reserve(1)
    s = pool.alloc()
    pool.free(s)
    with pytest.raises(ValueError):
        pool.free(s)                  # double-free
    with pytest.raises(ValueError):
        pool.unreserve(1)
    with pytest.raises(ValueError):
        StateSlotPool(0)


def test_state_slot_offload_restore_discard():
    """offload() frees the device slot and hands back a monotonic host
    id; restore() draws a fresh slot from a new reservation; discard()
    drops a parked id.  Stale handles raise; the drained pool's leak
    report is clean, an undrained one names what is held."""
    pool = StateSlotPool(2, slot_bytes=64)
    assert pool.reserve(2)
    a, b = pool.alloc(), pool.alloc()
    h1 = pool.offload(a)
    assert pool.in_use == 1 and pool.host_in_use == 1
    assert pool.offloaded_slots == 1 and pool.host_slots_peak == 1
    report = pool.leak_report()
    assert report is not None and "host" in report
    assert pool.reserve(1)
    a2 = pool.restore(h1)
    assert a2 == a                    # LIFO: the freed slot comes back
    assert pool.restored_slots == 1 and pool.host_in_use == 0
    with pytest.raises(ValueError, match="restore"):
        pool.restore(h1)              # handle already redeemed
    h2 = pool.offload(b)
    assert h2 != h1                   # host ids are never recycled
    pool.discard(h2)
    with pytest.raises(ValueError, match="discard"):
        pool.discard(h2)
    pool.free(a2)
    assert pool.leak_report() is None


def test_state_slot_id_base_spacing():
    """Per-shard pools use disjoint id ranges (base+1..base+n), same
    spacing convention as BlockPool's per-shard slabs."""
    pools = [StateSlotPool(2, id_base=s * 3) for s in range(2)]
    ids = []
    for p in pools:
        assert p.reserve(2)
        ids += [p.alloc(), p.alloc()]
    assert sorted(ids) == [1, 2, 4, 5]
    with pytest.raises(ValueError):
        StateSlotPool(2, id_base=-1)


# ----------------------------------------------------------------------
# Scheduler integration
# ----------------------------------------------------------------------

def _no_eos(max_new):
    return GenConfig(max_new_tokens=max_new, temperature=0.7, eos_id=-1)


def test_pool_exhaustion_backpressures_admission(setup):
    """A pool holding exactly one worst-case lane serializes admissions:
    everything still completes, in order, with no leak."""
    params, cfg, tok = setup
    bs = 8
    sched = Scheduler(params, cfg, tok, _no_eos(8), n_lanes=4,
                      round_tokens=4, max_prompt_len=MAXP, paged=True,
                      block_size=bs, pool_blocks=-(-(MAXP + 8) // bs))
    reqs = [Request(uid=i, prompt=f"Q: item {i}\nA: ") for i in range(6)]
    comps, stats = sched.run(reqs, jax.random.PRNGKey(1))
    assert [c.uid for c in comps] == list(range(6))
    assert all(c.gen_len == 8 and not c.cancelled for c in comps)
    assert stats.admission_blocked > 0
    assert stats.peak_blocks_in_use <= sched.pool_blocks
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0


def test_pool_too_small_for_one_lane_rejected(setup):
    params, cfg, tok = setup
    with pytest.raises(ValueError):
        Scheduler(params, cfg, tok, _no_eos(8), n_lanes=4,
                  max_prompt_len=MAXP, paged=True, block_size=8,
                  pool_blocks=2)


class _FirstFinishKills(StopPolicy):
    def observe(self, comp):
        return (comp.group,)


def test_no_block_leaked_after_vote_early_stop(setup):
    """Killing K-lane groups mid-flight must return every block and
    every unused reservation to the pool — SATER's rejection as freed
    memory."""
    params, cfg, tok = setup
    sched = Scheduler(params, cfg, tok, _no_eos(32), n_lanes=4,
                      round_tokens=4, max_prompt_len=MAXP, paged=True,
                      block_size=8)
    reqs = [Request(uid=i, prompt=f"Q: item {i}\nA: ", group=i // 5,
                    max_new_tokens=(4 if i % 5 == 0 else 32))
            for i in range(10)]
    es, es_stats = sched.run(reqs, jax.random.PRNGKey(1),
                             stop_policy=_FirstFinishKills())
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    assert es_stats.cancelled == 8
    peak_es = es_stats.peak_blocks_in_use
    full, full_stats = sched.run(reqs, jax.random.PRNGKey(1))
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    # reclaimed blocks show up as a lower (or equal) high-water mark
    assert peak_es <= full_stats.peak_blocks_in_use
    assert es_stats.generated_tokens < full_stats.generated_tokens


class _KillAndSnapshot(StopPolicy):
    """Kills every group on its first completion, recording the pool
    state the policy saw mid-flight."""

    def __init__(self, sched):
        self.sched = sched
        self.snaps = []

    def observe(self, comp):
        pool = self.sched.pool
        self.snaps.append((pool.in_use, pool.reserved))
        return (comp.group,)


def test_early_stop_shared_group_releases_refcounted_blocks(setup):
    """Regression: VoteEarlyStop killing a decided K-group under
    share_prefix frees exactly the group's private tail blocks and
    *decrements* (not frees) the shared prompt blocks — any double-free
    would raise inside free(), any leak shows as a non-empty pool after
    run().  The pool must drain to empty including the prefix cache's
    own holds."""
    params, cfg, tok = setup
    K = 4
    sched = Scheduler(params, cfg, tok, _no_eos(32), n_lanes=4,
                      round_tokens=4, max_prompt_len=MAXP, paged=True,
                      block_size=8, share_prefix=True)
    # lane 0 of each group finishes after round 1 (budget 4); the policy
    # then kills its group's other K-1 lanes mid-flight while they all
    # still hold the shared prompt blocks
    groups = [RequestGroup([
        Request(uid=qi * K + j, prompt=f"Q: same long shared prompt {qi}\nA: ",
                group=qi, max_new_tokens=(4 if j == 0 else 32))
        for j in range(K)]) for qi in range(3)]
    policy = _KillAndSnapshot(sched)
    es, es_stats = sched.run(groups, jax.random.PRNGKey(1),
                             stop_policy=policy)
    # each group shared one prefill; the kills released every hold
    assert es_stats.shared_lanes == 3 * (K - 1)
    assert es_stats.cancelled == 3 * (K - 1)
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    # mid-flight the killed groups' shared blocks were still held
    assert all(in_use > 0 for in_use, _ in policy.snaps)
    # the same groups run to completion: more tokens, no lower peak
    full, full_stats = sched.run(groups, jax.random.PRNGKey(1))
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    assert es_stats.generated_tokens < full_stats.generated_tokens
    assert es_stats.peak_blocks_in_use <= full_stats.peak_blocks_in_use
    # killed lanes still returned whatever they had generated so far
    for qi in range(3):
        grp = es[qi * K:(qi + 1) * K]
        assert not grp[0].cancelled and grp[0].gen_len == 4
        assert all(c.cancelled for c in grp[1:])


def test_shared_admission_backpressure_and_prefix_cache_eviction(setup):
    """A pool sized for one K-group serializes group admissions: the
    prefix cache gives up its warm blocks (LRU eviction) before
    admission blocks, everything completes, and nothing leaks."""
    params, cfg, tok = setup
    bs = 8
    K = 3
    s_max_blocks = -(-(MAXP + 8) // bs)
    sched = Scheduler(params, cfg, tok, _no_eos(8), n_lanes=K,
                      round_tokens=4, max_prompt_len=MAXP, paged=True,
                      block_size=bs, share_prefix=True,
                      pool_blocks=K * s_max_blocks)
    groups = [RequestGroup([
        Request(uid=qi * K + j, prompt=f"Q: item {qi} with a long tail\nA: ",
                group=qi) for j in range(K)]) for qi in range(4)]
    comps, stats = sched.run(groups, jax.random.PRNGKey(1))
    assert [c.uid for c in comps] == list(range(4 * K))
    assert all(c.gen_len == 8 and not c.cancelled for c in comps)
    assert stats.prefill_prompts == 4          # one prefill per group
    assert stats.shared_lanes == 4 * (K - 1)
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    assert stats.peak_blocks_in_use <= sched.pool_blocks


def test_paged_streaming_matches_dense_decisions(setup):
    """The streamed cascade makes identical accept/route decisions on
    the dense, paged, and shared-prefix paged caches (greedy: identical
    tokens, too) — and the shared run prefills each question once."""
    params, cfg, tok = setup
    import repro.data.tasks as tasks_lib
    items = tasks_lib.make_benchmark("arith", 4, seed=1)
    key = jax.random.PRNGKey(9)
    results = {}
    for mode in ("dense", "paged", "shared"):
        slm = routing_lib.SLM(params, cfg, tok,
                              GenConfig(max_new_tokens=24, temperature=0.0),
                              max_prompt_len=MAXP, lane_budget=16,
                              round_tokens=4, paged=mode != "dense",
                              block_size=8, share_prefix=mode == "shared")
        rows, stats = routing_lib.sample_k_streamed(
            slm, items, [1.0] * 4, key, tau=1.0, early_stop=True)
        results[mode] = rows
        assert stats.generated_tokens > 0
        if mode == "shared":
            # one prefill per question, not per vote lane
            assert stats.prefill_prompts == len(items)
            assert stats.shared_lanes > 0
    for mode in ("paged", "shared"):
        for rd, rp in zip(results["dense"], results[mode]):
            assert rd.decision.accepted == rp.decision.accepted
            assert rd.decision.answer == rp.decision.answer
            assert [v.text for v in rd.votes] == [v.text for v in rp.votes]
