"""Block-pool allocator + paged scheduler integration: alloc/free
round-trips, reservation-gated admission backpressure, and no block
leaked when VoteEarlyStop kills vote groups mid-flight."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import routing as routing_lib
from repro.serving.batch import GenConfig
from repro.serving.block_pool import BlockPool
from repro.serving.scheduler import Request, Scheduler, StopPolicy

MAXP = 64


@pytest.fixture(scope="module")
def setup():
    from repro.data.tokenizer import default_tokenizer
    from repro.models import model as M
    tok = default_tokenizer()
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128, vocab_size=tok.vocab_size, remat=False,
                      source="test")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg, tok


# ----------------------------------------------------------------------
# Allocator unit behaviour
# ----------------------------------------------------------------------

def test_alloc_free_roundtrip():
    pool = BlockPool(8, block_size=16)
    a = pool.alloc(0)
    assert a == [] and pool.in_use == 0
    assert pool.reserve(5)
    ids = pool.alloc(5)
    assert len(ids) == len(set(ids)) == 5
    assert all(1 <= i <= 8 for i in ids)          # 0 is the trash block
    assert pool.in_use == 5 and pool.n_free == 3 and pool.peak_in_use == 5
    pool.free(ids[:2])
    assert pool.in_use == 3 and pool.peak_in_use == 5
    # freed ids come back out (LIFO) before untouched ones
    assert pool.reserve(2)
    assert set(pool.alloc(2)) == set(ids[:2])
    pool.free(ids[2:] + ids[:2])
    assert pool.in_use == 0 and pool.n_free == 8


def test_reservation_gates_admission():
    pool = BlockPool(4, block_size=8)
    assert pool.reserve(3)
    assert not pool.reserve(2)        # only 1 unpromised block left
    assert pool.reserve(1)
    assert pool.available == 0
    # draws come out of the reservation, not on top of it
    pool.alloc(2)
    assert pool.reserved == 2 and pool.available == 0
    pool.unreserve(2)
    assert pool.available == 2


def test_alloc_and_free_misuse_raise():
    pool = BlockPool(2, block_size=8)
    with pytest.raises(RuntimeError):
        pool.alloc(1)                 # nothing reserved
    with pytest.raises(ValueError):
        pool.free([0])                # trash block is not allocatable
    with pytest.raises(ValueError):
        pool.free([1])                # never allocated
    pool.reserve(1)
    (bid,) = pool.alloc(1)
    pool.free([bid])
    with pytest.raises(ValueError):
        pool.free([bid])              # double-free
    pool.reserve(2)
    a, b = pool.alloc(2)
    with pytest.raises(ValueError):
        pool.free([a, a])             # duplicate in one call
    with pytest.raises(ValueError):
        pool.unreserve(1)
    with pytest.raises(ValueError):
        BlockPool(0, block_size=8)


# ----------------------------------------------------------------------
# Scheduler integration
# ----------------------------------------------------------------------

def _no_eos(max_new):
    return GenConfig(max_new_tokens=max_new, temperature=0.7, eos_id=-1)


def test_pool_exhaustion_backpressures_admission(setup):
    """A pool holding exactly one worst-case lane serializes admissions:
    everything still completes, in order, with no leak."""
    params, cfg, tok = setup
    bs = 8
    sched = Scheduler(params, cfg, tok, _no_eos(8), n_lanes=4,
                      round_tokens=4, max_prompt_len=MAXP, paged=True,
                      block_size=bs, pool_blocks=-(-(MAXP + 8) // bs))
    reqs = [Request(uid=i, prompt=f"Q: item {i}\nA: ") for i in range(6)]
    comps, stats = sched.run(reqs, jax.random.PRNGKey(1))
    assert [c.uid for c in comps] == list(range(6))
    assert all(c.gen_len == 8 and not c.cancelled for c in comps)
    assert stats.admission_blocked > 0
    assert stats.peak_blocks_in_use <= sched.pool_blocks
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0


def test_pool_too_small_for_one_lane_rejected(setup):
    params, cfg, tok = setup
    with pytest.raises(ValueError):
        Scheduler(params, cfg, tok, _no_eos(8), n_lanes=4,
                  max_prompt_len=MAXP, paged=True, block_size=8,
                  pool_blocks=2)


class _FirstFinishKills(StopPolicy):
    def observe(self, comp):
        return (comp.group,)


def test_no_block_leaked_after_vote_early_stop(setup):
    """Killing K-lane groups mid-flight must return every block and
    every unused reservation to the pool — SATER's rejection as freed
    memory."""
    params, cfg, tok = setup
    sched = Scheduler(params, cfg, tok, _no_eos(32), n_lanes=4,
                      round_tokens=4, max_prompt_len=MAXP, paged=True,
                      block_size=8)
    reqs = [Request(uid=i, prompt=f"Q: item {i}\nA: ", group=i // 5,
                    max_new_tokens=(4 if i % 5 == 0 else 32))
            for i in range(10)]
    es, es_stats = sched.run(reqs, jax.random.PRNGKey(1),
                             stop_policy=_FirstFinishKills())
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    assert es_stats.cancelled == 8
    peak_es = es_stats.peak_blocks_in_use
    full, full_stats = sched.run(reqs, jax.random.PRNGKey(1))
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    # reclaimed blocks show up as a lower (or equal) high-water mark
    assert peak_es <= full_stats.peak_blocks_in_use
    assert es_stats.generated_tokens < full_stats.generated_tokens


def test_paged_streaming_matches_dense_decisions(setup):
    """The streamed cascade makes identical accept/route decisions on
    the paged and dense caches (greedy: identical tokens, too)."""
    params, cfg, tok = setup
    import repro.data.tasks as tasks_lib
    items = tasks_lib.make_benchmark("arith", 4, seed=1)
    key = jax.random.PRNGKey(9)
    results = {}
    for paged in (False, True):
        slm = routing_lib.SLM(params, cfg, tok,
                              GenConfig(max_new_tokens=24, temperature=0.0),
                              max_prompt_len=MAXP, lane_budget=16,
                              round_tokens=4, paged=paged, block_size=8)
        rows, stats = routing_lib.sample_k_streamed(
            slm, items, [1.0] * 4, key, tau=1.0, early_stop=True)
        results[paged] = rows
        assert stats.generated_tokens > 0
    for rd, rp in zip(results[False], results[True]):
        assert rd.decision.accepted == rp.decision.accepted
        assert rd.decision.answer == rp.decision.answer
        assert [v.text for v in rd.votes] == [v.text for v in rp.votes]
