"""Asyncio streaming front-end (launch/async_serve.py).

Three contracts: (1) what a client sees on its token stream is exactly
what the serving loop finalized (and what the one-shot oracle says it
should be); (2) a client that disappears mid-stream releases its lane
within one decode round with nothing delivered and no leaked blocks;
(3) the two-class fair queue keeps ttft-class admission latency bounded
under a throughput-tenant flood, where plain FIFO admission does not.

All tests drive a real ServingLoop on the tiny trace-harness model via
``asyncio.run`` — no event-loop plugin needed.
"""

import asyncio

import jax
import numpy as np
import pytest

from repro.launch.async_serve import THROUGHPUT, TTFT, AsyncServer, FairQueue
from repro.serving.scheduler import Request

from test_serving_trace import MASTER_KEY, MAXNEW, Oracle, _scheduler, _setup


@pytest.fixture(scope="module")
def setup():
    return _setup()


async def _consume(stream, out):
    async for tok in stream:
        out.append(tok)


def test_fair_queue_unit():
    """take() grants ttft_burst ttft-class pops per throughput pop;
    fair=False degrades to arrival-order FIFO."""
    q = FairQueue(ttft_burst=2)
    for u in range(4):
        q.push(THROUGHPUT, Request(uid=u, tokens=[]))
    for u in range(4, 8):
        q.push(TTFT, Request(uid=u, tokens=[]))
    assert [r.uid for r in q.take(6)] == [4, 5, 0, 6, 7, 1]
    assert [r.uid for r in q.take(9)] == [2, 3]
    assert len(q) == 0
    q = FairQueue(fair=False)
    q.push(THROUGHPUT, Request(uid=0, tokens=[]))
    q.push(TTFT, Request(uid=1, tokens=[]))
    q.push(THROUGHPUT, Request(uid=2, tokens=[]))
    assert [r.uid for r in q.take(5)] == [0, 1, 2]
    with pytest.raises(ValueError, match="tenant"):
        q.push("batch", Request(uid=9, tokens=[]))


def test_stream_matches_completion_and_oracle(setup):
    """Per-request stream ordering: the concatenation of every yielded
    token equals the Completion's token array, which equals the
    one-shot oracle — streaming changes delivery, not content."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.7, "paged", chunked=False)
    oracle = Oracle(params, cfg, sched, 0.7)
    prompts = {0: [5, 6, 7], 1: [9] * 11, 2: [8, 3], 3: [4] * 20}

    async def run():
        server = AsyncServer(sched, jax.random.PRNGKey(MASTER_KEY))
        await server.start()
        got = {u: [] for u in prompts}
        streams = [
            server.submit(u, toks,
                          tenant=TTFT if u % 2 else THROUGHPUT)
            for u, toks in prompts.items()]
        await asyncio.gather(*(_consume(s, got[u])
                               for u, s in zip(prompts, streams)))
        await server.close()
        return got, server

    got, server = asyncio.run(run())
    for u, toks in prompts.items():
        comp = server.results[u]
        assert got[u] == comp.tokens.tolist(), \
            "stream must deliver exactly the completion's tokens, in order"
        want = oracle.tokens(u, toks, MAXNEW)
        assert np.array_equal(comp.tokens, want)
    assert sched.pool.leak_report() is None


def test_submit_before_start_lazy_starts_driver(setup):
    """A submit with no prior start() must still stream: the driver is
    lazy-started, so a consumer can never hang on a loop nothing
    drives."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.0, "paged", chunked=False)
    oracle = Oracle(params, cfg, sched, 0.0)

    async def run():
        server = AsyncServer(sched, jax.random.PRNGKey(MASTER_KEY))
        got = []
        await _consume(server.submit(0, [5, 6, 7]), got)
        await server.close()
        return got

    got = asyncio.run(run())
    assert np.array_equal(got, oracle.tokens(0, [5, 6, 7], MAXNEW))
    assert sched.pool.leak_report() is None


def test_cancel_mid_stream_releases_lane_within_one_round(setup):
    """A client that cancels after its first tokens: the stream ends,
    no completion is recorded, the lane is free again within one decode
    round, and the pool comes back clean."""
    params, cfg, _ = _setup()
    sched = _scheduler(params, cfg, 0.7, "paged", chunked=False)
    oracle = Oracle(params, cfg, sched, 0.7)

    async def run():
        server = AsyncServer(sched, jax.random.PRNGKey(MASTER_KEY))
        await server.start()
        s0 = server.submit(0, [5] * 9)
        s1 = server.submit(1, [7, 8])
        got1 = []
        survivor = asyncio.ensure_future(_consume(s1, got1))
        first = []
        async for tok in s0:
            first.append(tok)
            break                       # client walks away mid-stream
        cancel_round = server.rounds
        server.cancel(0)
        while any(lane is not None and lane.req.uid == 0
                  for lane in server.loop.lanes):
            await asyncio.sleep(0)
        freed_after = server.rounds - cancel_round
        await survivor
        await server.close()
        return first, got1, freed_after, server

    first, got1, freed_after, server = asyncio.run(run())
    assert freed_after <= 1, "cancel must release the lane within a round"
    assert 0 not in server.results, "cancelled request must deliver nothing"
    want0 = oracle.tokens(0, [5] * 9, MAXNEW)
    assert first == want0[: len(first)].tolist()
    assert got1 == oracle.tokens(1, [7, 8], MAXNEW).tolist()
    assert sched.pool.leak_report() is None


def test_fair_queue_bounds_ttft_under_flood(setup):
    """12 throughput-tenant requests arrive ahead of 4 ttft-tenant
    ones.  FIFO admission makes the interactive requests wait out the
    whole flood; the fair queue admits them within the first admission
    cycles, so their ttft (in rounds) stays bounded and strictly below
    FIFO's."""
    params, cfg, _ = _setup()

    def p95(server, uids):
        return float(np.percentile([server.ttft_rounds[u] for u in uids],
                                   95))

    async def run(fair):
        sched = _scheduler(params, cfg, 0.0, "paged", chunked=False)
        server = AsyncServer(sched, jax.random.PRNGKey(MASTER_KEY),
                             fair=fair)
        streams = []
        for u in range(12):
            streams.append(server.submit(u, [4, 5, 6],
                                         tenant=THROUGHPUT))
        ttft_uids = list(range(12, 16))
        for u in ttft_uids:
            streams.append(server.submit(u, [7, 8], tenant=TTFT))
        await server.start()
        sinks = [[] for _ in streams]
        await asyncio.gather(*(_consume(s, sink)
                               for s, sink in zip(streams, sinks)))
        await server.close()
        assert len(server.results) == 16
        assert sched.pool.leak_report() is None
        return p95(server, ttft_uids)

    fair_p95 = asyncio.run(run(True))
    fifo_p95 = asyncio.run(run(False))
    assert fair_p95 < fifo_p95, \
        f"fair queue should beat FIFO for ttft tenants " \
        f"({fair_p95} vs {fifo_p95})"
    assert fair_p95 <= 3, f"ttft p95 unbounded under flood: {fair_p95}"
