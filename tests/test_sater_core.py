"""SATER core unit tests: voting (Eq. 6), early stopping, preference-pair
construction (Stage I), refusal data (Stage II), metrics, cost model."""

import numpy as np
import pytest

from repro.core import metrics as metrics_lib
from repro.core import voting
from repro.core.confidence import Vote, fcv_schedule, parse_vote, rcv_schedule
from repro.core.cost import DEFAULT, with_ratio
from repro.core.metrics import QuestionRecord
from repro.core.preferences import SampledQuestion, build_preference_pairs
from repro.core.refusal import build_refusal_dataset
from repro.data import tasks as tasks_lib


def V(ans, conf=1.0, toks=10):
    return Vote(ans, conf, toks)


# ----------------------------------------------------------------------
# Voting (paper Eq. 6)
# ----------------------------------------------------------------------

def test_weight_formula():
    assert voting.weight(0.55) == pytest.approx(0.55)
    assert voting.weight(1.0) == pytest.approx(0.55 + 0.5 * 0.45)
    assert voting.weight(0.1) == pytest.approx(0.55 - 0.5 * 0.45)


def test_vote_scores_rejections_dilute():
    votes = [V("a", 1.0), V(None, 1.0), V(None, 1.0)]
    scores, _ = voting.vote_scores(votes)
    assert scores["a"] == pytest.approx(1 / 3)


def test_higher_confidence_wins_ties():
    votes = [V("a", 1.0), V("b", 0.1)]
    scores, _ = voting.vote_scores(votes)
    assert scores["a"] > scores["b"]


def test_early_stop_accept_when_decided():
    # equal weights: after 2 of 4 votes land on "a", its guaranteed lower
    # bound is 2/4 = 0.5 >= tau -> accept at t=10, not t=100
    votes = [V("a", 1.0, 5), V("a", 1.0, 10), V("a", 1.0, 15),
             V("b", 1.0, 100)]
    dec = voting.decide_with_early_stop(votes, 0.5)
    assert dec.accepted and dec.answer == "a"
    assert dec.decision_tokens == 10          # didn't wait for the 100-token lane
    assert dec.used_tokens == 5 + 10 + 10 + 10  # lanes truncated at decision
    full = voting.decide_no_early_stop(votes, 0.5)
    assert full.decision_tokens == 100
    assert dec.used_tokens < full.used_tokens


def test_early_stop_route_when_unreachable():
    # all rejections: tau can never be reached; route as soon as provable
    votes = [V(None, 1.0, t) for t in (3, 4, 5, 6)]
    dec = voting.decide_with_early_stop(votes, 0.6)
    assert not dec.accepted
    assert dec.decision_tokens <= 6


def test_early_stop_matches_full_decision():
    rng = np.random.RandomState(0)
    for _ in range(200):
        k = rng.randint(1, 10)
        votes = [V(rng.choice(["a", "b", None]),
                   float(rng.choice(rcv_schedule())),
                   int(rng.randint(1, 50))) for _ in range(k)]
        tau = float(rng.choice([0.1, 0.3, 0.5, 0.7, 0.9]))
        es = voting.decide_with_early_stop(votes, tau)
        full = voting.decide_no_early_stop(votes, tau)
        assert es.accepted == full.accepted, (votes, tau)
        # note: on accept the chosen answer also matches unless a later
        # vote only reorders non-winning candidates
        if es.accepted:
            assert es.score >= tau - 1e-9


# ----------------------------------------------------------------------
# Stage I preference pairs
# ----------------------------------------------------------------------

def _sq(answer="7", texts_lens=()):
    item = tasks_lib.TaskItem("t", 1, "q?", answer, ["s1."])
    texts = [t for t, _ in texts_lens]
    lens = [l for _, l in texts_lens]
    return SampledQuestion(item, texts, lens)


def test_preference_pair_selection():
    sq = _sq("7", [("Answer: 7.", 10), ("s1. Answer: 7.", 20),
                   ("Answer: 3.", 40), ("s1. Answer: 3.", 25)])
    pairs = build_preference_pairs([sq])
    assert len(pairs) == 1
    _, chosen, rejected = pairs[0]
    assert chosen == "Answer: 7."          # shortest correct
    assert rejected == "Answer: 3."        # longest incorrect (40 >= 1.5*10)


def test_preference_pair_length_ratio_filter():
    sq = _sq("7", [("Answer: 7.", 30), ("Answer: 3.", 40)])  # 40 < 1.5*30
    assert build_preference_pairs([sq]) == []


def test_preference_pair_needs_both_sides():
    assert build_preference_pairs([_sq("7", [("Answer: 7.", 10)])]) == []
    assert build_preference_pairs([_sq("7", [("Answer: 3.", 10)])]) == []


# ----------------------------------------------------------------------
# Stage II refusal data
# ----------------------------------------------------------------------

def test_refusal_dataset_thresholds():
    sq = _sq("7", [("Answer: 7.", 10), ("Answer: 3.", 12),
                   ("Answer: 7.", 11), ("Answer: 1.", 9)])   # acc = 0.5
    data = build_refusal_dataset([sq], seed=0)
    assert len(data) == 10
    rejects = [t for _, t in data if t == tasks_lib.REJECTION]
    answers = [t for _, t in data if t != tasks_lib.REJECTION]
    assert len(rejects) == 5               # thresholds 0.6..1.0
    assert all("Answer: 7." in a for a in answers)
    # every prompt carries its confidence level
    assert all("confidence level of [" in p for p, _ in data)


# ----------------------------------------------------------------------
# Confidence parsing
# ----------------------------------------------------------------------

def test_parse_vote_rejection_and_answer():
    v = parse_vote("Sorry, I can't answer that.", 0.8, 9)
    assert v.rejected and v.confidence == 0.8
    v2 = parse_vote("step1: ok. Answer: 42.", 0.3, 15)
    assert v2.answer == "42" and not v2.rejected


def test_schedules():
    assert rcv_schedule() == [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]
    assert fcv_schedule() == [1.0] * 10


# ----------------------------------------------------------------------
# Metrics
# ----------------------------------------------------------------------

def _records(n=40, seed=0):
    rng = np.random.RandomState(seed)
    recs = []
    for i in range(n):
        sc = bool(rng.rand() < 0.6)
        recs.append(QuestionRecord(
            slm_correct=sc, llm_correct=bool(rng.rand() < 0.9),
            slm_in_tokens=50, slm_out_tokens=int(rng.randint(10, 80)),
            llm_out_tokens=int(rng.randint(30, 90)),
            score=(0.8 * rng.rand() + 0.2) if sc else 0.6 * rng.rand()))
    return recs


def test_random_router_toa_half():
    # scores independent of correctness => ToA ~ 0.5
    rng = np.random.RandomState(1)
    recs = [QuestionRecord(bool(rng.rand() < 0.5), True, 50, 40, 40,
                           float(rng.rand())) for _ in range(4000)]
    s = metrics_lib.toa_summary(recs, DEFAULT)
    assert abs(s["toa_100"] - 0.5) < 0.05


def test_informed_router_beats_random():
    recs = _records()
    s = metrics_lib.toa_summary(recs, DEFAULT)
    assert s["toa_100"] > 0.5
    assert 0 < s["togr"] <= 1.25    # golden may be imperfectly matched


def test_golden_router_togr_is_one():
    recs = _records()
    golden = [metrics_lib.QuestionRecord(
        r.slm_correct, r.llm_correct, r.slm_in_tokens, r.slm_out_tokens,
        r.llm_out_tokens, 1.0 if r.slm_correct else 0.0) for r in recs]
    s = metrics_lib.toa_summary(golden, DEFAULT)
    assert s["togr"] == pytest.approx(1.0, abs=0.05)


def test_cost_model_ratios():
    cm = with_ratio(50)
    assert cm.ratio == pytest.approx(50)
    assert cm.slm_in == pytest.approx(cm.slm_out * 0.25)
    assert DEFAULT.ratio == pytest.approx(13.75)
