"""Lock the jax backend to a known simulated-device count for the whole
test process.

Sharded-serving tests (test_serving_trace.py sharded mode,
test_sharded_serving.py) need a multi-device host mesh; the
``--xla_force_host_platform_device_count`` trick only works if the env
var is set before anything initializes the backend.  Doing it here —
conftest imports before every test module — gives every test 8
simulated CPU devices without env-var ordering footguns, and still
protects against repro.launch.dryrun (which requests 512 for its own
process) re-raising the count mid-suite: the backend is locked below.
"""

from repro.launch.mesh import ensure_sim_devices

ensure_sim_devices(8)    # sets XLA_FLAGS, then locks the backend

import jax  # noqa: E402

assert jax.local_device_count() >= 8
