"""Initialize jax's device count (1 CPU device) before any test module
can import repro.launch.dryrun, which sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 for the dry-run
process.  Touching jax.devices() here locks the backend first, so tests
always see exactly one device."""

import jax

jax.devices()
