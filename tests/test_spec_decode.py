"""Speculative-decoding edge cases.

The broad contract — drafted serving bit-matches the per-request
oracle across cache layouts — lives in tests/test_serving_trace.py.
This module pins down the corners ISSUE 6 names explicitly:

  * an all-empty draft round falls back to the normal round bit-exactly
    (``decode_round_spec`` with draft_len 0 everywhere IS
    ``decode_round``, logits and cache included), and a spec_k
    scheduler that never sees a draft never runs the verify executable;
  * an EOS inside the accepted prefix finishes the request exactly
    where sequential decode would;
  * a draft longer than the lane's remaining ``max_new_tokens`` budget
    is clipped at staging, never committed past the budget;
  * a lane killed mid-verify (StopPolicy, drafts still queued) returns
    every pool block and drops its draft queue;
  * the unsupported-config guards raise at construction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving import batch as batch_lib
from repro.serving.batch import GenConfig
from repro.serving.scheduler import (Request, RequestGroup, Scheduler,
                                     StopPolicy)

KEY = 11
EOS_OFF = 99          # == vocab_size: unreachable, disables EOS


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=99, source="test")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def _gcfg(**kw):
    base = dict(max_new_tokens=12, temperature=0.7, top_p=1.0,
                eos_id=EOS_OFF, pad_id=0)
    base.update(kw)
    return GenConfig(**base)


def _sched(params, cfg, gcfg, **kw):
    base = dict(n_lanes=3, round_tokens=4, max_prompt_len=16)
    base.update(kw)
    return Scheduler(params, cfg, None, gcfg, **base)


def _reqs(n=4, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(uid=u,
                    tokens=list(rng.integers(3, 97, size=rng.integers(2, 9))))
            for u in range(n)]


def _tokens(comps):
    return {c.uid: list(c.tokens) for c in comps}


# ----------------------------------------------------------------------
# k=0: the fallback must be bitwise, not just token-equal
# ----------------------------------------------------------------------

def test_all_empty_draft_round_is_bitwise_decode_round(setup):
    params, cfg = setup
    gcfg = _gcfg()
    prompt = jnp.asarray(np.random.default_rng(2).integers(3, 97, (3, 6)))
    lengths = jnp.array([6, 4, 5], jnp.int32)
    logits, cache = model_lib.prefill(params, cfg, tokens=prompt,
                                      lengths=lengths, max_len=32,
                                      last_only=True)
    done = jnp.zeros((3,), bool)
    key = jax.random.PRNGKey(KEY)
    salts = jnp.array([7, 8, 9], jnp.int32)
    steps = jnp.zeros((3,), jnp.int32)
    c1, l1, d1, t1 = batch_lib.decode_round(
        params, cfg, gcfg, dict(cache), logits, done, key, salts, steps, 4)
    c2, l2, d2, spec_toks, accept, t2 = batch_lib.decode_round_spec(
        params, cfg, gcfg, dict(cache), logits, done, key, salts, steps,
        jnp.zeros((3, 4), jnp.int32), jnp.zeros((3,), jnp.int32), 4)
    assert np.array_equal(np.asarray(t1), np.asarray(t2))
    assert np.asarray(jnp.all(l1 == l2))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.asarray(accept).sum() == 0
    assert np.array_equal(np.asarray(c1["pos"]), np.asarray(c2["pos"]))
    # the rejected-draft K/V slots are rolled back: validity bitmaps match
    assert np.array_equal(np.asarray(c1["cache_pos"] >= 0),
                          np.asarray(c2["cache_pos"] >= 0))


def test_spec_scheduler_without_drafts_never_runs_verify(setup):
    params, cfg = setup
    gcfg = _gcfg()
    reqs = _reqs()
    base, _ = _sched(params, cfg, gcfg).run(
        [Request(**vars(r)) for r in reqs], KEY)
    sched = _sched(params, cfg, gcfg, spec_k=4)
    comps, stats = sched.run([Request(**vars(r)) for r in reqs], KEY)
    assert _tokens(comps) == _tokens(base)
    assert stats.spec_rounds == 0 and stats.drafted_tokens == 0


# ----------------------------------------------------------------------
# EOS inside the accepted prefix
# ----------------------------------------------------------------------

def test_eos_inside_accepted_prefix_finishes_exactly(setup):
    params, cfg = setup
    req = Request(uid=5, tokens=[4, 9, 11, 13])
    # the salted sample stream does not depend on eos_id, so the
    # EOS-disabled run IS the stream; re-serving with eos = stream[2]
    # must stop at its first occurrence
    stream, _ = _sched(params, cfg, _gcfg()).run(
        [Request(**vars(req)), Request(uid=6, tokens=[3, 3])], KEY)
    stream = list(stream[0].tokens)
    eos = int(stream[2])
    stop = stream.index(eos) + 1
    gcfg = _gcfg(eos_id=eos)
    want = stream[:stop]
    undrafted, _ = _sched(params, cfg, gcfg).run(
        [Request(**vars(req))], KEY)
    assert list(undrafted[0].tokens) == want
    sched = _sched(params, cfg, gcfg, spec_k=8, paged=True, block_size=8)
    loop = sched.loop(KEY)
    # draft the whole EOS-disabled stream: the EOS lands inside the
    # first verify round's accepted prefix
    loop.submit([Request(**vars(req))], draft_tokens={5: stream})
    comps = loop.drain()
    stats = loop.close()
    assert list(comps[0].tokens) == want
    assert stats.spec_rounds == 1 and stats.rounds == 1
    assert stats.accepted_draft_tokens >= stop
    assert stats.leak_report is None


# ----------------------------------------------------------------------
# draft longer than the remaining budget
# ----------------------------------------------------------------------

def test_draft_longer_than_budget_is_clipped(setup):
    params, cfg = setup
    gcfg = _gcfg()
    req = Request(uid=3, tokens=[8, 7, 6], max_new_tokens=3)
    base, _ = _sched(params, cfg, gcfg).run(
        [Request(**vars(req))], KEY)
    want = list(base[0].tokens)
    assert len(want) == 3
    sched = _sched(params, cfg, gcfg, spec_k=4, paged=True, block_size=8)
    loop = sched.loop(KEY)
    loop.submit([Request(**vars(req))],
                draft_tokens={3: want + [1, 1, 1, 1, 1]})
    comps = loop.drain()
    stats = loop.close()
    assert list(comps[0].tokens) == want
    # staging must clip the window to the remaining budget: 3 fed, not
    # spec_k, and nothing committed past the budget
    assert stats.drafted_tokens == 3
    assert stats.accepted_draft_tokens == 3
    assert stats.leak_report is None


# ----------------------------------------------------------------------
# kill mid-verify
# ----------------------------------------------------------------------

def test_kill_mid_verify_frees_blocks_and_queue(setup):
    params, cfg = setup
    gcfg = _gcfg(max_new_tokens=24)

    class CrossKill(StopPolicy):
        def observe(self, comp):
            return (1,) if comp.group == 0 else ()

    sched = _sched(params, cfg, gcfg, spec_k=4, paged=True, block_size=8,
                   n_lanes=4, round_tokens=2)
    loop = sched.loop(KEY, stop_policy=CrossKill())
    fast = RequestGroup([Request(uid=j, tokens=[5, 6, 7], group=0,
                                 max_new_tokens=2) for j in range(2)])
    slow = RequestGroup([Request(uid=10 + j, tokens=[9, 9, 8], group=1,
                                 max_new_tokens=24) for j in range(2)])
    # long junk drafts keep the victims' queues non-empty (junk rarely
    # matches, so one token is re-verified round after round) until the
    # cross-kill lands mid-verify
    loop.submit([fast, slow], draft_tokens={10: [1] * 24, 11: [2] * 24})
    comps = loop.drain()
    stats = loop.close()
    by_uid = {c.uid: c for c in comps}
    assert not by_uid[0].cancelled
    assert by_uid[10].cancelled and by_uid[11].cancelled
    assert stats.spec_rounds > 0
    assert loop._drafts == {}, "killed lanes must drop their draft queues"
    assert sched.pool.leak_report() is None
    assert stats.leak_report is None


# ----------------------------------------------------------------------
# construction guards
# ----------------------------------------------------------------------

def test_spec_rejects_unsupported_configs(setup):
    import dataclasses

    params, cfg = setup
    gcfg = _gcfg()
    with pytest.raises(ValueError, match="spec_k"):
        _sched(params, cfg, gcfg, spec_k=0)
    with pytest.raises(ValueError, match="non-ring"):
        ring = dataclasses.replace(cfg, sliding_window=8, global_every=0)
        _sched(params, ring, gcfg, spec_k=4)
    # quantized caches are supported since the int8 serving tier:
    # construction must NOT raise (per-slot quantization makes verify
    # rollback bit-stable; see model.verify_step)
    _sched(params, dataclasses.replace(cfg, kv_quant=True), gcfg, spec_k=4)
