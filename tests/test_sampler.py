"""Sampler contracts: greedy fallback and the documented top-p
tie-at-the-nucleus-edge boundary behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import sample_tokens, top_p_mask


def test_greedy_when_temperature_zero():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [2.0, 0.0, 1.0]])
    out = sample_tokens(jax.random.PRNGKey(0), logits, temperature=0.0,
                        top_p=0.1)
    assert out.tolist() == [1, 0]


def test_top_p_keeps_nucleus_prefix():
    # probs ~ [0.643, 0.236, 0.087, 0.032, 0.002]: top_p=0.7 keeps the
    # first two (0.643 < 0.7 <= 0.879), masks the rest
    logits = jnp.log(jnp.asarray([[0.643, 0.236, 0.087, 0.032, 0.002]]))
    masked = np.asarray(top_p_mask(logits, 0.7))
    assert np.all(np.isfinite(masked[0, :2]))
    assert np.all(np.isinf(masked[0, 2:])) and np.all(masked[0, 2:] < 0)


def test_top_p_boundary_ties_are_all_kept():
    """Documented contract: logits exactly equal to the one at the
    nucleus cutoff all survive, even those whose cumulative rank falls
    outside top_p — the kept set must not depend on sort tie order."""
    # three exactly-tied logits at the edge; p_tied ~ 0.245 each, head
    # ~ 0.221: cumulative crosses top_p=0.5 inside the tied run
    logits = jnp.asarray([[1.0, 1.1, 1.0, 1.0, -4.0]])
    masked = np.asarray(top_p_mask(logits, 0.5))
    assert np.all(np.isfinite(masked[0, [0, 1, 2, 3]]))   # head + all 3 ties
    assert np.isinf(masked[0, 4]) and masked[0, 4] < 0
    # permutation invariance of the kept set
    perm = np.asarray([4, 2, 0, 3, 1])
    masked_p = np.asarray(top_p_mask(jnp.asarray(np.asarray(logits)[:, perm]),
                                     0.5))
    np.testing.assert_array_equal(np.isfinite(masked_p[0]),
                                  np.isfinite(masked[0])[perm])


def test_top_p_one_keeps_everything():
    logits = jnp.asarray([[0.3, -2.0, 1.4, 0.0]])
    # top_p=1.0 short-circuits in sample_tokens; the mask itself must
    # also be a no-op at the boundary value
    masked = np.asarray(top_p_mask(logits, 1.0))
    assert np.all(np.isfinite(masked))


def test_sampled_tokens_respect_mask():
    # with top_p=0.5 on the tied distribution above, token 4 is masked:
    # no key may ever produce it, while every kept tie stays reachable
    logits = jnp.broadcast_to(jnp.asarray([[1.0, 1.1, 1.0, 1.0, -4.0]]),
                              (64, 5))
    seen = set()
    for s in range(20):
        toks = sample_tokens(jax.random.PRNGKey(s), logits, temperature=1.0,
                             top_p=0.5)
        seen.update(np.asarray(toks).tolist())
    assert 4 not in seen
    assert {0, 1, 2, 3} <= seen
