"""Per-architecture smoke tests: reduced same-family variant (<=2 layers,
d_model<=256, <=4 experts) — one forward + one train step + one decode
step on CPU; asserts shapes and no NaNs.  (Deliverable f.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.models import model as M
from repro.training.optimizer import adamw, cosine_warmup_schedule

ARCHS = [a for a in ARCH_IDS]


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, rng):
    cfg = smoke_variant(get_config(arch))
    params = M.init_params(cfg, rng)
    B, S = 2, 24
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.embedding_inputs:
        emb = jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32) * 0.02
        logits, aux = M.forward(params, cfg, embeds=emb)
    else:
        logits, aux = M.forward(params, cfg, tokens=toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch, rng):
    cfg = smoke_variant(get_config(arch))
    params = M.init_params(cfg, rng)
    opt = adamw(cosine_warmup_schedule(1e-3, 10))
    B, S = 2, 16
    toks = np.asarray(jax.random.randint(rng, (B, S), 0, cfg.vocab_size))
    mask = np.ones((B, S), np.int32)

    def loss_fn(p):
        if cfg.embedding_inputs:
            emb = jnp.take(p["embed"]["embedding"], jnp.asarray(toks), axis=0)
            logits, aux = M.forward(p, cfg, embeds=emb)
            return M.lm_loss(cfg, logits, jnp.asarray(toks),
                             jnp.asarray(mask), aux)
        logits, aux = M.forward(p, cfg, tokens=jnp.asarray(toks[:, :-1]))
        return M.lm_loss(cfg, logits, jnp.asarray(toks[:, 1:]),
                         jnp.asarray(mask[:, 1:]), aux)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss))
    state = opt.init(params)
    new_params, _ = opt.update(grads, state, params)
    leaves = jax.tree_util.tree_leaves(new_params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    cfg = smoke_variant(get_config(arch))
    if cfg.is_moe:   # capacity dropping differs between batch sizes
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = M.init_params(cfg, rng)
    B, S = 2, 20
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits, _ = M.forward(params, cfg, tokens=toks)
    _, cache = M.prefill(params, cfg, tokens=toks, max_len=S + 4)
    nxt = jnp.argmax(logits[:, -1], -1)
    dlogits, cache = M.decode_step(params, cfg, nxt, cache)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    flog, _ = M.forward(params, cfg, tokens=toks2)
    err = float(jnp.max(jnp.abs(dlogits.astype(jnp.float32) -
                                flog[:, -1].astype(jnp.float32))))
    assert err < 0.1, f"{arch}: decode/forward mismatch {err}"


def test_param_counts_match_init():
    """Analytic param_count agrees with actual init within 1%."""
    for arch in ("llama3-8b", "olmoe-1b-7b", "mamba2-1.3b", "hymba-1.5b"):
        cfg = smoke_variant(get_config(arch))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(l.size for l in jax.tree_util.tree_leaves(params))
        assert abs(actual - cfg.param_count()) / actual < 0.01, arch
