"""Sharding-rule unit tests + a 1-device pjit smoke of the distributed
step builders (the 512-device lower/compile runs live in the dry-run
sweep, launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, smoke_variant
from repro.distributed import sharding as sh
from repro.launch.analytics import (analytic_flops, collective_bytes_structural)
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    """Mesh stand-in with production axis sizes (no jax device state)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _abstract_params(cfg):
    from repro.models import model as M
    return jax.eval_shape(lambda k: M.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [POD, MULTI], ids=["pod", "multipod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    params = _abstract_params(cfg)
    specs = sh.param_specs(cfg, params, mesh)

    def check(path, leaf, spec):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert leaf.shape[dim] % size == 0, (arch, path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda path, leaf, spec: check(path, leaf, spec), params, specs,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-1.3b", "hymba-1.5b",
                                  "gemma3-1b", "olmoe-1b-7b"])
def test_cache_specs_divisible(arch):
    from repro.models import model as M
    cfg = get_config(arch)
    for shape_name in ("decode_32k", "long_500k"):
        shp = INPUT_SHAPES[shape_name]
        spec = sh.cache_specs(cfg, POD, shp.global_batch)
        cache = jax.eval_shape(
            lambda: M.init_decode_state(cfg, shp.global_batch,
                                        min(shp.seq_len, 16384)))
        def check(leaf, sp):
            for dim, axis in enumerate(sp):
                if axis is None:
                    continue
                axes = (axis,) if isinstance(axis, str) else axis
                size = 1
                for a in axes:
                    size *= POD.shape[a]
                assert leaf.shape[dim] % size == 0, (arch, shape_name,
                                                     leaf.shape, sp)
        jax.tree.map(check, cache, spec, is_leaf=lambda x: isinstance(x, P))


def test_llama4_gets_fsdp_expert_sharding():
    cfg = get_config("llama4-scout-17b-a16e")
    assert cfg.param_count() > sh.FSDP_PARAM_THRESHOLD
    params = _abstract_params(cfg)
    specs = sh.param_specs(cfg, params, POD)
    moe_spec = specs["layers"]["moe"]["wi_gate"]
    assert moe_spec == P(None, "model", "data", None)


def test_zero_spec_picks_divisible_dim():
    assert sh.zero_spec(P(None, "model"), (48, 32), 16) == P("data", "model")
    assert sh.zero_spec(P(None, None), (7, 32), 16) == P(None, "data")
    assert sh.zero_spec(P(None,), (7,), 16) == P(None)


def test_host_mesh_pjit_train_step_runs():
    """The distributed train step executes on a 1x1 mesh (CPU)."""
    from repro.launch.dryrun import make_train_step
    mesh = make_host_mesh()
    cfg = smoke_variant(get_config("llama3-8b"))
    from repro.models import model as M
    from repro.training.optimizer import adamw, cosine_warmup_schedule
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(cosine_warmup_schedule(1e-3, 10))
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.int32(0)}
    step = make_train_step(cfg)
    b, s = 2, 32
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "loss_mask": jnp.ones((b, s), jnp.int32)}
    with mesh:
        new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1


def test_analytic_flops_positive_all_pairs():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shp in INPUT_SHAPES.values():
            f = analytic_flops(cfg, shp)
            assert f > 0, (arch, shp.name)


def test_collective_parser_loop_multiplier():
    hlo = """
HloModule test
%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8] all-reduce(f32[8] %x), to_apply=%add
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}
%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(26)
  ROOT %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), condition=%cond.1, body=%body.1
  %ag = f32[16] all-gather(f32[8] %a)
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    res = collective_bytes_structural(hlo)
    assert res["all-reduce"] == 26 * 8 * 4
    assert res["all-gather"] == 16 * 4
    assert res["n_all-reduce"] == 26
