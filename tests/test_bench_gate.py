"""Unit tests for scripts/check_bench_regression.py — the CI
benchmark-regression gate.  It decides whether smoke benchmarks block a
merge, so its tolerance arithmetic, direction handling, missing-key
behaviour, baseline-free invariants, and exit codes are pinned here."""

import importlib.util
import json
import pathlib
import sys

import pytest

SCRIPT = pathlib.Path(__file__).resolve().parent.parent / "scripts" / \
    "check_bench_regression.py"
spec = importlib.util.spec_from_file_location("check_bench_regression",
                                              SCRIPT)
gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(gate)


# ----------------------------------------------------------------------
# walk: which metrics get gated at all
# ----------------------------------------------------------------------

def test_walk_recurses_and_skips_missing_and_bools():
    cur = {"table": {"arith": {"generated_tokens": 10, "wall_s": 1.0,
                               "equal_accuracy": True,     # bool: ignored
                               "note": "hi",               # str: ignored
                               "new_metric_no_base": 5,    # gated name absent
                               "rounds": 3}}}
    base = {"table": {"arith": {"generated_tokens": 12, "wall_s": 2.0}}}
    got = {path: (v, b) for path, _, v, b in gate.walk(cur, base)}
    # present in both -> gated; rounds missing from baseline -> skipped;
    # bools/strings/never-gated names -> skipped
    assert got == {"table.arith.generated_tokens": (10.0, 12.0),
                   "table.arith.wall_s": (1.0, 2.0)}


def test_walk_ignores_ungated_numeric_keys():
    cur = {"speedup": 2.0, "generated_tokens": 5}
    base = {"speedup": 1.0, "generated_tokens": 5}
    assert [p for p, *_ in gate.walk(cur, base)] == ["generated_tokens"]


# ----------------------------------------------------------------------
# check_metrics: tolerance edges, both directions, wall slack
# ----------------------------------------------------------------------

def _fails(cur, base, wall_slack=3.0):
    failures, _ = gate.check_metrics(cur, base, wall_slack)
    return failures


def test_low_direction_tolerance_edge():
    # generated_tokens: low is better, rel 0.20, slack 16
    base = {"generated_tokens": 100}
    limit = 100 * 1.20 + 16
    assert _fails({"generated_tokens": limit}, base) == []        # at bound
    assert len(_fails({"generated_tokens": limit + 1}, base)) == 1
    # improvements never fail
    assert _fails({"generated_tokens": 0}, base) == []


def test_high_direction_tolerance_edge():
    # cancelled_lanes: high is better, rel 0.30, slack 4
    base = {"cancelled_lanes": 100}
    limit = 100 * 0.70 - 4
    assert _fails({"cancelled_lanes": limit}, base) == []
    assert len(_fails({"cancelled_lanes": limit - 1}, base)) == 1


def test_ratio_floor_absolute_tolerance():
    # generated_cut: rel 0.0, abs 0.15
    base = {"generated_cut": 0.5}
    assert _fails({"generated_cut": 0.35}, base) == []
    assert len(_fails({"generated_cut": 0.34}, base)) == 1


def test_wall_metrics_gate_at_slack_only():
    base = {"wall_s": 10.0, "ttft_p95_s": 0.5}
    assert _fails({"wall_s": 29.9, "ttft_p95_s": 1.49}, base) == []
    bad = _fails({"wall_s": 30.1, "ttft_p95_s": 1.51}, base)
    assert len(bad) == 2
    assert _fails({"wall_s": 30.1}, base, wall_slack=4.0) == []


# ----------------------------------------------------------------------
# Baseline-free invariants
# ----------------------------------------------------------------------

def _pipe_row(seq_wall=10.0, pipe_wall=5.0, seq_rounds=40, pipe_rounds=30,
              equal=True):
    return {"sequential": {"wall_s": seq_wall, "rounds": seq_rounds},
            "pipelined": {"wall_s": pipe_wall, "rounds": pipe_rounds},
            "equal_accuracy": equal}


def test_pipeline_invariants_pass_and_fail():
    ok = {"table": {"arith": _pipe_row()}}
    assert gate.check_pipeline_invariants(ok) == []
    bad = {"table": {"arith": _pipe_row(pipe_wall=11.0, pipe_rounds=40,
                                        equal=False)}}
    msgs = gate.check_pipeline_invariants(bad)
    assert len(msgs) == 3          # accuracy, wall, rounds all violated


def _chunk_row(whole_p95=1.0, chunk_p95=0.5, tokens=True, acc=True):
    return {"whole": {"ttft_p95_s": whole_p95},
            "chunked": {"ttft_p95_s": chunk_p95},
            "equal_tokens": tokens, "equal_accuracy": acc}


def test_chunked_invariants_pass_and_fail():
    assert gate.check_chunked_invariants(
        {"table": {"serve": _chunk_row()}}) == []
    msgs = gate.check_chunked_invariants(
        {"table": {"serve": _chunk_row(chunk_p95=1.0, tokens=False,
                                       acc=False)}})
    assert len(msgs) == 3          # bit-identity, accuracy, strict ttft win
    # rows without both paths are ignored, not crashed on
    assert gate.check_chunked_invariants(
        {"table": {"serve": {"whole": {"ttft_p95_s": 1.0}}}}) == []


def _preempt_row(resumes=4, blocked_no_off=10, blocked_pre=2, equal=True):
    return {"no_offload": {"admission_blocked": blocked_no_off},
            "preempt": {"admission_blocked": blocked_pre,
                        "resumes": resumes},
            "completions_bitequal": equal}


def test_preempt_invariants_pass_and_fail():
    assert gate.check_preempt_invariants(
        {"table": {"arith": _preempt_row()}}) == []
    msgs = gate.check_preempt_invariants(
        {"table": {"arith": _preempt_row(resumes=0, blocked_pre=10,
                                         equal=False)}})
    assert len(msgs) == 3          # resumes, bit-identity, strict blocked win
    # rows without both paths are ignored, not crashed on
    assert gate.check_preempt_invariants(
        {"table": {"arith": {"preempt": {"resumes": 1}}}}) == []


def _quant_row(fp_peak=1000, int8_peak=300, fp_dense=4000, int8_dense=1200,
               fp_acc=0.5, int8_acc=0.5, gain=3.3, cut=0.7, agree=0.8,
               equal=True):
    return {"fp32": {"peak_cache_bytes": fp_peak, "dense_cache_bytes": fp_dense,
                     "accuracy": fp_acc, "n_lanes": 12},
            "int8": {"peak_cache_bytes": int8_peak,
                     "dense_cache_bytes": int8_dense,
                     "accuracy": int8_acc, "n_lanes": 12},
            "equal_lanes": equal,
            "lanes_per_byte_gain": gain, "kv_bytes_cut": cut,
            "token_agreement": agree}


def test_quant_invariants_pass_and_fail():
    assert gate.check_quant_invariants(
        {"table": {"arith": _quant_row()}}) == []
    bad = {"table": {"arith": _quant_row(
        int8_peak=1000, int8_dense=5000, gain=1.2, cut=0.1,
        int8_acc=0.1, agree=0.05, equal=False)}}
    msgs = gate.check_quant_invariants(bad)
    # lanes, peak bytes, dense bytes, efficiency bar, accuracy, agreement
    assert len(msgs) == 6
    # rows without both precisions are ignored, not crashed on
    assert gate.check_quant_invariants(
        {"table": {"arith": {"fp32": {"peak_cache_bytes": 1}}}}) == []


def test_quant_invariants_efficiency_bar_is_either_or():
    # a 1.7x lanes/byte gain clears the bar even with a small peak cut
    assert gate.check_quant_invariants(
        {"table": {"a": _quant_row(gain=1.7, cut=0.1)}}) == []
    # ...and a 40% peak cut clears it even at a low gain
    assert gate.check_quant_invariants(
        {"table": {"a": _quant_row(gain=1.2, cut=0.4)}}) == []
    assert len(gate.check_quant_invariants(
        {"table": {"a": _quant_row(gain=1.69, cut=0.39)}})) == 1


def test_quant_invariants_accuracy_respects_tol():
    row = _quant_row(fp_acc=0.5, int8_acc=0.4)
    assert len(gate.check_quant_invariants({"table": {"a": row}},
                                           tol=0.1)) == 1
    assert gate.check_quant_invariants({"table": {"a": row}},
                                       tol=0.2) == []


# ----------------------------------------------------------------------
# --tol: generic accuracy tolerance in check_metrics
# ----------------------------------------------------------------------

def test_accuracy_metrics_gate_downward_at_tol():
    base = {"accuracy": 0.80, "token_agreement": 0.90}
    # bound = base * (1 - tol) - 0.02 abs slack
    ok = {"accuracy": 0.80 * 0.9 - 0.02, "token_agreement": 0.90 * 0.9 - 0.02}
    failures, _ = gate.check_metrics(ok, base, 3.0, tol=0.1)
    assert failures == []
    bad = {"accuracy": 0.80 * 0.9 - 0.03, "token_agreement": 0.90 * 0.9 - 0.03}
    failures, _ = gate.check_metrics(bad, base, 3.0, tol=0.1)
    assert len(failures) == 2
    # a looser --tol admits the same run
    failures, _ = gate.check_metrics(bad, base, 3.0, tol=0.2)
    assert failures == []
    # improvements never fail
    failures, _ = gate.check_metrics({"accuracy": 1.0, "token_agreement": 1.0},
                                     base, 3.0, tol=0.0)
    assert failures == []


# ----------------------------------------------------------------------
# main(): exit codes and --update
# ----------------------------------------------------------------------

def _run_main(tmp_path, monkeypatch, cur, base, extra=()):
    c = tmp_path / "cur.json"
    b = tmp_path / "base.json"
    c.write_text(json.dumps(cur))
    b.write_text(json.dumps(base))
    monkeypatch.setattr(sys, "argv",
                        ["check_bench_regression.py", str(c), str(b),
                         *extra])
    return gate.main(), c, b


def test_main_exit_zero_on_clean_run(tmp_path, monkeypatch, capsys):
    rc, _, _ = _run_main(tmp_path, monkeypatch,
                         {"generated_tokens": 90}, {"generated_tokens": 100})
    assert rc == 0
    assert "no regressions" in capsys.readouterr().out


def test_main_exit_nonzero_on_regression(tmp_path, monkeypatch, capsys):
    rc, _, _ = _run_main(tmp_path, monkeypatch,
                         {"generated_tokens": 200}, {"generated_tokens": 100})
    assert rc == 1
    assert "regression" in capsys.readouterr().out


def test_main_exit_nonzero_on_invariant_failure(tmp_path, monkeypatch):
    cur = {"pipeline_cascade": True,
           "table": {"arith": _pipe_row(pipe_wall=20.0)}}
    rc, _, _ = _run_main(tmp_path, monkeypatch, cur, {})
    assert rc == 1
    cur = {"chunked_serve": True,
           "table": {"serve": _chunk_row(chunk_p95=2.0)}}
    rc, _, _ = _run_main(tmp_path, monkeypatch, cur, {})
    assert rc == 1


def test_main_dispatches_quant_invariants_and_tol(tmp_path, monkeypatch):
    cur = {"quant_smoke": True,
           "table": {"arith": _quant_row(fp_acc=0.5, int8_acc=0.4)}}
    rc, _, _ = _run_main(tmp_path, monkeypatch, cur, {})
    assert rc == 1                 # default --tol 0.1 rejects a 20% drop
    rc, _, _ = _run_main(tmp_path, monkeypatch, cur, {},
                         extra=("--tol", "0.2"))
    assert rc == 0


def test_main_update_rewrites_baseline(tmp_path, monkeypatch):
    cur = {"generated_tokens": 500}
    rc, c, b = _run_main(tmp_path, monkeypatch, cur,
                         {"generated_tokens": 1}, extra=("--update",))
    assert rc == 0
    assert json.loads(b.read_text()) == cur


def test_main_missing_file_raises(tmp_path, monkeypatch):
    monkeypatch.setattr(sys, "argv",
                        ["check_bench_regression.py",
                         str(tmp_path / "nope.json"),
                         str(tmp_path / "also-nope.json")])
    with pytest.raises(FileNotFoundError):
        gate.main()
