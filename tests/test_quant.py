"""Quantized serving tiers (int8 KV + int8 weights).

The quantized tier's contract is three-layered:

  * **determinism**: quantization is elementwise and per-slot, so the
    quant serving output is bit-identical across *every* serving
    configuration (dense vs paged vs shared-prefix, drafted, preempted)
    and bit-identical to the quantized one-shot engine — the broad
    trace form lives in tests/test_serving_trace.py; this module pins
    the one-shot equalities;
  * **tolerance**: quant vs fp32 serving agrees only approximately —
    the comparison is a stated tolerance on token-prefix agreement,
    never bit-equality;
  * **construction**: the serving guards lifted for quantized caches
    (paged, chunked prefill, speculative verify) must now construct,
    while the genuinely-unsupported combos (SSM/MoE chunking or spec,
    ring caches, share-prefix without paging) still fail fast with
    actionable messages.

Weight quantization (``SLM.quantize="int8"``) is covered at the same
three layers: round-trip properties, quantize-once memoization, and a
mixed-precision cascade where only the cheap tier is quantized.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import cascade_multi as cm
from repro.core import routing as routing_lib
from repro.data import tasks as tasks_lib
from repro.serving.batch import GenConfig
from repro.serving.scheduler import Request, Scheduler

MAXP = 48
MAXNEW = 10
KEY = 7


@pytest.fixture(scope="module")
def setup():
    from repro.data.tokenizer import default_tokenizer
    from repro.models import model as M
    tok = default_tokenizer()
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128, vocab_size=tok.vocab_size, remat=False,
                      source="test")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg, tok


def _gcfg(temperature=0.0):
    return GenConfig(max_new_tokens=MAXNEW, temperature=temperature,
                     top_p=1.0, eos_id=2)


def _sched(params, cfg, temperature=0.0, **kw):
    base = dict(n_lanes=4, round_tokens=5, max_prompt_len=MAXP)
    base.update(kw)
    return Scheduler(params, cfg, None, _gcfg(temperature), **base)


def _reqs(n=6, seed=3):
    rng = np.random.RandomState(seed)
    return [Request(uid=u,
                    tokens=rng.randint(3, 90,
                                       (int(rng.randint(1, 34)),)).tolist(),
                    max_new_tokens=MAXNEW)
            for u in range(n)]


def _tokens(comps):
    return {c.uid: list(c.tokens) for c in comps}


def _prefix_agreement(got, want):
    """Fraction of ``want`` that ``got`` reproduces as an exact prefix."""
    if not want:
        return 1.0
    n = 0
    for a, b in zip(got, want):
        if a != b:
            break
        n += 1
    return n / len(want)


# ----------------------------------------------------------------------
# Determinism: quant serving is bit-equal across cache layouts
# ----------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_quant_serving_bitexact_across_layouts(setup, temperature):
    """Dense, paged, and shared-prefix quant schedulers must produce
    literally identical completions: quantization happens once per
    cache slot at lane insertion, and blocks move as raw int8 + scales
    everywhere after that."""
    params, cfg, _ = setup
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    reqs = _reqs()
    outs = []
    for kw in (dict(),
               dict(paged=True, block_size=8),
               dict(paged=True, block_size=8, share_prefix=True),
               dict(paged=True, block_size=8, chunk_size=8),
               dict(paged=True, block_size=8, spec_k=4)):
        sched = _sched(params, qcfg, temperature, **kw)
        comps, _ = sched.run([Request(**vars(r)) for r in reqs], KEY)
        outs.append(_tokens(comps))
        if sched.pool is not None:
            assert sched.pool.leak_report() is None
    # whole-prefill layouts are all bit-equal (index 3 is chunked: its
    # prompt K/V quantize chunk-by-chunk, so it only joins the family
    # at tolerance — asserted below)
    for i in (1, 2, 4):
        assert outs[i] == outs[0], f"layout {i} diverged from dense quant"
    agree = [_prefix_agreement(outs[3][u], outs[0][u]) for u in outs[0]]
    assert np.mean(agree) >= 0.5, \
        "chunked quant drifted too far from whole-prefill quant"


# ----------------------------------------------------------------------
# Tolerance: quant vs fp32 serving
# ----------------------------------------------------------------------

def test_quant_tracks_fp_at_tolerance_not_bitexact(setup):
    """int8 KV serving must stay close to fp32 serving (the tier is
    useful) without being bit-equal (the tolerance mode exists for a
    reason).  Greedy decoding, so divergence is purely quantization
    noise crossing an argmax boundary — never sampling jitter."""
    params, cfg, _ = setup
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    reqs = _reqs(n=8, seed=5)
    fp, _ = _sched(params, cfg, 0.0, paged=True, block_size=8).run(
        [Request(**vars(r)) for r in reqs], KEY)
    q, _ = _sched(params, qcfg, 0.0, paged=True, block_size=8).run(
        [Request(**vars(r)) for r in reqs], KEY)
    fp_t, q_t = _tokens(fp), _tokens(q)
    agree = [_prefix_agreement(q_t[u], fp_t[u]) for u in fp_t]
    assert np.mean(agree) >= 0.5, \
        f"quant/fp token agreement collapsed: {agree}"


# ----------------------------------------------------------------------
# Weight quantization: round-trip properties + memoization
# ----------------------------------------------------------------------

def test_quantize_params_int8_roundtrip_properties(setup):
    params, _, _ = setup
    quant = routing_lib.quantize_params_int8(params)
    leaves = jax.tree.leaves(params)
    qleaves = jax.tree.leaves(quant)
    assert len(leaves) == len(qleaves)
    changed = 0
    for w, qw in zip(leaves, qleaves):
        assert w.shape == qw.shape and w.dtype == qw.dtype
        if w.ndim < 2:
            # norm gains / biases / scalars stay exact
            assert np.array_equal(np.asarray(w), np.asarray(qw))
            continue
        wf = np.asarray(w, np.float32)
        qf = np.asarray(qw, np.float32)
        # per-output-channel absmax scale bounds the error at half a
        # quantization step per column
        step = np.abs(wf).max(axis=-2, keepdims=True) / 127.0
        assert np.all(np.abs(wf - qf) <= 0.5 * step + 1e-6)
        changed += int(not np.array_equal(wf, qf))
    # the random-init matmul weights cannot all survive int8 bit-exactly
    assert changed > 0, "int8 round-trip was a no-op on every weight"


def test_tier_params_memoizes_and_validates(setup):
    params, cfg, tok = setup
    slm = routing_lib.SLM(params, cfg, tok, _gcfg())
    # no quantization requested: the original tree, by identity
    assert routing_lib._tier_params(slm) is params
    q8 = dataclasses.replace(slm, quantize="int8")
    first = routing_lib._tier_params(q8)
    assert first is not params
    # quantize-once: the same params tree maps to the same quantized
    # tree, even through a distinct SLM wrapper
    assert routing_lib._tier_params(q8) is first
    assert routing_lib._tier_params(
        dataclasses.replace(slm, quantize="int8")) is first
    with pytest.raises(ValueError, match="only 'int8'"):
        routing_lib._tier_params(dataclasses.replace(slm, quantize="fp4"))


def test_make_scheduler_applies_tier_quantization(setup):
    params, cfg, tok = setup
    slm = routing_lib.SLM(params, cfg, tok, _gcfg(), lane_budget=4,
                          kv_quant=True, quantize="int8")
    sched = routing_lib.make_scheduler(slm, 4)
    assert sched.cfg.kv_quant
    assert sched.params is routing_lib._tier_params(slm)
    assert sched.params is not params
    # the SLM's own cfg is untouched (replace, not mutation)
    assert not cfg.kv_quant


# ----------------------------------------------------------------------
# Mixed-precision cascade: one chain, per-tier precision
# ----------------------------------------------------------------------

def test_mixed_precision_cascade_runs_end_to_end(setup):
    """A cascade whose cheap tier serves int8 KV + int8 weights while
    the next tier stays fp must run through the unchanged
    ``run_cascade`` driver: precision is an SLM attribute, invisible to
    the cascade logic."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=16, temperature=0.0)
    cheap = routing_lib.SLM(params, cfg, tok, gcfg, max_prompt_len=64,
                            lane_budget=8, round_tokens=4,
                            paged=True, block_size=8,
                            kv_quant=True, quantize="int8")
    full = routing_lib.SLM(params, cfg, tok, gcfg, max_prompt_len=64,
                           lane_budget=8, round_tokens=4)
    items = tasks_lib.make_benchmark("arith", 3, seed=1)
    tiers = [cm.Tier(slm=cheap, tau=1.0, mode="FCV", k=2),
             cm.Tier(slm=full, tau=1.0, mode="FCV", k=2)]
    terminal = cm.TerminalTier(llm=routing_lib.OracleLLM(accuracy=1.0))
    out = cm.run_cascade(tiers, terminal, items, jax.random.PRNGKey(9),
                         stream_early_stop=True)
    assert len(out) == len(items)
    s = cm.summarize(out, len(tiers))
    assert sum(s["tier_histogram"]) == len(items)
    assert 0.0 <= s["accuracy"] <= 1.0


# ----------------------------------------------------------------------
# Construction guards: lifted for quant, kept where real
# ----------------------------------------------------------------------

def test_quant_combos_construct(setup):
    """Every guard ISSUE 9 lifts: paged caches, chunked prefill, and
    speculative verify must all accept ``kv_quant`` configs now."""
    params, cfg, _ = setup
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    from repro.models import model as model_lib
    cache = model_lib.init_paged_decode_state(qcfg, 2, 32, 8, 6)
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].shape == cache["k"].shape[:-1]
    assert cache["k_scale"].dtype == jnp.float32
    _sched(params, qcfg, paged=True, block_size=8, chunk_size=8)
    _sched(params, qcfg, paged=True, block_size=8, spec_k=4)
    _sched(params, qcfg, spec_k=4)
    _sched(params, qcfg, paged=True, block_size=8, share_prefix=True,
           chunk_size=8, spec_k=4)


def test_remaining_guards_still_actionable(setup):
    """The combos that stay unsupported must keep failing at
    construction with messages that say *why* — quant lifting must not
    have widened any of them."""
    params, cfg, _ = setup
    ring = dataclasses.replace(cfg, sliding_window=8, global_every=0,
                               kv_quant=True)
    with pytest.raises(ValueError, match="non-ring"):
        _sched(params, ring, spec_k=4)
    with pytest.raises(ValueError, match="full-length"):
        _sched(params, ring, paged=True, block_size=8)
    with pytest.raises(ValueError, match="share_prefix requires paged"):
        _sched(params, cfg, share_prefix=True)
    # SSM-bearing configs: chunked is allowed on the SSD scan grid
    # (rejected off it), speculation stays rejected — recurrent state
    # cannot roll a rejected draft back
    ssm = dataclasses.replace(cfg, ssm_state=16)
    with pytest.raises(ValueError, match="ssm_chunk"):
        _sched(params, ssm, chunk_size=8)
    with pytest.raises(ValueError, match="recurrent"):
        _sched(params, ssm, spec_k=4)
    # MoE guards are gone: dropless decode dispatch makes chunked and
    # speculative serving sound (ISSUE 10), quantized or not
    moe = dataclasses.replace(cfg, n_experts=4, kv_quant=True)
    _sched(params, moe, chunk_size=8)
    _sched(params, moe, spec_k=4)
