"""Data pipeline + serving engine tests."""

import random

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import tasks as tasks_lib
from repro.data.pipeline import (encode_pair, encode_prompts,
                                 preference_batches, sft_batches)
from repro.data.tokenizer import default_tokenizer
from repro.models import model as M
from repro.serving.engine import GenConfig, decode_texts, generate


def test_tokenizer_roundtrip():
    tok = default_tokenizer()
    s = "Q: Compute (3 + 4) mod 97.\nA: Answer: 7."
    assert tok.decode(tok.encode(s)) == s


def test_task_generators_verifiable():
    rng = random.Random(0)
    for name, gen in tasks_lib.GENERATORS.items():
        for d in (1, 3):
            it = gen(rng, d)
            assert it.answer
            assert tasks_lib.is_correct(it, it.verbose)
            assert tasks_lib.is_correct(it, it.concise)
            assert len(it.verbose) >= len(it.concise)
            assert not tasks_lib.is_correct(it, "Answer: nope_xyz.")


def test_modchain_answer_math():
    rng = random.Random(1)
    it = tasks_lib.gen_modchain(rng, 3)
    # recompute from the question text
    expr = it.question.split("(")[1].split(")")[0]
    mod = int(it.question.rsplit("mod", 1)[1].strip(". "))
    acc = None
    toks = expr.split()
    acc = int(toks[0])
    i = 1
    while i < len(toks):
        op, v = toks[i], int(toks[i + 1])
        acc = (acc + v) % mod if op == "+" else (acc * v) % mod
        i += 2
    assert str(acc) == it.answer


def test_rejection_detection():
    assert tasks_lib.is_rejection(tasks_lib.REJECTION)
    assert tasks_lib.is_rejection("Sorry, I can't answer that. extra")
    assert not tasks_lib.is_rejection("Answer: 7.")


def test_encode_pair_masks():
    tok = default_tokenizer()
    toks, mask = encode_pair(tok, "Q: x\nA: ", "Answer: 1.", 64)
    n_prompt = len(tok.encode("Q: x\nA: ", bos=True))
    assert mask[:n_prompt].sum() == 0
    assert mask[n_prompt:].sum() == len(tok.encode("Answer: 1.", eos=True))


def test_batch_iterators():
    tok = default_tokenizer()
    pairs = [("Q: a\nA: ", "Answer: 1.")] * 10
    batches = list(sft_batches(pairs, tok, 4, 48, epochs=2))
    assert len(batches) == 4           # 2 per epoch, drop remainder
    assert batches[0]["tokens"].shape == (4, 48)
    prefs = [("Q: a\nA: ", "Answer: 1.", "Answer: 2. blah blah")] * 8
    pb = list(preference_batches(prefs, tok, 4, 48))
    assert len(pb) == 2
    assert set(pb[0]) == {"chosen", "chosen_mask", "rejected", "rejected_mask"}


def test_generate_greedy_deterministic_and_eos():
    tok = default_tokenizer()
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=tok.vocab_size, remat=False, source="test")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts, lens = encode_prompts(["Q: hi\nA: ", "Q: longer prompt\nA: "],
                                   tok, 40)
    g = GenConfig(max_new_tokens=12, temperature=0.0)
    t1, l1 = generate(params, cfg, prompts, lens, jax.random.PRNGKey(1), g)
    t2, l2 = generate(params, cfg, prompts, lens, jax.random.PRNGKey(2), g)
    np.testing.assert_array_equal(t1, t2)      # greedy ignores key
    assert t1.shape == (2, 12)
    assert all(1 <= l <= 12 for l in l1)
    texts = decode_texts(tok, t1)
    assert all(isinstance(t, str) for t in texts)


def test_generate_gen_len_counts_eos():
    tok = default_tokenizer()
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=tok.vocab_size, remat=False, source="test")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts, lens = encode_prompts(["abc"], tok, 8)
    g = GenConfig(max_new_tokens=6, temperature=0.9)
    toks, glen = generate(params, cfg, prompts, lens, jax.random.PRNGKey(0), g)
    row = toks[0]
    eos = np.nonzero(row == g.eos_id)[0]
    expect = int(eos[0]) + 1 if len(eos) else 6
    assert glen[0] == expect
