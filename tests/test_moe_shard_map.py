"""shard_map expert-parallel MoE (§Perf B9) == reference dispatch.

The shard_map path needs >1 device on the 'model' axis, so the check
runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the main test process must keep seeing 1 device).
"""

import os
import subprocess
import sys
import textwrap


_SCRIPT = textwrap.dedent("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig
    from repro.models import moe as moe_mod
    from repro.models import moe_shard_map as msm

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    msm.set_mesh(mesh)
    cfg = ModelConfig(name="m", arch_type="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=0,
                      vocab_size=50, n_experts=8, moe_top_k=2, moe_d_ff=48,
                      moe_capacity_factor=16.0)
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    out_ref, _ = moe_mod.apply_moe(cfg, p, x)
    cfg_sm = dataclasses.replace(cfg, moe_shard_map=True)
    with mesh:
        out_sm, _ = jax.jit(lambda xx: moe_mod.apply_moe(cfg_sm, p, xx))(x)
        # differentiability: grad of a scalar loss must exist and be finite
        g = jax.jit(jax.grad(
            lambda xx: jnp.sum(moe_mod.apply_moe(cfg_sm, p, xx)[0] ** 2)))(x)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_sm),
                               rtol=2e-4, atol=2e-4)
    assert bool(jnp.all(jnp.isfinite(g)))
    print("OK")
""")


def test_shard_map_moe_matches_reference_subprocess():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
