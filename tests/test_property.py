"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import voting
from repro.core.confidence import Vote
from repro.core.cost import with_ratio
from repro.core.metrics import QuestionRecord, curve_points, toa
from repro.data.tokenizer import default_tokenizer
from repro.launch.analytics import _type_bytes

conf_levels = st.sampled_from([round(0.1 * i, 1) for i in range(1, 11)])
answers = st.sampled_from(["a", "b", "c", None])


@st.composite
def vote_lists(draw, min_size=1, max_size=12):
    n = draw(st.integers(min_size, max_size))
    return [Vote(draw(answers), draw(conf_levels),
                 draw(st.integers(1, 200))) for _ in range(n)]


@given(vote_lists())
@settings(max_examples=200, deadline=None)
def test_vote_scores_normalized(votes):
    scores, total_w = voting.vote_scores(votes)
    assert all(0.0 <= v <= 1.0 + 1e-9 for v in scores.values())
    assert sum(scores.values()) <= 1.0 + 1e-9


@given(vote_lists())
@settings(max_examples=200, deadline=None)
def test_vote_scores_permutation_invariant(votes):
    import random
    shuffled = votes[:]
    random.Random(0).shuffle(shuffled)
    s1, _ = voting.vote_scores(votes)
    s2, _ = voting.vote_scores(shuffled)
    assert set(s1) == set(s2)
    for k in s1:
        assert abs(s1[k] - s2[k]) < 1e-12


@given(vote_lists(), st.sampled_from([0.1, 0.3, 0.5, 0.7, 0.9, 1.0]))
@settings(max_examples=300, deadline=None)
def test_early_stop_agrees_with_full(votes, tau):
    """Early stopping must never change the accept/route decision and
    must never be slower than waiting for every sample."""
    es = voting.decide_with_early_stop(votes, tau)
    full = voting.decide_no_early_stop(votes, tau)
    assert es.accepted == full.accepted
    assert es.decision_tokens <= full.decision_tokens
    assert es.used_tokens <= full.used_tokens


@given(vote_lists(), st.sampled_from([0.2, 0.5, 0.8]))
@settings(max_examples=200, deadline=None)
def test_used_tokens_bounds(votes, tau):
    dec = voting.decide_with_early_stop(votes, tau)
    lo = 0
    hi = sum(v.gen_tokens for v in votes)
    assert lo <= dec.used_tokens <= hi
    assert dec.decision_tokens <= max(v.gen_tokens for v in votes)


@given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
               max_size=200))
@settings(max_examples=200, deadline=None)
def test_tokenizer_roundtrip_property(s):
    tok = default_tokenizer()
    assert tok.decode(tok.encode(s)) == s


@st.composite
def record_lists(draw):
    n = draw(st.integers(5, 40))
    recs = []
    for _ in range(n):
        recs.append(QuestionRecord(
            slm_correct=draw(st.booleans()),
            llm_correct=draw(st.booleans()),
            slm_in_tokens=draw(st.integers(1, 100)),
            slm_out_tokens=draw(st.integers(1, 200)),
            llm_out_tokens=draw(st.integers(1, 200)),
            score=draw(st.floats(0, 1, allow_nan=False))))
    return recs


@given(record_lists(), st.sampled_from([13.75, 25, 50, 100]))
@settings(max_examples=100, deadline=None)
def test_curve_monotone_cost_in_tau(recs, ratio):
    """Pre-gen routing: raising tau routes a superset of questions, so
    normalized cost is non-decreasing in tau (LLM is the dearer model)."""
    cm = with_ratio(ratio)
    pts = curve_points(recs, cm, assume_llm_perfect=True)
    costs = [c for c, _ in pts]
    assert all(c2 >= c1 - 1e-9 for c1, c2 in zip(costs, costs[1:]))
    # routing is strict (score < tau): only score==1.0 questions stay on
    # the SLM at tau=1.0, so perf there is bounded below by the routed mass
    n = len(recs)
    kept = [r for r in recs if r.score >= 1.0]
    lower = (n - len(kept)) / n
    assert pts[-1][1] >= lower - 1e-9


@given(record_lists())
@settings(max_examples=100, deadline=None)
def test_toa_bounded(recs):
    cm = with_ratio(25)
    pts = curve_points(recs, cm, assume_llm_perfect=True)
    c_s = min(c for c, _ in pts)
    p_s = pts[0][1]
    val = toa([(c_s, p_s)] + pts + [(1.0, 1.0)], c_s, p_s, 1.0)
    assert -0.5 <= val <= 1.5


@given(st.lists(st.tuples(st.integers(0, 8), st.integers(0, 63)),
                max_size=60))
@settings(max_examples=300, deadline=None)
def test_block_pool_invariants_under_interleaving(ops):
    """BlockPool under arbitrary interleaved reserve / alloc / share /
    cow / free / offload / restore / discard sequences: no leak
    (in_use + free == blocks), every promise backed (reserved <= free),
    no block live in two unrelated lanes (refcount == model holds;
    alloc/cow never hand out a held block), refcount 0 <=> the block is
    on the free list, refcounts conserved across the device/host
    boundary (offload moves each hold one-for-one, restore moves it
    back), the dual-residence twin maps touch only blocks live on both
    sides, and an under-reserved restore raises before mutating.  The
    op interpreter lives next to the allocator's unit tests
    (tests/test_block_pool.py) and is also driven there with seeded
    random sequences so the invariants hold even without hypothesis."""
    from test_block_pool import drive_block_pool
    drive_block_pool(ops)


@given(st.sampled_from(["f32", "bf16", "s32", "pred"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=3))
@settings(max_examples=100, deadline=None)
def test_hlo_type_bytes(dtype, dims):
    seg = f"{dtype}[{','.join(map(str, dims))}]"
    n = int(np.prod(dims)) if dims else 1
    per = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1}[dtype]
    assert _type_bytes(seg) == n * per
