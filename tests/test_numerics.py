"""Numerical-equivalence tests for the memory-optimized execution paths
(EXPERIMENTS.md §Perf): each optimized path must match its naive
reference on CPU-sized shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.models.attention import chunked_attention, direct_attention


def _dense_cfg(**kw):
    base = dict(name="t", arch_type="dense", n_layers=3, d_model=64,
                n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=50)
    base.update(kw)
    return ModelConfig(**base)


# ----------------------------------------------------------------------
# fused CE == log_softmax + take_along_axis
# ----------------------------------------------------------------------

def test_fused_ce_matches_reference():
    cfg = _dense_cfg()
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 7, cfg.vocab_size)) * 4
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0,
                                cfg.vocab_size)
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (2, 7)) > 0.3
            ).astype(jnp.int32)
    loss, _ = M.lm_loss(cfg, logits, labels, mask)
    logp = jax.nn.log_softmax(logits, -1)
    ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    ref = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1)
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)


# ----------------------------------------------------------------------
# chunked MoE dispatch == unchunked (incl. the S % nc != 0 divisor path)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("s,nc", [(16, 4), (15, 4), (12, 2)])
def test_moe_chunked_matches_unchunked(s, nc):
    cfg = ModelConfig(name="m", arch_type="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=0,
                      vocab_size=50, n_experts=4, moe_top_k=2, moe_d_ff=48,
                      moe_capacity_factor=8.0)   # high cap: no drops
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, 32))
    out0, aux0 = moe_mod.apply_moe(cfg, p, x)
    import dataclasses
    cfg_c = dataclasses.replace(cfg, moe_dispatch_chunks=nc)
    out1, aux1 = moe_mod.apply_moe(cfg_c, p, x)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               rtol=2e-4, atol=2e-4)


def test_moe_chunk_divisor_fallback():
    """s=13 (prime) with nc=4 must fall back to unchunked, not crash."""
    cfg = ModelConfig(name="m", arch_type="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=0,
                      vocab_size=50, n_experts=4, moe_top_k=1, moe_d_ff=48,
                      moe_dispatch_chunks=4)
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 13, 32))
    out, _ = moe_mod.apply_moe(cfg, p, x)
    assert out.shape == (2, 13, 32)
    assert not bool(jnp.any(jnp.isnan(out)))


# ----------------------------------------------------------------------
# chunked (online-softmax, checkpointed) attention == direct
# ----------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 8])
def test_chunked_attention_matches_direct(window):
    cfg = _dense_cfg()
    b, s, h, kv, dh = 2, 24, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o_direct = direct_attention(cfg, q, k, v, pos, pos, jnp.int32(window))
    o_chunked = chunked_attention(cfg, q, k, v, pos, pos, jnp.int32(window),
                                  block=8)
    np.testing.assert_allclose(np.asarray(o_direct), np.asarray(o_chunked),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_grads_match():
    cfg = _dense_cfg()
    b, s, h, kv, dh = 1, 16, 2, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def f_direct(q):
        return jnp.sum(direct_attention(cfg, q, k, v, pos, pos,
                                        jnp.int32(0)) ** 2)

    def f_chunked(q):
        return jnp.sum(chunked_attention(cfg, q, k, v, pos, pos,
                                         jnp.int32(0), block=4) ** 2)

    g1 = jax.grad(f_direct)(q)
    g2 = jax.grad(f_chunked)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=5e-3, atol=5e-4)


# ----------------------------------------------------------------------
# carry-based decode == full forward (dense + hybrid)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dense", "hybrid"])
def test_decode_matches_forward(arch):
    if arch == "dense":
        cfg = _dense_cfg()
    else:
        cfg = ModelConfig(name="h", arch_type="hybrid", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                          d_ff=128, vocab_size=50, ssm_state=8,
                          ssm_head_dim=16, ssm_chunk=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 3,
                              cfg.vocab_size)
    logits_full, _ = M.forward(params, cfg, tokens=toks)
    last, cache = M.prefill(params, cfg, tokens=toks[:, :6],
                            lengths=jnp.array([6, 6]), max_len=9,
                            last_only=True)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, 5]),
                               rtol=5e-3, atol=5e-3)
    cur = cache
    for t in range(6, 8):
        lg, cur = M.decode_step(params, cfg, toks[:, t], cur)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, t]),
                                   rtol=1e-2, atol=1e-2)


def test_prefill_identity_cache_path():
    """max_len == prompt len triggers the scatter-free cache build."""
    cfg = _dense_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 3,
                              cfg.vocab_size)
    _, cache_id = M.prefill(params, cfg, tokens=toks,
                            lengths=jnp.array([8, 5]), last_only=True)
    _, cache_sc = M.prefill(params, cfg, tokens=toks,
                            lengths=jnp.array([8, 5]), max_len=12,
                            last_only=True)
    # identity-path cache slots [0..8) must equal the scatter-path ones
    np.testing.assert_allclose(np.asarray(cache_id["k"]),
                               np.asarray(cache_sc["k"][:, :, :8]),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(cache_id["cache_pos"]),
                                  np.asarray(cache_sc["cache_pos"][:, :8]))


# ----------------------------------------------------------------------
# int8 kv-cache decode (beyond-paper §Perf A5) stays close to bf16
# ----------------------------------------------------------------------

def test_kv_quant_decode_close():
    import dataclasses
    from repro.models.attention import quantize_kv
    cfg = _dense_cfg()
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 3,
                              cfg.vocab_size)
    _, cache = M.prefill(params, cfg, tokens=toks[:, :6],
                         lengths=jnp.array([6, 6]), max_len=10,
                         last_only=True)
    kq, ks = quantize_kv(cache["k"])
    vq, vs = quantize_kv(cache["v"])
    cq = dict(cache, k=kq, v=vq, k_scale=ks, v_scale=vs)
    c1, c2 = cache, cq
    for t in range(6, 10):
        l1, c1 = M.decode_step(params, cfg, toks[:, t], c1)
        l2, c2 = M.decode_step(params, cfgq, toks[:, t], c2)
        dev = float(jnp.max(jnp.abs(jax.nn.softmax(l1) - jax.nn.softmax(l2))))
        assert dev < 0.05, dev
    assert c2["k"].dtype == jnp.int8
