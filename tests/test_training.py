"""Training substrate tests: AdamW, cosine schedule, LoRA, DPO step,
checkpoint roundtrip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.dpo import DPOConfig, dpo_loss, make_full_dpo_step
from repro.models import model as M
from repro.training import checkpoint, lora as lora_lib
from repro.training.optimizer import adamw, cosine_warmup_schedule, global_norm


def tiny_cfg(vocab=64):
    return ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, vocab_size=vocab, remat=False, source="test")


def test_adamw_minimizes_quadratic():
    opt = adamw(lambda s: 0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"x": 2 * params["x"]}
        params, state = opt.update(g, state, params)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_warmup_schedule(1e-3, 100, warmup_ratio=0.1)
    assert float(lr(1)) < float(lr(10))
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) < 1e-4
    assert float(lr(55)) > float(lr(90))


def test_lora_only_adapters_get_grads():
    cfg = tiny_cfg()
    lcfg = lora_lib.LoraConfig(rank=4)
    key = jax.random.PRNGKey(0)
    base = M.init_params(cfg, key)
    adapters = lora_lib.init_lora(base, lcfg, key)
    n_ad = lora_lib.n_lora_params(adapters)
    assert n_ad > 0

    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)

    def loss(lt):
        merged = lora_lib.merge(base, lt, lcfg)
        logits, aux = M.forward(merged, cfg, tokens=toks[:, :-1])
        l, _ = M.lm_loss(cfg, logits, toks[:, 1:],
                         jnp.ones_like(toks[:, 1:]), aux)
        return l

    grads = jax.grad(loss)(adapters)
    gnorm = float(global_norm(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # b-matrices start at zero => merge is identity at init
    merged = lora_lib.merge(base, adapters, lcfg)
    l0, _ = M.forward(base, cfg, tokens=toks[:, :-1])
    l1, _ = M.forward(merged, cfg, tokens=toks[:, :-1])
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-5)


def _pref_batch(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 2)
    chosen = jax.random.randint(ks[0], (b, s), 3, cfg.vocab_size)
    rejected = jax.random.randint(ks[1], (b, s), 3, cfg.vocab_size)
    mask = jnp.concatenate([jnp.zeros((b, s // 2), jnp.int32),
                            jnp.ones((b, s - s // 2), jnp.int32)], 1)
    return {"chosen": chosen, "chosen_mask": mask,
            "rejected": rejected, "rejected_mask": mask}


def test_dpo_loss_prefers_chosen_after_steps():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    from repro.training.optimizer import adamw as mk
    opt = mk(lambda s: 3e-3, weight_decay=0.0)
    step = jax.jit(make_full_dpo_step(cfg, opt))
    state = {"params": params, "ref_params": params,
             "opt_state": opt.init(params), "step": jnp.int32(0)}
    batch = _pref_batch(cfg, key)
    m0 = None
    for i in range(30):
        state, metrics = step(state, batch)
        if i == 0:
            m0 = float(metrics["reward_margin"])
    assert float(metrics["reward_margin"]) > m0
    assert float(metrics["pref_acc"]) == 1.0


def test_dpo_zero_at_init():
    """policy == reference => DPO loss == log 2 exactly."""
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    batch = _pref_batch(cfg, key)
    loss, metrics = dpo_loss(params, params, cfg, batch, DPOConfig(sft_lambda=0.0))
    assert float(metrics["dpo_loss"]) == pytest.approx(np.log(2), rel=1e-3)
    assert float(metrics["reward_margin"]) == pytest.approx(0.0, abs=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    tree = {"params": params, "step": jnp.int32(7),
            "lora": {"a": None, "b": jnp.ones((2, 2), jnp.bfloat16)},
            "hist": [jnp.zeros(3), jnp.ones(2)]}
    path = os.path.join(tmp_path, "ckpt")
    checkpoint.save(path, tree)
    back = checkpoint.restore(path)
    assert back["lora"]["a"] is None
    assert back["lora"]["b"].dtype == jnp.bfloat16
    flat1 = jax.tree_util.tree_leaves(tree)
    flat2 = jax.tree_util.tree_leaves(back)
    assert len(flat1) == len(flat2)
    for l1, l2 in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))
